"""Per-kernel allclose vs the pure-jnp oracle (ref.py), interpret mode.

Sweeps shapes (incl. non-multiples of the block sizes) and dtypes, plus the
feature matrix of the flash kernel (causal x window x softcap x GQA).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(42)


def _tol(dt):
    return dict(atol=3e-2, rtol=3e-2) if dt == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "BH,BKV,Sq,Sk,hd,causal,window,softcap",
    [
        (4, 2, 256, 256, 64, True, 0, 0.0),     # GQA causal
        (4, 4, 128, 384, 64, True, 0, 0.0),     # cross-length
        (2, 1, 200, 200, 32, True, 64, 0.0),    # sliding window + padding
        (2, 2, 256, 256, 64, False, 0, 0.0),    # bidirectional (encoder)
        (4, 2, 256, 256, 128, True, 0, 30.0),   # gemma softcap
        (1, 1, 96, 512, 64, True, 128, 0.0),    # window > q extent
    ])
def test_flash_attention(BH, BKV, Sq, Sk, hd, causal, window, softcap,
                         dtype):
    q = jnp.asarray(RNG.standard_normal((BH, Sq, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((BKV, Sk, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((BKV, Sk, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_shapes():
    """Result is block-size independent."""
    q = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128)
    b = flash_attention(q, k, v, block_q=64, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# rwkv6 scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("T,chunk", [(96, 32), (64, 64), (130, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(T, chunk, dtype):
    BH, n = 3, 16
    r = jnp.asarray(RNG.standard_normal((BH, T, n)), dtype)
    k = jnp.asarray(RNG.standard_normal((BH, T, n)), dtype)
    v = jnp.asarray(RNG.standard_normal((BH, T, n)), dtype)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (BH, T, n)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((BH, n)), jnp.float32)
    y, sT = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), **_tol(dtype))


def test_rwkv6_scan_initial_state():
    BH, T, n = 2, 32, 8
    r, k, v = (jnp.asarray(RNG.standard_normal((BH, T, n)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.8, 0.99, (BH, T, n)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((BH, n)), jnp.float32)
    s0 = jnp.asarray(RNG.standard_normal((BH, n, n)), jnp.float32)
    y, sT = rwkv6_scan(r, k, v, w, u, s0, chunk=16)
    yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# mamba scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,d,N,chunk,block_d",
                         [(2, 64, 32, 8, 32, 16),
                          (1, 100, 48, 16, 64, 32),   # padding both dims
                          (2, 32, 16, 4, 32, 16)])
def test_mamba_scan(B, T, d, N, chunk, block_d):
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, T, d)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B, T, d)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, T, N)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 4.0, (d, N)), jnp.float32)
    y = mamba_scan(dt, x, Bm, Cm, a, chunk=chunk, block_d=block_d)
    yr = ref.mamba_scan_ref(dt, x, Bm, Cm, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 7, 128), (3, 256), (1000, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jnp.asarray(RNG.standard_normal(shape), dtype)
    w = jnp.asarray(RNG.standard_normal(shape[-1]) * 0.1, jnp.float32)
    got = rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------
# kernel oracles vs MODEL paths (ties the two stacks together)
# --------------------------------------------------------------------------
def test_model_attention_matches_kernel_ref():
    from repro.configs.base import AttentionConfig
    from repro.models.attention import attend_qchunk
    B, S, H, KV, hd = 2, 256, 4, 2, 32
    acfg = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    got = attend_qchunk(acfg, q, k, v, pos, pos, window=0, q_chunk=64)
    qk = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    want = ref.flash_attention_ref(qk, kk, vk).reshape(
        B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_model_wkv_matches_kernel_ref():
    from repro.models.rwkv6 import _wkv_chunk_scan
    B, T, D, n = 2, 64, 32, 16
    r, k, v = (jnp.asarray(RNG.standard_normal((B, T, D)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.7, 0.99, (B, T, D)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal(D), jnp.float32)
    got = _wkv_chunk_scan(r, k, v, w, u, head_dim=n, chunk=16)
    from repro.kernels import ops
    want, _ = ops.wkv(r, k, v, w, u, head_dim=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)

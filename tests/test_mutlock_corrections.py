"""C1/C2 wake-up-count correction paths of MutableLock (Algorithm 1
A23-A33, R2-R7) — the grow-with-sleepers and shrink-with-excess-spinners
cases, exercised deterministically (scripted oracle + phantom waiters on
the packed lstate word) and with real threads.

Phantom-waiter technique: ``lstate.fetch_add(k)`` registers k waiters
exactly as k concurrent ``acquire()`` calls would (A4) without parking real
threads, so the correction arithmetic observed by the next acquirer is
deterministic.  Wake permits issued toward phantoms land in the semaphore
(banked), where we can count them.
"""

import threading
import time

import pytest

from repro.core.mutlock import MutableLock
from repro.core.oracle import EvalSWS


class ScriptedOracle:
    """Replays a fixed delta sequence (then zeros)."""

    def __init__(self, deltas):
        self.deltas = list(deltas)
        self.calls = []

    def eval_sws(self, spun, slept, sws):
        self.calls.append((spun, slept, sws))
        return self.deltas.pop(0) if self.deltas else 0


# --------------------------------------------------------------------------
# Deterministic single-thread drives of the correction arithmetic
# --------------------------------------------------------------------------
def test_c1_grow_with_sleepers_banks_wakeups():
    """Grow by +2 while 3 phantom threads wait outside the window: C1 must
    schedule exactly 2 extra wake-ups (A27-A33), shipped at release on top
    of the R16-17 sleep->spin promotion."""
    lock = MutableLock(max_sws=8, initial_sws=1,
                       oracle=ScriptedOracle([+2]))
    lock.lstate.fetch_add(3)            # 3 phantom waiters (A4 x3)
    lock.slp_obj.wake_up(1)             # pre-bank a permit so A9 won't park
    lock.acquire()                      # thc 3 -> 4; slept=True, spun=False
    assert lock.sws == 3                # 1 + 2
    assert lock.thc == 4
    # A27-A28: thc(4) > sws_pre(1) -> tmp = 3; wuc += min(2, 3) = 2
    assert lock.wuc == 2

    sem_before = lock.slp_obj.wakes
    lock.release()
    # R3: r_wuc = 2; R16: thc_pre(4) > sws(3) -> +1 => 3 permits issued
    assert lock.wuc == 0
    assert lock.slp_obj.wakes - sem_before == 3


def test_c2_shrink_with_excess_spinners_suppresses_wakeups():
    """Shrink by -2 while 3 phantom spinners sit inside the window: C2 must
    bank 2 wake-up suppressions (A25-A26), and the next two releases must
    issue no wake-up at all (R6-R7, R11-R12)."""
    lock = MutableLock(max_sws=8, initial_sws=4,
                       oracle=ScriptedOracle([-2]))
    lock.lstate.fetch_add(3)            # 3 phantoms inside the window
    lock.acquire()                      # thc 3 -> 4 < sws=4: no sleep
    assert lock.sws == 2
    # A25-A26: thc(4) > sws_post(2) -> tmp = 2; wuc -= min(2, 2)
    assert lock.wuc == -2

    w0 = lock.slp_obj.wakes
    lock.release()                      # R7: wuc -2 -> -1; no wake-up
    assert lock.wuc == -1
    assert lock.slp_obj.wakes == w0     # suppressed

    # the next acquire lands outside the shrunken window (thc 3 >= sws 2):
    # pre-bank a permit so the phantom-backed sleep doesn't park for real
    lock.slp_obj.wake_up(1)
    lock.acquire()
    w1 = lock.slp_obj.wakes
    lock.release()                      # R7 again: wuc -1 -> 0; no wake-up
    assert lock.wuc == 0
    assert lock.slp_obj.wakes == w1     # second suppression

    # debt paid: the next release ships wake-ups again (R16 promotion)
    lock.slp_obj.wake_up(1)
    lock.acquire()
    w2 = lock.slp_obj.wakes
    lock.release()                      # r_wuc=0; thc_pre(4) > sws(2) -> +1
    assert lock.slp_obj.wakes == w2 + 1


def test_c2_clamp_never_drops_window_below_one():
    lock = MutableLock(max_sws=4, initial_sws=1,
                       oracle=ScriptedOracle([-3, -3]))
    lock.acquire()
    assert lock.sws == 1                # A16 clamp: delta -> 0
    assert lock.wuc == 0
    lock.release()


# --------------------------------------------------------------------------
# Real multi-thread drives
# --------------------------------------------------------------------------
def _run_workers(lock, n_threads, iters, cs=2e-5):
    counter = [0]

    def worker():
        for _ in range(iters):
            with lock:
                counter[0] += 1
                time.sleep(cs)          # releases the GIL
    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return counter[0]


@pytest.mark.parametrize("deltas", [[+2] * 4, [-1] * 6, [+3, -2] * 3])
def test_multithread_corrections_preserve_liveness(deltas):
    """Resizes with C1/C2 corrections while real threads sleep and spin:
    no thread may be lost (every wake-up owed is eventually delivered) and
    mutual exclusion must hold."""
    lock = MutableLock(max_sws=4, initial_sws=1,
                       oracle=ScriptedOracle(list(deltas)),
                       record_stats=True)
    done = _run_workers(lock, n_threads=6, iters=8)
    assert done == 48                   # no lost updates, no deadlock
    assert lock.thc == 0                # everyone checked out (A4/R9 paired)
    assert 1 <= lock.sws <= 4
    assert lock.stats.acquisitions == 48


def test_multithread_grow_with_sleepers_delivers_extra_wakeups():
    """With a window pinned small and then grown under load, the C1 path
    must deliver more wake-ups than sleeps would otherwise get: the grown
    window admits sleepers without waiting for one-release-one-wake."""
    lock = MutableLock(max_sws=6, initial_sws=1,
                       oracle=ScriptedOracle([0, 0, +4]),
                       record_stats=True)
    done = _run_workers(lock, n_threads=6, iters=10)
    assert done == 60
    assert lock.thc == 0
    assert lock.sws >= 5                # the scripted grow landed
    assert lock.slp_obj.sleeps > 0      # contention did park threads
    # every parked thread was eventually woken (conservation)
    assert lock.slp_obj.wakes >= lock.slp_obj.sleeps \
        - lock.slp_obj._sem._value


def test_multithread_adaptive_oracle_end_to_end():
    """The real EvalSWS under contention: acquisitions equal the work done
    and the window stays in bounds (sanity net for the paths above)."""
    lock = MutableLock(max_sws=4, oracle=EvalSWS(k=5), record_stats=True)
    done = _run_workers(lock, n_threads=5, iters=10)
    assert done == 50
    assert 1 <= lock.sws <= 4
    assert lock.stats.late_wakeups >= 0

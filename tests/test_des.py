"""DES validation of the paper's claims (Fig. 1 exact, Fig. 3 trends).

Slot units: CS duration == wake-up latency == 1.0 (the paper's Fig. 1
scenario: "critical section duration is equal to the time required by a
thread to be awaken and CPU-rescheduled"), 3 threads on 3 cores, one CS
each.
"""

import pytest

from repro.core.des import LockSim, simulate
from repro.core.oracle import FixedOracle


def _fig1(lock, **lock_kwargs):
    sim = LockSim(
        lock, threads=3, cores=3, cs=(1.0, 1.0), ncs=(0.0, 0.0),
        wake_latency=1.0, seed=1, record_timeline=True,
        max_cs_per_thread=1, lock_kwargs=dict(lock_kwargs, alpha=0.0),
    )
    return sim.run(target_cs=3)


# ---------------------------------------------------------------------------
# Fig. 1 — exact slot accounting
# ---------------------------------------------------------------------------
def test_fig1_spin_lock():
    """Fig. 1a: 3 slots for CSes + 3 slots of spinning (50% waste)."""
    r = _fig1("ttas")
    assert r.completed_cs == 3
    assert r.t_end == pytest.approx(3.0)
    assert r.spin_cpu == pytest.approx(3.0)
    assert r.wake_count == 0


def test_fig1_sleep_lock():
    """Fig. 1b: 5 slots for 3 CSes (40% throughput drop), 2 wake slots."""
    r = _fig1("sleep")
    assert r.completed_cs == 3
    assert r.t_end == pytest.approx(5.0)
    assert r.spin_cpu == pytest.approx(0.0)
    assert r.wake_count == 2
    # paper: "overall throughput is 40% worse than the spin lock"
    spin = _fig1("ttas")
    assert r.throughput / spin.throughput == pytest.approx(0.6)


def test_fig1_mutable_lock():
    """Fig. 1c: spin-lock latency (3 slots) with only 2 wasted slots
    (1 spin + 1 masked wake)."""
    r = _fig1("mutable", initial_sws=2, oracle=FixedOracle())
    assert r.completed_cs == 3
    assert r.t_end == pytest.approx(3.0)      # same latency as the spin lock
    assert r.spin_cpu == pytest.approx(1.0)   # one thread spun one slot
    assert r.wake_count == 1                  # wake masked by T2's CS


# ---------------------------------------------------------------------------
# Conservation / sanity across all disciplines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lock", ["tas", "ttas", "mcs", "sleep", "adaptive",
                                  "mutable"])
@pytest.mark.parametrize("threads", [1, 2, 8, 24])
def test_progress_and_conservation(lock, threads):
    r = simulate(lock, threads=threads, cores=8, target_cs=500, seed=3)
    assert r.completed_cs >= 500
    assert r.t_end > 0
    assert r.spin_cpu >= 0
    if lock in ("tas", "ttas", "mcs"):
        assert r.wake_count == 0


def test_mutable_sws_bounded_and_adaptive():
    r = simulate("mutable", threads=16, cores=8, cs=(0, 3.7e-6),
                 ncs=(0, 3.7e-6), wake_latency=5e-6, target_cs=3000, seed=7)
    assert r.sws_trace, "oracle never sampled"
    assert all(1 <= s <= 8 for _, s in r.sws_trace)
    # with wake latency > CS length the window must have grown beyond 1
    assert max(s for _, s in r.sws_trace) > 1


# ---------------------------------------------------------------------------
# Fig. 3 trends (paper's quantitative claims, DES with the paper's setup:
# 20 cores, wake-up latency ~5us)
# ---------------------------------------------------------------------------
THREADS = [2, 4, 8, 16, 24, 32, 40]
SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)


def _grid(lock, cs, ncs, target=1500):
    return {n: simulate(lock, threads=n, cores=20, cs=cs, ncs=ncs,
                        wake_latency=5e-6, target_cs=target, seed=11)
            for n in THREADS}


def _avg_ratio_to_optimal(grids, lock):
    """Right-hand column of Fig. 3: mean over thread counts of
    throughput(lock)/max_throughput(that thread count)."""
    ratios = []
    for n in THREADS:
        best = max(g[n].throughput for g in grids.values())
        ratios.append(grids[lock][n].throughput / best)
    return sum(ratios) / len(ratios)


@pytest.fixture(scope="module")
def short_short_grids():
    return {k: _grid(k, SHORT, SHORT) for k in
            ("ttas", "mcs", "sleep", "adaptive", "mutable")}


def test_fig3_short_cs_mutlock_beats_static_expectation(short_short_grids):
    """Paper Fig. 3c: MUTLOCK's average ratio-to-optimal exceeds PT-EXP
    (the expected value of an a-priori coin flip between spin and sleep)."""
    g = short_short_grids
    mut = _avg_ratio_to_optimal(g, "mutable")
    spin = _avg_ratio_to_optimal(g, "ttas")
    slp = _avg_ratio_to_optimal(g, "sleep")
    pt_exp = (spin + slp) / 2
    assert mut > pt_exp, f"mutable {mut:.3f} <= PT-EXP {pt_exp:.3f}"


def test_fig3_short_cs_spin_wins_without_timesharing(short_short_grids):
    """Paper Fig. 3a: spin locking is the best option with no time-sharing;
    sleep locks pay wake-up latency (-25% for PT-MUTEX at low counts)."""
    g = short_short_grids
    for n in (2, 4, 8, 16):
        assert g["ttas"][n].throughput >= 0.95 * g["sleep"][n].throughput


def test_fig3_short_cs_sleep_saves_cpu(short_short_grids):
    """Paper Fig. 3b: mutexes reduce sync CPU by ~an order of magnitude."""
    g = short_short_grids
    n = 40  # heavy oversubscription
    assert g["sleep"][n].spin_cpu < 0.2 * g["ttas"][n].spin_cpu


def test_fig3_long_cs_mutable_saves_cpu_order_of_magnitude():
    """Paper Fig. 3e: with long CSes and thread counts above 10, MUTLOCK
    spends ~10x less CPU in synchronization than spin locks."""
    mut = simulate("mutable", threads=16, cores=20, cs=LONG, ncs=SHORT,
                   wake_latency=5e-6, target_cs=800, seed=5)
    spin = simulate("ttas", threads=16, cores=20, cs=LONG, ncs=SHORT,
                    wake_latency=5e-6, target_cs=800, seed=5)
    assert mut.spin_cpu < 0.15 * spin.spin_cpu, (
        f"mutable sync CPU {mut.spin_cpu:.4f} not <<"
        f" spin {spin.spin_cpu:.4f}")


def test_fig3_long_cs_mutable_throughput_stable():
    """Paper Fig. 3d: pure spin degrades as threads grow (coherence
    pressure on the holder); MUTLOCK stays within a bounded loss."""
    mut = {n: simulate("mutable", threads=n, cores=20, cs=LONG, ncs=SHORT,
                       wake_latency=5e-6, target_cs=800, seed=5)
           for n in (4, 16)}
    spin = {n: simulate("ttas", threads=n, cores=20, cs=LONG, ncs=SHORT,
                        wake_latency=5e-6, target_cs=800, seed=5)
            for n in (4, 16)}
    spin_drop = spin[16].throughput / spin[4].throughput
    mut_drop = mut[16].throughput / mut[4].throughput
    assert mut_drop > spin_drop, (
        f"mutable should degrade less: {mut_drop:.3f} vs {spin_drop:.3f}")


def test_fig3_low_contention_all_equal():
    """Paper Fig. 3g: short CS + long NCS -> low contention -> all locks
    within ~15% of each other (<= core count threads)."""
    res = {k: simulate(k, threads=8, cores=20, cs=SHORT, ncs=LONG,
                       wake_latency=5e-6, target_cs=800, seed=9)
           for k in ("ttas", "sleep", "mutable")}
    best = max(r.throughput for r in res.values())
    for k, r in res.items():
        assert r.throughput > 0.85 * best, f"{k} off by >15% at low contention"

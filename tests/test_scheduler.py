"""Continuous-batching scheduler: completion, window dynamics, engine
integration (real tiny model + simulated engine)."""

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import base as cbase
from repro.configs.catalog import tiny
from repro.core.oracle import EvalSWS, FixedOracle
from repro.serve import (ContinuousBatcher, DecodeEngine, Request,
                         SimulatedEngine)


def _submit(bat, n, prompt_len=4, new_tokens=3):
    for i in range(n):
        bat.submit(Request(rid=i, prompt=[2] * prompt_len,
                           max_new_tokens=new_tokens))


def test_all_requests_complete_sim():
    bat = ContinuousBatcher(SimulatedEngine(max_slots=4), initial=1)
    _submit(bat, 37)
    stats = bat.run_until_drained()
    assert stats.completed == 37
    assert stats.handoffs == 37


def test_window_grows_under_load():
    eng = SimulatedEngine(max_slots=4)
    bat = ContinuousBatcher(eng, max_standby=16, initial=0,
                            oracle=EvalSWS(k=10))
    _submit(bat, 60, new_tokens=2)
    stats = bat.run_until_drained()
    assert stats.completed == 60
    # initial=0 clamps to the paper's sws>=1; load must grow it further
    assert max(stats.window_trace) > 1
    assert stats.late_handoffs < stats.handoffs  # some were masked


def test_static_zero_window_always_late():
    bat = ContinuousBatcher(SimulatedEngine(max_slots=2), initial=0,
                            oracle=FixedOracle())
    _submit(bat, 10, new_tokens=2)
    stats = bat.run_until_drained()
    assert stats.completed == 10
    # without standby, every handoff pays prefill in the open
    assert stats.late_handoffs == stats.handoffs


def test_real_engine_generates_consistent_tokens():
    """Scheduler output must equal a straight prefill+decode of the same
    prompt (greedy) — batching must not change results."""
    cfg = tiny(cbase.get_config("llama3.2-1b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    prompt = [5, 7, 11, 13]
    new_tokens = 5

    # reference: sequential greedy decode
    import jax.numpy as jnp
    logits, cache = models.prefill(cfg, params,
                                   {"tokens": jnp.asarray([prompt])})
    ref = [int(jnp.argmax(logits[0]))]
    # re-build cache at engine capacity to mirror the engine's state
    eng = DecodeEngine(cfg, params, max_slots=3, max_seq=32)
    bat = ContinuousBatcher(eng, initial=1)
    reqs = [Request(rid=i, prompt=list(prompt), max_new_tokens=new_tokens)
            for i in range(3)]
    for r in reqs:
        bat.submit(r)
    bat.run_until_drained(max_steps=200)
    for r in reqs:
        assert len(r.generated) >= new_tokens
        assert r.generated[0] == ref[0], (r.generated, ref)
    # identical prompts -> identical continuations across slots
    assert reqs[0].generated == reqs[1].generated == reqs[2].generated


def test_c1_correction_promotes_immediately():
    """When the oracle doubles the window, queued requests are prefilled
    right away (Algorithm 1 C1), not lazily."""
    eng = SimulatedEngine(max_slots=1, prefill_cost=1e-3)
    bat = ContinuousBatcher(eng, max_standby=8, initial=0,
                            oracle=EvalSWS(k=50))
    _submit(bat, 20, new_tokens=1)
    bat.run_step()                  # first handoff is late -> window doubles
    assert bat.window.sws >= 1
    assert len(bat.standby) >= 1    # C1 promoted a sleeper immediately

"""Property-based tests (hypothesis) for the paper's state-machine invariants.

Invariants checked:

* lstate packing is a bijection and FAD on packed fields never corrupts the
  sibling field (the paper's §3.2 single-word design).
* The clamped oracle keeps 1 <= sws <= max under any observation sequence
  (Algorithm 1 lines A16-A17).
* C1/C2 corrections never promote more items than exist outside the window
  and never demote more than the overflow (paper §3.1 conditions).
* The DES maintains conservation (every thread's CS count sums to the total)
  and mutual exclusion for arbitrary workload draws.
"""

import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dependency (requirements-dev.txt)")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import AtomicU64, pack_lstate, sws_delta, unpack_lstate
from repro.core.des import simulate
from repro.core.window import SpinningWindow

U32 = st.integers(min_value=0, max_value=2**32 - 1)


@given(U32, U32)
def test_pack_unpack_bijection(sws, thc):
    assert unpack_lstate(pack_lstate(sws, thc)) == (sws, thc)


@given(
    sws=st.integers(min_value=0, max_value=2**31),
    thc=st.integers(min_value=1, max_value=2**31),
    thc_deltas=st.lists(st.sampled_from([+1, -1]), max_size=32),
    sws_deltas=st.lists(st.integers(min_value=-64, max_value=64), max_size=32),
)
def test_fad_field_independence(sws, thc, thc_deltas, sws_deltas):
    """Interleaved FADs on the two fields never interfere, provided each
    field individually stays within u32 (the algorithm guarantees this:
    thc >= 0 always, 1 <= sws <= max)."""
    a = AtomicU64(pack_lstate(sws, thc))
    exp_sws, exp_thc = sws, thc
    ops = [(d, False) for d in thc_deltas] + [(d, True) for d in sws_deltas]
    for delta, is_sws in ops:
        if is_sws:
            if not (0 <= exp_sws + delta <= 2**32 - 1):
                continue
            a.fetch_add(sws_delta(delta))
            exp_sws += delta
        else:
            if not (0 <= exp_thc + delta <= 2**32 - 1):
                continue
            a.fetch_add(delta)
            exp_thc += delta
        assert unpack_lstate(a.load()) == (exp_sws, exp_thc)


@given(
    max_size=st.integers(min_value=1, max_value=64),
    initial=st.integers(min_value=1, max_value=64),
    events=st.lists(
        st.tuples(st.booleans(), st.integers(min_value=0, max_value=200)),
        max_size=200,
    ),
)
def test_window_bounds_and_corrections(max_size, initial, events):
    w = SpinningWindow(max_size=max_size, initial=initial)
    assert 1 <= w.sws <= max_size
    for late, occupancy in events:
        sws_pre = w.sws
        corr = w.observe(late_wake=late, occupancy=occupancy)
        # invariant: window always within [1, max]
        assert 1 <= w.sws <= max_size
        if corr > 0:   # C1: cannot promote more than the cold population
            assert corr <= max(0, occupancy - sws_pre)
            assert corr <= w.sws - sws_pre
        elif corr < 0:  # C2: cannot drain more than the hot overflow
            assert -corr <= max(0, occupancy - w.sws)
            assert -corr <= sws_pre - w.sws


@given(
    lock=st.sampled_from(["ttas", "sleep", "adaptive", "mutable", "mcs"]),
    threads=st.integers(min_value=1, max_value=12),
    cores=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**16),
    cs_hi=st.floats(min_value=1e-7, max_value=1e-4),
    ncs_hi=st.floats(min_value=1e-7, max_value=1e-4),
)
@settings(max_examples=40, deadline=None)
def test_des_conservation_and_progress(lock, threads, cores, seed, cs_hi,
                                       ncs_hi):
    r = simulate(lock, threads=threads, cores=cores, cs=(0.0, cs_hi),
                 ncs=(0.0, ncs_hi), wake_latency=5e-6, target_cs=200,
                 seed=seed)
    # progress: the DES reached the target without deadlock
    assert r.completed_cs >= 200
    # conservation: monotone time, non-negative CPU accounting
    assert r.t_end > 0 and r.spin_cpu >= 0
    # mutual exclusion is asserted inside the model (_enter_cs)


@given(st.integers(min_value=1, max_value=31))
def test_mutable_lock_single_thread_any_sws(sws):
    """Whatever the initial window, an uncontended lock acquires/releases
    and ends with thc == 0 (paper: thc counts waiters + holder)."""
    from repro.core import MutableLock

    m = MutableLock(max_sws=32, initial_sws=sws)
    for _ in range(3):
        with m:
            assert m.thc == 1
    assert m.thc == 0
    assert 1 <= m.sws <= 32

"""Property-based DES invariants (hypothesis): for ANY lock discipline,
thread count, core count, CS/NCS regime and seed —

  * progress: the target number of critical sections completes,
  * mutual exclusion: the model asserts a single holder internally,
  * conservation: completed CSes == sum of per-thread counts,
  * windows: the mutable model's sws stays within [1, max].
"""

import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dependency (requirements-dev.txt)")

from hypothesis import given, settings, strategies as st

from repro.core.des import LockSim, simulate

LOCKS = ["tas", "ttas", "mcs", "sleep", "adaptive", "mutable"]


@settings(max_examples=30, deadline=None)
@given(
    lock=st.sampled_from(LOCKS),
    threads=st.integers(1, 12),
    cores=st.integers(1, 24),
    cs_hi=st.floats(1e-7, 1e-4),
    ncs_hi=st.floats(1e-7, 1e-4),
    wake=st.floats(1e-7, 5e-5),
    seed=st.integers(0, 2**16),
)
def test_des_progress_and_conservation(lock, threads, cores, cs_hi, ncs_hi,
                                       wake, seed):
    sim = LockSim(lock, threads, cores, (0.0, cs_hi), (0.0, ncs_hi), wake,
                  seed=seed)
    res = sim.run(target_cs=60)
    assert res.completed_cs >= 60
    assert res.completed_cs == sum(t.cs_done for t in sim.tasks)
    assert res.t_end > 0
    assert res.spin_cpu >= 0.0


@settings(max_examples=20, deadline=None)
@given(
    threads=st.integers(2, 16),
    cores=st.integers(2, 24),
    initial=st.integers(1, 8),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**16),
)
def test_mutable_window_bounds(threads, cores, initial, k, seed):
    from repro.core.oracle import EvalSWS
    sim = LockSim("mutable", threads, cores, (0.0, 2e-6), (0.0, 2e-6), 5e-6,
                  seed=seed,
                  lock_kwargs={"initial_sws": min(initial, cores),
                               "oracle": EvalSWS(k=k)})
    res = sim.run(target_cs=150)
    assert res.completed_cs >= 150
    for _, sws in res.sws_trace:
        assert 1 <= sws <= sim.model.max


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), threads=st.integers(2, 10))
def test_mutable_thc_returns_to_idle(seed, threads):
    """After the run drains, the model's thread count is consistent: no
    phantom waiters (lost wake-ups would strand thc > 0 with idle tasks)."""
    sim = LockSim("mutable", threads, 8, (0.0, 3e-6), (0.0, 3e-6), 4e-6,
                  seed=seed, max_cs_per_thread=5)
    res = sim.run(target_cs=5 * threads)
    assert res.completed_cs == 5 * threads
    # every task retired; nobody left sleeping/waking/spinning
    from repro.core.des import DONE
    assert all(t.state == DONE for t in sim.tasks)
    assert sim.model.thc == 0

"""Correctness tests for the mutable lock (paper Algorithm 1) and baselines."""

import threading
import time

import pytest

from repro.core import (
    ALL_LOCKS,
    EvalSWS,
    FixedOracle,
    MutableLock,
    make_lock,
    pack_lstate,
    unpack_lstate,
)


# ---------------------------------------------------------------------------
# lstate packing
# ---------------------------------------------------------------------------
def test_lstate_pack_roundtrip():
    for sws, thc in [(1, 0), (7, 3), (2**31, 2**31), (2**32 - 1, 2**32 - 1)]:
        assert unpack_lstate(pack_lstate(sws, thc)) == (sws, thc)


def test_lstate_fad_fields_independent():
    from repro.core import AtomicU64, sws_delta

    a = AtomicU64(pack_lstate(3, 5))
    a.fetch_add(1)                      # thc += 1
    assert unpack_lstate(a.load()) == (3, 6)
    a.fetch_add(sws_delta(+3))          # sws += 3
    assert unpack_lstate(a.load()) == (6, 6)
    a.fetch_add(sws_delta(-5))          # sws -= 5
    assert unpack_lstate(a.load()) == (1, 6)
    a.fetch_add(-1)                     # thc -= 1
    assert unpack_lstate(a.load()) == (1, 5)


# ---------------------------------------------------------------------------
# mutual exclusion + progress for every lock kind
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", sorted(ALL_LOCKS))
def test_mutual_exclusion_and_counter(kind):
    lock = make_lock(kind)
    n_threads, n_iters = 8, 200
    counter = {"v": 0, "in_cs": 0, "max_in_cs": 0}

    def worker():
        for _ in range(n_iters):
            with lock:
                counter["in_cs"] += 1
                counter["max_in_cs"] = max(counter["max_in_cs"], counter["in_cs"])
                v = counter["v"]
                # widen the race window beyond a single bytecode
                time.sleep(0)
                counter["v"] = v + 1
                counter["in_cs"] -= 1

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), f"{kind}: worker hung (possible lost wakeup)"
    assert counter["v"] == n_threads * n_iters, f"{kind}: lost updates"
    assert counter["max_in_cs"] == 1, f"{kind}: mutual exclusion violated"


def test_mutable_lock_thc_returns_to_zero():
    lock = MutableLock(max_sws=4)
    done = []

    def worker():
        for _ in range(50):
            with lock:
                pass
        done.append(1)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert len(done) == 6
    assert lock.thc == 0
    assert 1 <= lock.sws <= lock.max


def test_release_by_non_holder_raises():
    lock = MutableLock()
    lock.acquire()
    err = []

    def bad_release():
        try:
            lock.release()
        except RuntimeError:
            err.append(1)

    t = threading.Thread(target=bad_release)
    t.start()
    t.join()
    assert err == [1]
    lock.release()


# ---------------------------------------------------------------------------
# spinning-window semantics
# ---------------------------------------------------------------------------
def test_sws_never_leaves_bounds_under_contention():
    lock = MutableLock(max_sws=3, initial_sws=1, record_stats=True)
    stop = threading.Event()

    def worker():
        while not stop.is_set():
            with lock:
                time.sleep(0)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive()
    assert all(1 <= s <= 3 for s in lock.stats.sws_samples)
    assert lock.stats.acquisitions > 0


def test_oracle_doubles_on_late_wake_and_decays():
    o = EvalSWS(k=3)
    # late wake-up: slept and did not spin -> delta == +sws (doubling)
    assert o.eval_sws(spun=False, slept=True, sws=4) == 4
    # three clean rounds -> shrink by 1
    assert o.eval_sws(spun=True, slept=False, sws=8) == 0
    assert o.eval_sws(spun=True, slept=False, sws=8) == 0
    assert o.eval_sws(spun=True, slept=False, sws=8) == -1


def test_fixed_oracle_keeps_sws_constant():
    lock = MutableLock(max_sws=4, initial_sws=2, oracle=FixedOracle())
    n_threads = 5
    barrier = threading.Barrier(n_threads)

    def worker():
        barrier.wait()
        for _ in range(100):
            with lock:
                pass

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert lock.sws == 2


def test_single_thread_fast_path():
    lock = MutableLock()
    for _ in range(1000):
        with lock:
            pass
    assert lock.thc == 0
    # single-thread: never slept, so the oracle can only have shrunk to 1
    assert lock.sws == 1

"""Self-healing sweeps and fault tolerance (docs/robustness.md): the
streaming engine's OOM chunk-halving, non-finite quarantine, and
chunk-granular checkpoint/resume (including a hard mid-sweep kill); the
heartbeat monitor against a genuinely stalled peer; and the checkpoint
manager's crash-safety contract (a save that dies mid-write leaves the
previous checkpoint restorable and LATEST never dangling)."""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import stream as xstream
from repro.core.policy import SimConfig, config_columns

LOCKS = ["ttas", "fifo", "sleep", "mutable", "adaptive", "mcs"]

#: Deterministic mixed batch shared — via exec — between this process and
#: the crash-resume subprocess, so both sides build the SAME sweep plan
#: (the resume fingerprint covers the encoded inputs bit for bit).
_BATCH_SRC = r"""
import numpy as np
from repro.core.policy import SimConfig

def res_batch(n=24, seed=42):
    locks = ["ttas", "fifo", "sleep", "mutable", "adaptive", "mcs"]
    rng = np.random.default_rng(seed)
    return [SimConfig(locks[i % 6], threads=int(rng.integers(2, 10)),
                      cores=int(rng.integers(2, 8)),
                      cs=(0.0, 3.7e-6), ncs=(0.0, 8e-6),
                      wake_latency=8e-6, seed=int(rng.integers(0, 1000)),
                      oracle=("paper", "aimd", "fixed")[i % 3])
            for i in range(n)]
"""
_ns: dict = {}
exec(_BATCH_SRC, _ns)
res_batch = _ns["res_batch"]


def _assert_summaries_equal(a, b, msg=""):
    for f in xstream.SUMMARY_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"{msg}:{f}")


# --------------------------------------------------------------------------
# OOM chunk-halving
# --------------------------------------------------------------------------
def test_oom_retries_with_halved_chunks_bit_identical(monkeypatch):
    """First device call dies with RESOURCE_EXHAUSTED: the chunk is
    split into two group-aligned halves, both complete, and the sweep's
    bits match an unfailed run."""
    cfgs = res_batch(24, seed=7)
    clean = xstream.sweep_stream(cfgs, n_steps=300, chunk=8, shard=False)

    real = xstream._run_chunk
    calls = {"n": 0, "oom": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            calls["oom"] += 1
            raise RuntimeError(
                "RESOURCE_EXHAUSTED: Out of memory while trying to "
                "allocate 1234567 bytes.")
        return real(*a, **kw)

    monkeypatch.setattr(xstream, "_run_chunk", flaky)
    with pytest.warns(UserWarning, match="halved"):
        s = xstream.sweep_stream(cfgs, n_steps=300, chunk=8, shard=False)
    assert calls["oom"] == 1
    assert calls["n"] >= 4          # 1 failed + 2 halves + later chunks
    _assert_summaries_equal(s, clean, "oom-halved")


def test_oom_at_quantum_floor_reraises(monkeypatch):
    """Halving bottoms out at one reduction/shard quantum: a persistent
    allocation failure eventually surfaces instead of looping."""
    cfgs = res_batch(8, seed=1)

    def always_oom(*a, **kw):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    monkeypatch.setattr(xstream, "_run_chunk", always_oom)
    with pytest.warns(UserWarning, match="halved"):
        with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
            xstream.sweep_stream(cfgs, n_steps=100, chunk=4, shard=False)


def test_non_oom_error_propagates_without_halving(monkeypatch):
    """Only allocation failures trigger the retry path — anything else
    is a real bug and must surface on the FIRST call."""
    cfgs = res_batch(8, seed=2)
    calls = {"n": 0}

    def broken(*a, **kw):
        calls["n"] += 1
        raise ValueError("wrong dtype")

    monkeypatch.setattr(xstream, "_run_chunk", broken)
    with pytest.raises(ValueError, match="wrong dtype"):
        xstream.sweep_stream(cfgs, n_steps=100, chunk=8, shard=False)
    assert calls["n"] == 1


# --------------------------------------------------------------------------
# Non-finite quarantine
# --------------------------------------------------------------------------
def test_quarantine_reports_and_sanitizes_wins(monkeypatch, tmp_path):
    """One poisoned config (NaN t_end): its raw value stays visible in
    the summary columns, a structured failure record (global index,
    offending fields, full config) lands in StreamResult.failures and
    the JSON report, and the win-count reduction sees a sanitized row so
    the poison cannot flip a phase-diagram cell."""
    cfgs = res_batch(16, seed=11)
    red = xstream.CellReduce(group=4,
                             cell_ids=np.asarray([0, 1, 0, 1], np.int32),
                             n_cells=2)
    clean = xstream.sweep_stream(cfgs, n_steps=250, chunk=8, shard=False,
                                 reduce=red)

    real = xstream._run_chunk
    state = {"n": 0}

    def poison(*a, **kw):
        state["n"] += 1
        out = {k: np.asarray(v).copy()
               for k, v in real(*a, **kw).items()}
        if state["n"] == 1:
            out["t_end"][1] = np.nan
        return out

    monkeypatch.setattr(xstream, "_run_chunk", poison)
    fpath = str(tmp_path / "sweep_failures.json")
    with pytest.warns(UserWarning, match="quarantined"):
        s = xstream.sweep_stream(cfgs, n_steps=250, chunk=8, shard=False,
                                 reduce=red, failures_path=fpath)

    # raw NaN kept in the summary column; every other row untouched
    assert np.isnan(s.t_end[1])
    mask = np.ones(16, bool)
    mask[1] = False
    np.testing.assert_array_equal(s.completed[mask], clean.completed[mask])
    np.testing.assert_array_equal(s.t_end[mask], clean.t_end[mask])

    # structured failure record, in memory and on disk
    assert len(s.failures) == 1
    rec = s.failures[0]
    assert rec["index"] == 1
    assert "t_end" in rec["fields"]
    assert rec["config"] and isinstance(rec["config"], dict)
    with open(fpath) as f:
        report = json.load(f)
    assert report["n_configs"] == 16 and report["n_failures"] == 1
    assert report["failures"][0]["index"] == 1

    # win reduction saw the sanitized row (throughput 0), not the NaN
    thr = s.completed.astype(np.float64) / np.where(
        np.isfinite(s.t_end), np.maximum(s.t_end, 1e-30), 1.0)
    thr[1] = 0.0
    expect = np.zeros((2, 4), np.int64)
    win = thr.reshape(4, 4).argmax(axis=1)
    for g in range(4):
        expect[red.cell_ids[g], win[g]] += 1
    np.testing.assert_array_equal(s.wins, expect)


# --------------------------------------------------------------------------
# Checkpoint / resume
# --------------------------------------------------------------------------
_CRASH_SCRIPT = r"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
from repro.core import stream as xstream
""" + _BATCH_SRC + r"""
real = xstream._run_chunk
calls = {"n": 0}

def dying(*a, **kw):
    calls["n"] += 1
    if calls["n"] == 3:
        os._exit(9)       # hard kill mid-sweep: no cleanup, no atexit
    return real(*a, **kw)

xstream._run_chunk = dying
red = xstream.CellReduce(group=6,
                         cell_ids=np.asarray([0, 1, 0, 1], np.int32),
                         n_cells=2)
xstream.sweep_stream(res_batch(), n_steps=300, chunk=6, shard=False,
                     reduce=red, checkpoint_dir=os.environ["CKPT_DIR"])
print("UNREACHABLE")
"""


def test_kill_mid_sweep_then_resume_bit_identical(tmp_path):
    """A subprocess sweep is hard-killed (os._exit) inside its third
    chunk; resuming from the checkpoint skips the two committed chunks
    and the final result — including the on-device win counts — is bit-
    identical to an uninterrupted run."""
    ckpt = str(tmp_path / "ck")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH")]))
    env["CKPT_DIR"] = ckpt
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CRASH_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 9, proc.stdout + proc.stderr
    assert "UNREACHABLE" not in proc.stdout
    assert os.path.exists(os.path.join(ckpt, "LATEST"))

    cfgs = res_batch()
    red = xstream.CellReduce(group=6,
                             cell_ids=np.asarray([0, 1, 0, 1], np.int32),
                             n_cells=2)
    clean = xstream.sweep_stream(cfgs, n_steps=300, chunk=6, shard=False,
                                 reduce=red)
    resumed = xstream.sweep_stream(cfgs, n_steps=300, chunk=6,
                                   shard=False, reduce=red,
                                   checkpoint_dir=ckpt, resume=True)
    assert resumed.resumed_chunks == 2 and resumed.n_chunks == 4
    _assert_summaries_equal(resumed, clean, "crash-resume")
    np.testing.assert_array_equal(resumed.wins, clean.wins)


def test_resume_from_complete_checkpoint_recomputes_nothing(monkeypatch,
                                                            tmp_path):
    """Resuming a sweep that already finished restores every chunk from
    disk: the device is never touched and the bits match."""
    cfgs = res_batch(24, seed=5)
    ckpt = str(tmp_path / "ck")
    plain = xstream.sweep_stream(cfgs, n_steps=250, chunk=8, shard=False)
    first = xstream.sweep_stream(cfgs, n_steps=250, chunk=8, shard=False,
                                 checkpoint_dir=ckpt)
    # checkpointing is observation-only: same bits as the plain run
    _assert_summaries_equal(first, plain, "ckpt-observer")

    def boom(*a, **kw):
        raise AssertionError("resume recomputed a committed chunk")

    monkeypatch.setattr(xstream, "_run_chunk", boom)
    res = xstream.sweep_stream(cfgs, n_steps=250, chunk=8, shard=False,
                               checkpoint_dir=ckpt, resume=True)
    assert res.resumed_chunks == res.n_chunks == 3
    _assert_summaries_equal(res, first, "full-resume")


def test_resume_refuses_foreign_checkpoint(tmp_path):
    """A checkpoint written by a DIFFERENT sweep plan (other configs or
    other chunking) must never silently resume into this one."""
    ckpt = str(tmp_path / "ck")
    xstream.sweep_stream(res_batch(16, seed=3), n_steps=200, chunk=8,
                         shard=False, checkpoint_dir=ckpt)
    with pytest.raises(ValueError, match="refusing to resume"):
        xstream.sweep_stream(res_batch(16, seed=4), n_steps=200, chunk=8,
                             shard=False, checkpoint_dir=ckpt,
                             resume=True)
    with pytest.raises(ValueError, match="refusing to resume"):
        xstream.sweep_stream(res_batch(16, seed=3), n_steps=200, chunk=4,
                             shard=False, checkpoint_dir=ckpt,
                             resume=True)


# --------------------------------------------------------------------------
# strict= escape hatch
# --------------------------------------------------------------------------
def test_sweep_stream_strict_false_clamps_columns():
    """Out-of-range sweep columns raise under the default strict
    validation; strict=False clamps them (arrival_rate -> 0 here, i.e.
    the closed-loop encoding) instead of killing a 100k-config sweep."""
    cols = config_columns(res_batch(8, seed=9))
    bad = {k: np.asarray(v).copy() for k, v in cols.items()}
    bad["arrival_rate"] = np.full(8, -3.0, np.float64)
    with pytest.raises(ValueError):
        xstream.sweep_stream(bad, n_steps=100, chunk=8, shard=False)
    s = xstream.sweep_stream(bad, n_steps=100, chunk=8, shard=False,
                             strict=False)
    good = {k: np.asarray(v).copy() for k, v in cols.items()}
    good["arrival_rate"] = np.zeros(8, np.float64)
    ref = xstream.sweep_stream(good, n_steps=100, chunk=8, shard=False)
    _assert_summaries_equal(s, ref, "strict-clamp")


# --------------------------------------------------------------------------
# Heartbeat: a genuinely stalled peer
# --------------------------------------------------------------------------
def test_straggler_monitor_flags_stalled_thread():
    """Four live worker threads; one silently stops beating after step 2.
    While its silence is shorter than dead_after_s it is a straggler
    (behind the median by > lag_steps); once the silence exceeds
    dead_after_s it is presumed dead and no longer blocks the barrier."""
    from repro.runtime.heartbeat import HeartbeatBoard, StragglerMonitor

    board = HeartbeatBoard(4)

    def worker(hid, stall_after):
        for step in range(1, 8):
            if stall_after is not None and step > stall_after:
                return
            board.beat(hid, step)
            time.sleep(0.01)

    threads = [threading.Thread(target=worker, args=(h, None))
               for h in range(3)]
    threads.append(threading.Thread(target=worker, args=(3, 2)))
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    mon = StragglerMonitor(board, dead_after_s=60.0, lag_steps=2)
    rep = mon.wait_for_step(7, timeout_s=0.5)
    assert rep.stragglers == [3]
    assert sorted(rep.ready) == [0, 1, 2]
    assert rep.failed == []

    # silence crosses dead_after_s: reclassified failed, barrier unblocks
    time.sleep(0.25)
    for h in range(3):
        board.beat(h, 8)
    mon2 = StragglerMonitor(board, dead_after_s=0.2, lag_steps=2)
    t0 = time.monotonic()
    rep2 = mon2.wait_for_step(8, timeout_s=5.0)
    assert time.monotonic() - t0 < 4.0      # did not ride the timeout
    assert rep2.failed == [3]
    assert sorted(rep2.ready) == [0, 1, 2]


# --------------------------------------------------------------------------
# Checkpoint manager crash-safety
# --------------------------------------------------------------------------
def test_checkpoint_crash_mid_save_keeps_previous(monkeypatch, tmp_path):
    """A save that dies mid-serialization (partial tmp dir on disk)
    leaves the previous checkpoint restorable and LATEST still pointing
    at it; the next successful save cleans the debris and commits."""
    from repro.checkpoint import manager as ckpt

    mgr = ckpt.CheckpointManager(str(tmp_path), keep_last=3,
                                 async_save=False)
    state1 = {"a": np.arange(8, dtype=np.int32),
              "b": np.full((), 1.5, np.float32)}
    mgr.save(1, state1)

    real_save = ckpt.save_pytree

    def die_mid_write(tree, out_dir):
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "leaf_000000.npy"), "wb") as f:
            f.write(b"partial garbage")     # torn write, no manifest
        raise RuntimeError("killed mid-serialization")

    monkeypatch.setattr(ckpt, "save_pytree", die_mid_write)
    state2 = {"a": np.arange(8, dtype=np.int32) * 2,
              "b": np.full((), 2.5, np.float32)}
    with pytest.raises(RuntimeError, match="mid-serialization"):
        mgr.save(2, state2)

    # LATEST never dangles: still the last COMMITTED step, restorable
    assert mgr.latest_step() == 1
    tmp_debris = os.path.join(str(tmp_path), "step_00000002.tmp")
    assert os.path.exists(tmp_debris)       # the torn save, uncommitted
    template = {"a": np.zeros(8, np.int32), "b": np.zeros((), np.float32)}
    step, tree = mgr.restore(template)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["a"]), state1["a"])
    assert float(tree["b"]) == 1.5

    # recovery: the retried save clears the debris and commits atomically
    monkeypatch.setattr(ckpt, "save_pytree", real_save)
    mgr.save(2, state2)
    assert mgr.latest_step() == 2
    assert not os.path.exists(tmp_debris)
    step, tree = mgr.restore(template)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["a"]), state2["a"])

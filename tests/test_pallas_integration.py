"""REPRO_USE_PALLAS=1 routes model attention through the Pallas flash
kernel (interpret mode on CPU) and must match the XLA path."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
import numpy as np
import jax, jax.numpy as jnp
from repro import models
from repro.configs import base as cbase
from repro.configs.catalog import tiny
from repro.configs.inputs import concrete_batch

cfg = tiny(cbase.get_config("llama3.2-1b"))
# seq > _Q_CHUNK not needed; kernel path takes over whenever enabled
params = models.init_params(cfg, jax.random.PRNGKey(0))
batch = concrete_batch(cfg, 2, 64, jax.random.PRNGKey(1))

os.environ.pop("REPRO_USE_PALLAS", None)
loss_x, _ = models.loss_fn(cfg, params, batch)

os.environ["REPRO_USE_PALLAS"] = "1"
loss_p, _ = models.loss_fn(cfg, params, batch)

print("XLA", float(loss_x), "PALLAS", float(loss_p))
np.testing.assert_allclose(float(loss_x), float(loss_p), rtol=2e-2,
                           atol=2e-2)
print("PALLAS_PATH_OK")
"""


@pytest.mark.slow
def test_pallas_model_path_matches_xla():
    env = dict(os.environ, PYTHONPATH="src", PYTHONUNBUFFERED="1")
    out = subprocess.run([sys.executable, "-u", "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PALLAS_PATH_OK" in out.stdout

"""Runtime layer: heartbeat/straggler detection, elastic re-mesh plans,
hot-spare window adaptation, checkpoint manager, data pipeline."""

import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.oracle import FixedOracle
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.runtime import (ElasticMesh, HeartbeatBoard, HotSparePool,
                           StragglerMonitor)


# --------------------------------------------------------------------------
# heartbeat / straggler
# --------------------------------------------------------------------------
def test_heartbeat_all_ready():
    board = HeartbeatBoard(4)
    mon = StragglerMonitor(board, dead_after_s=5.0)
    for h in range(4):
        board.beat(h, 7)
    rep = mon.wait_for_step(7, timeout_s=1.0)
    assert sorted(rep.ready) == [0, 1, 2, 3]
    assert not rep.failed and not rep.stragglers


def test_heartbeat_detects_straggler_and_failure():
    board = HeartbeatBoard(4)
    mon = StragglerMonitor(board, dead_after_s=0.2, lag_steps=2)
    for h in (0, 1):
        board.beat(h, 10)
    stop = threading.Event()

    def slow_host():                            # alive, stuck at step 4
        while not stop.is_set():
            board.beat(2, 4)
            time.sleep(0.02)

    t = threading.Thread(target=slow_host)
    t.start()
    try:
        rep = mon.wait_for_step(10, timeout_s=0.5)   # host 3 never beats
    finally:
        stop.set()
        t.join()
    assert 3 in rep.failed                     # silent host presumed dead
    assert 2 in rep.stragglers                 # alive but behind the median


def test_heartbeat_concurrent_beats():
    board = HeartbeatBoard(8)

    def beat(h):
        for s in range(50):
            board.beat(h, s)

    ts = [threading.Thread(target=beat, args=(h,)) for h in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = board.snapshot()
    assert all(p.last_step == 49 for p in snap.values())


# --------------------------------------------------------------------------
# elastic re-mesh
# --------------------------------------------------------------------------
def test_elastic_plan_full_and_degraded():
    em = ElasticMesh(chips_per_host=4, model_axis=16, global_batch=256)
    full = em.plan(64)                      # 64 hosts * 4 = 256 chips
    assert full.shape == (16, 16)
    assert full.hosts_idle == 0
    # lose 3 hosts -> 61 hosts = 244 chips -> data axis 15 doesn't divide
    # 256; largest divisor of 256 that fits is 8
    degraded = em.plan(61)
    assert degraded.model == 16
    assert degraded.data == 8 and 256 % degraded.data == 0
    assert degraded.hosts_used <= 61
    # grad accum keeps the global batch
    assert em.accum_for(degraded) == 2


def test_elastic_too_few_hosts_raises():
    em = ElasticMesh(chips_per_host=4, model_axis=16)
    with pytest.raises(ValueError):
        em.plan(2)


def test_elastic_restore_across_meshes(tmp_path):
    """The same checkpoint restores into a template with different
    (simulated) sharding — leaf shapes are mesh-independent."""
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
              "b": jnp.ones((8,), jnp.bfloat16)}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, params)
    step, restored = mgr.restore(params)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(params["w"]))


# --------------------------------------------------------------------------
# hot-spare pool (the paper's window over standby hosts)
# --------------------------------------------------------------------------
def test_hot_spares_mask_failures_and_adapt():
    pool = HotSparePool(max_spares=8, initial=1, hot_spinup_s=30,
                        cold_spinup_s=600)
    # first failure is masked by the single hot spare
    assert pool.on_failure() == 30
    # second failure finds the pool empty -> exposed (late wake) -> window
    # doubles
    before = pool.window.sws
    assert pool.on_failure() == 600
    assert pool.window.sws >= min(8, 2 * before)
    # spares warm up; subsequent failures are masked again
    pool.on_spare_ready(pool.cold_queue)
    assert pool.on_failure() == 30
    st = pool.stats
    assert st.failures == 3 and st.exposed == 1 and st.masked == 2


def test_hot_spares_shrink_when_quiet():
    pool = HotSparePool(max_spares=8, initial=4)
    pool.on_spare_ready(8)
    # many cleanly-masked failures -> K-rule shrinks the window
    for _ in range(25):
        pool.on_spare_ready(8)
        pool.on_failure()
    assert pool.window.sws < 4


def test_hot_spares_static_zero_always_exposed():
    pool = HotSparePool(max_spares=8, initial=0, oracle=FixedOracle())
    for _ in range(3):
        assert pool.on_failure() == 600
    assert pool.stats.exposed == 3


# --------------------------------------------------------------------------
# data pipeline determinism + self-tuning depth
# --------------------------------------------------------------------------
def test_corpus_sharding_partition():
    """Host shards partition the global batch: different hosts, different
    rows; same host, identical stream across runs."""
    d0 = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                    host_count=2, host_id=0)
    d1 = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                    host_count=2, host_id=1)
    b0 = SyntheticCorpus(d0).batch_at(3)
    b1 = SyntheticCorpus(d1).batch_at(3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    again = SyntheticCorpus(d0).batch_at(3)
    np.testing.assert_array_equal(b0["tokens"], again["tokens"])


def test_prefetch_loader_under_slow_producer():
    corpus = SyntheticCorpus(DataConfig(vocab_size=50, seq_len=4,
                                        global_batch=2))
    loader = PrefetchLoader(corpus, workers=1, produce_cost_s=2e-3,
                            initial_depth=1, max_depth=8)
    for i in range(12):
        b = loader.get()
        assert b["tokens"].shape == (2, 4)
    # consumer outpaced the producer at depth 1 -> the window must have grown
    assert loader.window.sws >= 1
    assert loader.stats["gets"] == 12
    loader.close()

"""Oracle-family parity: the threaded Oracle classes (repro.core.oracle),
the vectorized policy rows (repro.core.policy.ORACLE_ROWS / oracle_update),
the standalone oracle kernels (repro.kernels), and the batched simulator's
per-config dispatch must all implement the SAME update rules —
bit-identically, since the phase-diagram report compares families across
backends."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import policy as P
from repro.core.oracle import (AIMDOracle, EvalSWS, FixedBudgetOracle,
                               HistoryOracle, make_oracle)
from repro.core.policy import SimConfig

FAMILIES = sorted(P.ORACLE_IDS)


# --------------------------------------------------------------------------
# Threaded class vs vectorized row
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", FAMILIES)
@pytest.mark.parametrize("k", [1, 3, 10])
def test_threaded_oracle_matches_vectorized_row(name, k):
    """N independent randomized (spun, slept) streams: stepping N threaded
    oracles one by one must equal one jnp array step over the batch —
    identical (delta, cnt, ewma) trajectories, every step."""
    N, steps = 8, 250
    rng = np.random.default_rng(hash((name, k)) % 2**32)
    spun_seq = rng.integers(0, 2, (steps, N)).astype(np.int32)
    slept_seq = rng.integers(0, 2, (steps, N)).astype(np.int32)

    oid = P.ORACLE_IDS[name]
    threaded = [make_oracle(name, k=k) for _ in range(N)]
    sws_t = [1] * N

    oid_v = jnp.full((N,), oid, jnp.int32)
    sws_v = jnp.ones((N,), jnp.int32)
    cnt_v = jnp.zeros((N,), jnp.int32)
    ewma_v = jnp.zeros((N,), jnp.int32)
    k_v = jnp.full((N,), k, jnp.int32)

    for t in range(steps):
        deltas = [o.eval_sws(bool(spun_seq[t, i]), bool(slept_seq[t, i]),
                             sws_t[i]) for i, o in enumerate(threaded)]
        dv, cnt_v, ewma_v = P.oracle_update(
            oid_v, jnp.asarray(spun_seq[t]), jnp.asarray(slept_seq[t]),
            sws_v, cnt_v, ewma_v, k_v)
        assert np.asarray(dv).tolist() == deltas, (name, t)
        assert np.asarray(cnt_v).tolist() == [o.cnt for o in threaded]
        assert np.asarray(ewma_v).tolist() == [o.ewma for o in threaded]
        # both sides apply the same A16-A17 clamp (max window 16)
        sws_t = [sws + P.clamp_delta(sws, d, 1, 16)
                 for sws, d in zip(sws_t, deltas)]
        dv = jnp.clip(dv, 1 - sws_v, 16 - sws_v)
        sws_v = sws_v + dv
        assert np.asarray(sws_v).tolist() == sws_t


def test_row_functions_match_scalar_reference():
    """The branch-free EvalSWS row equals the readable scalar reference
    (eval_sws_delta) on its full small-state space."""
    for spun in (0, 1):
        for slept in (0, 1):
            for sws in (1, 2, 7):
                for cnt in range(0, 12):
                    for k in (1, 5, 10):
                        want = P.eval_sws_delta(bool(spun), bool(slept),
                                                sws, cnt, k)
                        d, c, e = P.oracle_evalsws_row(spun, slept, sws,
                                                       cnt, 0, k)
                        assert (d, c) == want
                        assert e == 0


def test_family_semantics():
    # paper: doubling on a late wake, -1 after k clean
    o = EvalSWS(k=3)
    assert o.eval_sws(spun=False, slept=True, sws=4) == 4
    assert [o.eval_sws(True, False, 4) for _ in range(3)] == [0, 0, -1]
    # aimd: +1 on late wake, halve after k clean
    a = AIMDOracle(k=2)
    assert a.eval_sws(spun=False, slept=True, sws=8) == 1
    assert [a.eval_sws(True, False, 8) for _ in range(2)] == [0, -4]
    # fixed: always drives the window to the budget
    f = FixedBudgetOracle(k=6)
    assert f.eval_sws(True, False, 1) == 5
    assert f.eval_sws(False, True, 10) == -4
    # history: EWMA ramps up under sustained late wakes, decays when clean
    h = HistoryOracle(k=10)
    deltas = [h.eval_sws(spun=False, slept=True, sws=2) for _ in range(4)]
    assert h.ewma > 2 * (P.EWMA_ONE // 11)
    assert any(d > 0 for d in deltas)
    for _ in range(40):
        h.eval_sws(spun=True, slept=False, sws=8)
    assert h.ewma < P.EWMA_ONE // 11 // 2 + 1
    assert h.eval_sws(spun=True, slept=False, sws=8) == -1


# --------------------------------------------------------------------------
# Standalone oracle kernel (Pallas) vs XLA ref vs scalar rows
# --------------------------------------------------------------------------
def test_oracle_kernel_matches_ref_and_rows():
    from repro.kernels.lock_sim import oracle_step
    from repro.kernels.ref import oracle_update_ref

    rng = np.random.default_rng(7)
    C = 203                               # non-multiple of the block size
    oid = rng.integers(0, 4, C).astype(np.int32)
    spun = rng.integers(0, 2, C).astype(np.int32)
    slept = rng.integers(0, 2, C).astype(np.int32)
    sws = rng.integers(1, 33, C).astype(np.int32)
    cnt = rng.integers(0, 12, C).astype(np.int32)
    ewma = rng.integers(0, P.EWMA_ONE + 1, C).astype(np.int32)
    k = rng.integers(1, 31, C).astype(np.int32)
    smax = rng.integers(1, 33, C).astype(np.int32)

    d_ref, c_ref, e_ref = oracle_update_ref(oid, spun, slept, sws, cnt,
                                            ewma, k, smax)
    d_pal, c_pal, e_pal = oracle_step(oid, spun, slept, sws, cnt, ewma,
                                      k, smax, block_configs=64)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_pal))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
    np.testing.assert_array_equal(np.asarray(e_ref), np.asarray(e_pal))

    for i in range(C):
        d, c, e = P.ORACLE_ROWS[oid[i]](int(spun[i]), int(slept[i]),
                                        int(sws[i]), int(cnt[i]),
                                        int(ewma[i]), int(k[i]))
        d = P.clamp_delta(int(sws[i]), int(d), 1, int(smax[i]))
        assert (d, c, e) == (int(d_ref[i]), int(c_ref[i]), int(e_ref[i]))


# --------------------------------------------------------------------------
# Batched simulator: per-config oracle dispatch, backend bit-identity
# --------------------------------------------------------------------------
def _oracle_cfgs():
    return [SimConfig("mutable", threads=6, cores=6, cs=(0.0, 3.7e-6),
                      ncs=(0.0, 3.7e-6), wake_latency=8e-6,
                      oracle=o, k=k)
            for o in FAMILIES for k in (3, 10)]


def test_pallas_backend_bit_identical_on_oracle_rows():
    from repro.core import xdes

    cfgs = _oracle_cfgs()
    r_ref = xdes.simulate_batch(cfgs, n_steps=300, backend="ref")
    r_pal = xdes.simulate_batch(cfgs, n_steps=300, backend="pallas")
    np.testing.assert_array_equal(r_ref.completed, r_pal.completed)
    np.testing.assert_array_equal(r_ref.final_sws, r_pal.final_sws)
    np.testing.assert_array_equal(r_ref.wake_count, r_pal.wake_count)
    np.testing.assert_allclose(r_ref.spin_cpu, r_pal.spin_cpu, rtol=1e-5)


def test_fixed_oracle_pins_window_at_budget():
    from repro.core import xdes

    cfgs = [SimConfig("mutable", threads=8, cores=8, cs=(0.0, 3.7e-6),
                      ncs=(0.0, 3.7e-6), oracle="fixed", k=k,
                      sws_max=m)
            for k in (2, 5, 30) for m in (None, 4)]
    res = xdes.simulate_batch(cfgs, n_steps=400)
    want = [min(k, m if m else 8) for k in (2, 5, 30) for m in (None, 4)]
    assert res.final_sws.tolist() == want


def test_oracle_families_all_make_progress():
    from repro.core import xdes

    res = xdes.simulate_batch(_oracle_cfgs(), target_cs=80)
    assert (res.completed >= 60).all(), res.completed
    assert (res.final_sws >= 1).all() and (res.final_sws <= 6).all()


# --------------------------------------------------------------------------
# Config plumbing
# --------------------------------------------------------------------------
def test_sim_config_oracle_encoding():
    cfgs = [SimConfig("mutable", threads=2, cores=2, cs=(0, 1e-6),
                      ncs=(0, 1e-6), oracle=o) for o in FAMILIES]
    arrs = P.encode_configs(cfgs)
    assert arrs["oracle"].tolist() == [P.ORACLE_IDS[o] for o in FAMILIES]
    with pytest.raises(ValueError):
        SimConfig("mutable", threads=2, cores=2, cs=(0, 1e-6),
                  ncs=(0, 1e-6), oracle="nope")


def test_des_kwargs_builds_matching_threaded_oracle():
    cfg = SimConfig("mutable", threads=4, cores=4, cs=(0, 1e-6),
                    ncs=(0, 1e-6), oracle="aimd", k=7)
    kw = cfg.des_kwargs()
    assert isinstance(kw["oracle"], AIMDOracle)
    assert kw["oracle"].k == 7


def test_oracle_grid_catalog_shape():
    from repro.configs.catalog import (lock_oracle_sweep,
                                       lock_oracle_variants)

    variants = lock_oracle_variants()
    cfgs = lock_oracle_sweep(n_scenarios=5)
    assert len(cfgs) == 5 * len(variants)
    # scenario-major, variant-minor: every variant block shares its machine
    V = len(variants)
    for s in range(5):
        block = cfgs[s * V:(s + 1) * V]
        assert len({(c.threads, c.cores, c.cs, c.wake_latency)
                    for c in block}) == 1
        assert [(c.oracle, c.k, c.sws_max) for c in block] \
            == [(v["oracle"], v["k"], v["sws_max"]) for v in variants]

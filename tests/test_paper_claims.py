"""The paper's claims, asserted (EXPERIMENTS.md §Paper-claims).

C1 (Fig 1): 3 threads, CS == wake latency: spin needs ~3 slots for 3 CSes,
    sleep ~5 (-40% throughput), mutable ~3 with sleep-level waste.
C2 (Fig 3a): short CS/NCS — MUTLOCK within ~12% of the best and above
    PT-EXP (the blind static choice).
C3 (Fig 3d/e): long CS — MUTLOCK cuts spin CPU by >=5x vs TTAS at 20
    threads while staying within ~15% of the optimum.
C4 (Fig 3g): low contention — all locks within ~10% of each other.
C5 (oracle): sws doubles after a late wake-up and decays by 1 after K
    clean acquisitions (Algorithm 1 E4-E9).
C6 (serving window): the adapted technique reaches window=max latency at
    materially lower standby cost than window=max.
"""

import pytest

from repro.core.des import simulate
from repro.core.oracle import EvalSWS


UNIT = 10e-6


def _fig1(lock, **kw):
    return simulate(lock, threads=3, cores=3, cs=(UNIT, UNIT),
                    ncs=(1e-9, 1e-9), wake_latency=UNIT, target_cs=3,
                    seed=1, max_cs_per_thread=1, lock_kwargs=kw)


def test_c1_fig1_timelines():
    spin = _fig1("ttas")
    sleep = _fig1("sleep")
    mut = _fig1("mutable", initial_sws=2)
    slots = lambda r: r.t_end / UNIT
    assert slots(spin) < 3.5, slots(spin)
    assert 4.5 < slots(sleep) < 5.5, slots(sleep)          # paper: 5 slots
    assert slots(mut) < 3.5, slots(mut)                    # spin-level latency
    # mutable wastes ~2 slots (1 spin + 1 wake) vs spin's ~3 spin slots
    assert mut.spin_cpu / UNIT < spin.spin_cpu / UNIT
    assert mut.wake_count <= sleep.wake_count


def _fig3_cell(lock, threads, cs, ncs, seed=0):
    return simulate(lock, threads=threads, cores=20, cs=cs, ncs=ncs,
                    wake_latency=8e-6, target_cs=1200, seed=seed)


SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)


def test_c2_short_cs_mutable_beats_static_expectation():
    tcs = [4, 8, 16, 20, 26]
    avg = {}
    for lock in ("ttas", "sleep", "mutable"):
        avg[lock] = sum(_fig3_cell(lock, t, SHORT, SHORT).throughput
                        for t in tcs) / len(tcs)
    pt_exp = 0.5 * (avg["ttas"] + avg["sleep"])
    assert avg["mutable"] > pt_exp, (avg, pt_exp)
    assert avg["mutable"] > 0.85 * max(avg.values())


def test_c3_long_cs_cpu_savings():
    r_spin = _fig3_cell("ttas", 20, LONG, SHORT)
    r_mut = _fig3_cell("mutable", 20, LONG, SHORT)
    r_sleep = _fig3_cell("sleep", 20, LONG, SHORT)
    assert r_mut.sync_cpu_per_cs < r_spin.sync_cpu_per_cs / 5
    best = max(r.throughput for r in (r_spin, r_mut, r_sleep))
    assert r_mut.throughput > 0.85 * best


def test_c4_low_contention_parity():
    thr = {lock: _fig3_cell(lock, 8, SHORT, LONG).throughput
           for lock in ("ttas", "sleep", "adaptive", "mutable")}
    best = max(thr.values())
    assert all(v > 0.9 * best for v in thr.values()), thr


def test_c5_oracle_rules():
    o = EvalSWS(k=3)
    # late wake-up (slept and not spun) -> delta = +sws (doubling)
    assert o.eval_sws(spun=False, slept=True, sws=4) == 4
    # K clean acquisitions -> -1
    assert o.eval_sws(spun=True, slept=False, sws=8) == 0
    assert o.eval_sws(spun=True, slept=False, sws=8) == 0
    assert o.eval_sws(spun=True, slept=False, sws=8) == -1
    # counter resets after a shrink
    assert o.eval_sws(spun=True, slept=False, sws=7) == 0


def test_c6_serving_window_tradeoff():
    from benchmarks.sched_bench import run_policy
    zero = run_policy("zero", n_requests=250)
    mx = run_policy("max", n_requests=250)
    mut = run_policy("mutable", n_requests=250)
    # mutable reaches (or beats) max-policy responsiveness...
    assert mut["late_handoff_rate"] <= mx["late_handoff_rate"] * 1.1
    assert mut["late_handoff_rate"] < zero["late_handoff_rate"]
    # ...while holding less standby KV than always-max
    assert mut["avg_standby"] < mx["avg_standby"]

"""Fault/interference-row registry: registry + encoding semantics,
engine invariants (the benign row is bit-identical to the pre-fault
engine, wake faults never touch pure spinners, full-rate preemption
yields exactly zero completions), seed-averaged xdes-vs-DES parity per
fault row, ref-vs-Pallas bit-identity on the fault-aware kernel body,
the spin-vs-sleep ranking flip under lock-holder preemption, and the
fault sweep / serve-scenario plumbing (see docs/robustness.md)."""

import numpy as np
import pytest

from repro.core import policy as P
from repro.core import xdes
from repro.core.des import simulate
from repro.core.policy import SimConfig

FAULTS = ["none", "preempt", "oversub", "lostwake", "jitter"]
#: The many-windows parity recipe (docs/robustness.md): µs-scale holds
#: with a 10 µs fault window, so every horizon samples dozens of
#: windows — the regime where the engine's step-indexed draws and the
#: DES's event-time draws agree distributionally.
CS = (1e-6, 2e-6)
NCS = (2e-6, 4e-6)
WAKE = 5e-6
SCALE = 1e-5
RATES = {"none": 0.0, "preempt": 0.6, "oversub": 0.6,
         "lostwake": 0.5, "jitter": 0.5}


def _mk(lock, fault, seed, rate=None, **kw):
    kw.setdefault("threads", 8)
    kw.setdefault("cores", 4)
    return SimConfig(lock, cs=CS, ncs=NCS, wake_latency=WAKE, seed=seed,
                     fault=fault,
                     fault_rate=RATES[fault] if rate is None else rate,
                     fault_scale=SCALE, **kw)


# --------------------------------------------------------------------------
# Registry + encoding
# --------------------------------------------------------------------------
def test_fault_registry():
    assert sorted(P.FAULT_IDS) == sorted(FAULTS)
    assert all(P.FAULT_ROWS[n].fid == i for n, i in P.FAULT_IDS.items())
    assert P.FAULT_IDS["none"] == P.FAULT_NONE == 0
    # salts are pairwise distinct from the workload/arrival/tie-break ones
    salts = (P.FLT_GATE_SALT, P.FLT_WAKE_SALT, P.FLT_MAG_SALT,
             P.WL_PHASE_SALT, P.WL_SPREAD_SALT, P.AR_SALT, P.TB_SALT)
    assert len(set(salts)) == len(salts)


def test_fault_progress_scalar_semantics():
    # none: exactly 1.0 whatever the draws
    assert P.fault_progress_scale(P.FAULT_NONE, 1.0, 0.1, 0.9) == 1.0
    # preempt: the whole window is lost iff the gate fires
    assert P.fault_progress_scale(P.FAULT_PREEMPT, 1.0, 0.3, 0.6) == 0.0
    assert P.fault_progress_scale(P.FAULT_PREEMPT, 1.0, 0.9, 0.6) == 1.0
    # oversub: fractional slowdown, never a blackout
    assert P.fault_progress_scale(P.FAULT_OVERSUB, 0.0, 0.5, 0.6) \
        == pytest.approx(0.7)
    # wake-path rows leave progress untouched
    for fid in (P.FAULT_LOSTWAKE, P.FAULT_JITTER):
        assert P.fault_progress_scale(fid, 1.0, 0.01, 0.9) == 1.0


def test_fault_wake_delay_scalar_semantics():
    wake, scale = 5e-6, 1e-4
    # progress rows leave the wake latency bit-identical
    for fid in (P.FAULT_NONE, P.FAULT_PREEMPT, P.FAULT_OVERSUB):
        assert P.fault_wake_delay(fid, wake, 0.01, 0.7, 0.9, scale) == wake
    # lostwake: a dropped wake recovers exactly at the timeout
    assert P.fault_wake_delay(P.FAULT_LOSTWAKE, wake, 0.3, 0.7, 0.5,
                              scale) == scale
    assert P.fault_wake_delay(P.FAULT_LOSTWAKE, wake, 0.9, 0.7, 0.5,
                              scale) == wake
    # jitter: up to `scale` extra, magnitude from the second draw
    assert P.fault_wake_delay(P.FAULT_JITTER, wake, 0.3, 0.5, 0.5,
                              scale) == pytest.approx(wake + 0.5 * scale)
    assert P.fault_wake_delay(P.FAULT_JITTER, wake, 0.9, 0.5, 0.5,
                              scale) == wake


def test_sim_config_validates_and_encodes_fault():
    cfgs = [_mk("mutable", f, seed=0) for f in FAULTS]
    arrs = P.encode_configs(cfgs)
    assert arrs["fault"].tolist() == [P.FAULT_IDS[f] for f in FAULTS]
    assert arrs["flt_rate"].tolist() == pytest.approx(
        [np.float32(RATES[f]) for f in FAULTS])
    assert arrs["flt_scale"].tolist() == [np.float32(SCALE)] * len(FAULTS)
    with pytest.raises(ValueError):
        _mk("mutable", "meteor", seed=0, rate=0.5)
    with pytest.raises(ValueError):
        _mk("mutable", "preempt", seed=0, rate=1.5)
    with pytest.raises(ValueError):
        SimConfig("mutable", threads=2, cores=2, cs=CS, ncs=CS,
                  fault="jitter", fault_rate=0.5, fault_scale=0.0)


def _raw_columns(n=3, lock="ttas"):
    """Full RAW column dict for ``n`` benign configs (the interchange
    form the catalog generators emit)."""
    return P.config_columns([
        SimConfig(lock, threads=4, cores=4, cs=CS, ncs=CS, seed=s)
        for s in range(n)])


def test_encode_columns_fault_strict_and_clamp():
    base = _raw_columns()
    # fault ids and rates always raise, named by row — never clamped
    with pytest.raises(ValueError, match="row 1.*fault id"):
        P.encode_columns({**base, "fault": np.asarray([0, 9, 0])})
    with pytest.raises(ValueError, match="fault_rate"):
        P.encode_columns({**base, "fault": 1,
                          "fault_rate": np.asarray([0.5, 0.5, 2.0])},
                         strict=False)
    # the strict=False escape hatch still clamps the continuous sweep
    # knobs on a faulted grid (mechanically generated edge cells survive)
    out = P.encode_columns({**base, "fault": "oversub", "fault_rate": 0.5,
                            "arrival_rate": np.asarray([-1.0, 0.0, 5.0]),
                            "wl_duty": np.asarray([0.0, 0.5, 1.0])},
                           strict=False)
    assert out["arr_rate"].min() >= 0.0
    assert out["wl_duty"].max() <= 1.0    # clamped through validation
    with pytest.raises(ValueError, match="arrival_rate"):
        P.encode_columns({**base, "arrival_rate": -1.0})


def test_raw_fault_defaults_encode_benign():
    """Column producers written before the fault rows (no fault keys at
    all) encode bit-identically to an explicit benign row."""
    base = {k: v for k, v in _raw_columns(4, lock="mutable").items()
            if k not in P.RAW_FAULT_DEFAULTS}
    old = P.encode_columns(dict(base))
    new = P.encode_columns({**base, "fault": "none", "fault_rate": 0.0,
                            "fault_scale": P.RAW_FAULT_DEFAULTS[
                                "fault_scale"]})
    for k in old:
        np.testing.assert_array_equal(old[k], new[k], err_msg=k)


# --------------------------------------------------------------------------
# Engine invariants
# --------------------------------------------------------------------------
def test_none_row_bit_identical_to_prefault_engine():
    plain = [SimConfig(l, threads=6, cores=4, cs=CS, ncs=NCS,
                       wake_latency=WAKE, seed=s)
             for l in ("ttas", "sleep", "mutable") for s in (0, 1)]
    benign = [_mk(l, "none", s, threads=6)
              for l in ("ttas", "sleep", "mutable") for s in (0, 1)]
    a = xdes.simulate_batch(plain, n_steps=300)
    b = xdes.simulate_batch(benign, n_steps=300)
    np.testing.assert_array_equal(a.completed, b.completed)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu)


def test_wake_faults_never_touch_pure_spinners():
    """lostwake/jitter only perturb the wake path; disciplines that never
    park (ttas) must be bit-identical to their benign run."""
    for fault in ("lostwake", "jitter"):
        a = xdes.simulate_batch([_mk("ttas", "none", s) for s in range(3)],
                                n_steps=300)
        b = xdes.simulate_batch([_mk("ttas", fault, s) for s in range(3)],
                                n_steps=300)
        np.testing.assert_array_equal(a.completed, b.completed,
                                      err_msg=fault)
        np.testing.assert_array_equal(a.completed_per_thread,
                                      b.completed_per_thread,
                                      err_msg=fault)
        # ...while the same fault visibly taxes a sleeping discipline
        c = xdes.simulate_batch([_mk("sleep", "none", s)
                                 for s in range(3)], n_steps=300)
        d = xdes.simulate_batch([_mk("sleep", fault, s)
                                 for s in range(3)], n_steps=300)
        assert d.completed.sum() < c.completed.sum(), fault


def test_full_rate_preemption_stops_everything():
    """fault_rate=1.0 preemption gates every window of every thread: the
    rewind must give back every completion — any leak means the engine
    let a gated thread slip through mid-window."""
    cfgs = [_mk(l, "preempt", s, rate=1.0)
            for l in ("ttas", "mcs", "sleep", "mutable") for s in (0, 1)]
    res = xdes.simulate_batch(cfgs, n_steps=500)
    assert res.completed.tolist() == [0] * len(cfgs)


def test_fault_rows_degrade_throughput():
    for fault in ("preempt", "oversub"):
        base = xdes.simulate_batch(
            [_mk("mutable", "none", s) for s in range(3)], target_cs=100)
        hurt = xdes.simulate_batch(
            [_mk("mutable", fault, s) for s in range(3)], target_cs=100)
        assert (hurt.throughput.mean()
                < 0.9 * base.throughput.mean()), fault


# --------------------------------------------------------------------------
# xdes vs DES parity per fault row (the event-driven twin)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fault", FAULTS)
def test_xdes_vs_des_parity_per_row(fault):
    """Seed-averaged throughput band per (fault, lock) cell.  The DES
    draws its gates at event times and its wake faults from per-thread
    counters, the engine from step-indexed streams — agreement is
    distributional, so the pin is the 4-seed mean in a wide band, in the
    many-windows regime (``SCALE`` = dozens of fault windows per
    horizon; see docs/robustness.md for why few-window runs diverge)."""
    locks = ("ttas", "sleep", "mutable")
    seeds = (0, 1, 2, 3)
    cfgs = [_mk(lock, fault, s) for lock in locks for s in seeds]
    x = xdes.simulate_batch(cfgs, target_cs=150)
    xthr = x.throughput.reshape(len(locks), len(seeds)).mean(axis=1)
    for i, lock in enumerate(locks):
        dthr = np.mean([simulate(
            lock, threads=8, cores=4, cs=CS, ncs=NCS, wake_latency=WAKE,
            target_cs=800, seed=s, **cfgs[i * len(seeds)].fault_kwargs()
        ).throughput for s in seeds])
        assert 0.7 * dthr < xthr[i] < 1.4 * dthr, (
            fault, lock, xthr[i], dthr)


# --------------------------------------------------------------------------
# ref vs Pallas bit-identity on the fault-aware kernel body
# --------------------------------------------------------------------------
def _fault_batch(seed=0):
    """Every fault row x several disciplines/oracles, random shapes —
    the randomized parity surface for the fault-aware kernel body."""
    rng = np.random.default_rng(seed)
    cfgs = []
    for f in FAULTS:
        for lock, oracle in (("mutable", "paper"), ("mutable", "aimd"),
                             ("ttas", "paper"), ("mcs", "paper"),
                             ("sleep", "paper"), ("adaptive", "paper")):
            cfgs.append(SimConfig(
                lock, threads=int(rng.integers(2, 10)),
                cores=int(rng.integers(2, 10)), cs=CS, ncs=NCS,
                wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
                oracle=oracle, fault=f,
                fault_rate=float(rng.uniform(0.2, 0.8)) if f != "none"
                else 0.0,
                fault_scale=float(rng.uniform(5e-6, 5e-5))))
    return cfgs


def _assert_results_equal(a, b, msg=""):
    np.testing.assert_array_equal(a.completed, b.completed, err_msg=msg)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread, err_msg=msg)
    np.testing.assert_array_equal(a.wake_count, b.wake_count, err_msg=msg)
    np.testing.assert_array_equal(a.final_sws, b.final_sws, err_msg=msg)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu, err_msg=msg)


def test_fault_ref_vs_pallas_per_step():
    cfgs = _fault_batch(seed=17)
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                              backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                              backend="pallas")
    _assert_results_equal(ref, pal, "per-step")


@pytest.mark.parametrize("block_steps", [1, 32])
def test_fault_ref_vs_pallas_blocked(block_steps):
    """The blocked body re-derives the global step (and so the fault
    window) from step0 + s — bit-identity across block sizes pins that
    indexing."""
    cfgs = _fault_batch(seed=19)
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="pallas")
    _assert_results_equal(ref, pal, f"blocked B={block_steps}")
    scan = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                               backend="ref")
    _assert_results_equal(ref, scan, f"blocked==scan B={block_steps}")


# --------------------------------------------------------------------------
# The paper-level claim: preemption flips the ranking toward sleep
# --------------------------------------------------------------------------
def test_preemption_flips_ranking_toward_sleep():
    """On the benign oversubscribed machine the mutable lock wins (its
    EvalSWS window beats both extremes); under heavy lock-holder
    preemption the pure sleep lock overtakes every spin-leaning
    discipline — preemption steals progress but never spin burn, so
    parked waiters are the only ones not paying for stolen windows."""
    locks = ("ttas", "mutable", "sleep")
    seeds = (0, 1, 2, 3)
    cfgs = [_mk(l, f, s, rate=r)
            for (f, r) in (("none", 0.0), ("preempt", 0.7))
            for l in locks for s in seeds]
    res = xdes.simulate_batch(cfgs, target_cs=150)
    thr = res.throughput.reshape(2, len(locks), len(seeds)).mean(-1)
    benign = dict(zip(locks, thr[0]))
    faulted = dict(zip(locks, thr[1]))
    assert benign["mutable"] > benign["sleep"] > benign["ttas"]
    assert faulted["sleep"] > 1.2 * faulted["ttas"]
    assert faulted["sleep"] > 1.2 * faulted["mutable"]


# --------------------------------------------------------------------------
# Sweep + serve plumbing
# --------------------------------------------------------------------------
def test_fault_sweep_catalog_shape():
    from repro.configs.catalog import (LOCK_FAULT_RATES, LOCK_FAULTS,
                                       lock_discipline_variants,
                                       lock_fault_sweep,
                                       lock_fault_variants)

    disc = lock_discipline_variants()
    variants = lock_fault_variants()
    assert len(variants) == len(LOCK_FAULTS) * len(disc)
    cfgs = lock_fault_sweep(n_scenarios=3)
    assert len(cfgs) == 3 * len(variants)
    B = len(variants)
    for s in range(3):
        block = cfgs[s * B:(s + 1) * B]
        # scenario-major: every row of the block shares its machine
        assert len({(c.threads, c.cores, c.cs, c.wake_latency)
                    for c in block}) == 1
        # fault-major within the block, disciplines minor
        assert [c.fault for c in block] == [
            f for f in LOCK_FAULTS for _ in disc]
        assert [c.fault_rate for c in block[:len(disc)]] \
            == [LOCK_FAULT_RATES["none"]] * len(disc)
        # the fault window is scenario-scaled
        assert block[0].fault_scale == pytest.approx(
            4.0 * (block[0].cs[1] + block[0].ncs[1]))


def test_fault_columns_twin_bit_identical():
    from repro.configs.catalog import lock_fault_columns, lock_fault_sweep

    a = P.encode_configs(lock_fault_sweep(n_scenarios=5, seed=3))
    b = P.encode_configs(lock_fault_columns(n_scenarios=5, seed=3))
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert sorted(set(b["fault"].tolist())) == sorted(P.FAULT_IDS.values())


def test_fault_grid_smoke():
    from benchmarks.sweep import fault_grid
    from repro.configs.catalog import lock_discipline_variants

    out = fault_grid(n_scenarios=4, target_cs=25, verbose=False)
    assert out["meta"]["n_configs"] == 4 * 5 * len(lock_discipline_variants())
    assert set(out["faults"]) == set(FAULTS)
    for fl, rows in out["faults"].items():
        assert sum(r["wins"] for r in rows.values()) == 4, fl
        # the benign row retains exactly 1.0 of itself
        if fl == "none":
            assert all(r["mean_retained_vs_none"] == pytest.approx(1.0)
                       for r in rows.values())
    assert all(0 < c["win_share"] <= 1 for c in out["phase"])


def test_sched_scenario_fault_row():
    from repro.serve import SchedScenario

    sc = SchedScenario(slots=8, requests=20, decode_s=0.05, think_s=0.1,
                       fault="preempt", fault_rate=0.5)
    c = sc.to_sim_config("mutable")
    assert (c.fault, c.fault_rate) == ("preempt", 0.5)
    assert c.fault_scale == pytest.approx(4.0 * (0.05 + 0.1))
    assert SchedScenario(slots=4, requests=8).to_sim_config("zero").fault \
        == "none"
    with pytest.raises(ValueError):
        SchedScenario(slots=4, requests=8,
                      fault="meteor").to_sim_config("zero")

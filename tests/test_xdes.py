"""Equivalence of the batched xdes engine with the event-driven DES, plus
the Pallas step kernel vs its XLA reference.

The batched simulator quantizes time, so the pin is *qualitative*: on the
paper's regimes it must reproduce the claim orderings (C2-C4) and agree
with the Python DES on per-cell trends within a tolerance band."""

import numpy as np
import pytest

from repro.core import xdes
from repro.core.des import simulate
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)
WAKE = 8e-6
LOCKS = ["ttas", "mcs", "sleep", "adaptive", "mutable"]
REGIMES = {"ss": (SHORT, SHORT), "ls": (LONG, SHORT), "sl": (SHORT, LONG)}
THREADS = [4, 20]


@pytest.fixture(scope="module")
def batch():
    """One jit-compiled run covering 3 regimes x 5 locks x 2 thread counts
    (row order: regime-major, then lock, then threads)."""
    cfgs = [SimConfig(lock, threads=tc, cores=20, cs=cs, ncs=ncs,
                      wake_latency=WAKE, seed=0)
            for cs, ncs in REGIMES.values()
            for lock in LOCKS for tc in THREADS]
    res = xdes.simulate_batch(cfgs, target_cs=120)
    idx = {(reg, lock, tc): i for i, (reg, lock, tc) in enumerate(
        (reg, lock, tc) for reg in REGIMES for lock in LOCKS
        for tc in THREADS)}
    return res, idx


def test_progress_everywhere(batch):
    res, _ = batch
    assert (res.completed >= 100).all(), res.completed
    assert np.isfinite(res.throughput).all()
    assert (res.spin_cpu >= 0).all()


def test_mutable_window_stays_bounded(batch):
    res, idx = batch
    for reg in REGIMES:
        for tc in THREADS:
            i = idx[(reg, "mutable", tc)]
            assert 1 <= res.final_sws[i] <= 20


def test_c2_short_cs_mutable_beats_static_expectation(batch):
    res, idx = batch
    thr = lambda lock, tc: res.throughput[idx[("ss", lock, tc)]]
    mut = np.mean([thr("mutable", tc) for tc in THREADS])
    pt_exp = 0.5 * (np.mean([thr("ttas", tc) for tc in THREADS])
                    + np.mean([thr("sleep", tc) for tc in THREADS]))
    assert mut > pt_exp, (mut, pt_exp)


def test_c3_long_cs_mutable_cuts_spin_cpu(batch):
    res, idx = batch
    i_ttas = idx[("ls", "ttas", 20)]
    i_mut = idx[("ls", "mutable", 20)]
    ratio = (res.sync_cpu_per_cs[i_ttas]
             / max(res.sync_cpu_per_cs[i_mut], 1e-12))
    assert ratio >= 5.0, ratio          # paper: ~an order of magnitude
    best = max(res.throughput[idx[("ls", lock, 20)]] for lock in LOCKS)
    assert res.throughput[i_mut] >= 0.8 * best


def test_c4_low_contention_all_locks_converge(batch):
    res, idx = batch
    for tc in THREADS:
        thr = [res.throughput[idx[("sl", lock, tc)]] for lock in LOCKS]
        assert min(thr) > 0.85 * max(thr), thr


def test_agrees_with_event_driven_des_on_trends(batch):
    """Per-cell pin against the exact DES: throughput within a band and
    the same winner between spin and sleep in their home regimes."""
    res, idx = batch
    for reg, lock, tc in [("ss", "ttas", 20), ("ss", "sleep", 20),
                          ("ls", "mutable", 20)]:
        cs, ncs = REGIMES[reg]
        d = simulate(lock, threads=tc, cores=20, cs=cs, ncs=ncs,
                     wake_latency=WAKE, target_cs=800, seed=0)
        x = res.throughput[idx[(reg, lock, tc)]]
        assert 0.7 * d.throughput < x < 1.4 * d.throughput, (lock, reg, x,
                                                             d.throughput)
    # ordering: spinning wins the short regime, sleeping wins long-CS waste
    assert (res.throughput[idx[("ss", "ttas", 20)]]
            > res.throughput[idx[("ss", "sleep", 20)]])
    assert (res.sync_cpu_per_cs[idx[("ls", "sleep", 20)]]
            < res.sync_cpu_per_cs[idx[("ls", "ttas", 20)]])


def test_pallas_backend_matches_ref_exactly():
    cfgs = [SimConfig(lock, threads=6, cores=6, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE) for lock in LOCKS]
    r_ref = xdes.simulate_batch(cfgs, n_steps=250, backend="ref")
    r_pal = xdes.simulate_batch(cfgs, n_steps=250, backend="pallas")
    np.testing.assert_array_equal(r_ref.completed, r_pal.completed)
    np.testing.assert_allclose(r_ref.spin_cpu, r_pal.spin_cpu, rtol=1e-5)
    np.testing.assert_array_equal(r_ref.final_sws, r_pal.final_sws)


def test_lock_sim_step_kernel_matches_ref():
    from repro.kernels.lock_sim import lock_sim_step
    from repro.kernels.ref import lock_sim_step_ref

    rng = np.random.default_rng(3)
    C, T = 33, 29                       # non-multiples of the block sizes
    st = rng.integers(0, 6, (C, T)).astype(np.int32)
    rem = rng.uniform(0.0, 1e-4, (C, T)).astype(np.float32)
    alpha = rng.uniform(0.0, 0.1, C).astype(np.float32)
    cores = rng.integers(1, 33, C).astype(np.float32)
    dt = rng.uniform(1e-7, 2e-6, C).astype(np.float32)
    hb = rng.integers(0, 2, C).astype(bool)
    r1, b1 = lock_sim_step_ref(st, rem, alpha, cores, dt, hb)
    r2, b2 = lock_sim_step(st, rem, alpha, cores, dt, hb)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-12)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-12)


def test_thousand_config_sweep_single_call():
    """The acceptance-scale shape: >= 1000 heterogeneous configurations in
    one jit-compiled call (short horizon keeps this a shape/plumbing test;
    benchmarks/sweep.py runs it at full length)."""
    from repro.configs.catalog import lock_scenario_sweep

    cfgs = lock_scenario_sweep(n_scenarios=200)
    assert len(cfgs) == 1000
    res = xdes.simulate_batch(cfgs, n_steps=400)
    assert res.completed.shape == (1000,)
    assert np.isfinite(res.throughput).all()
    assert (res.completed > 0).sum() > 500   # short horizon, most progress


@pytest.mark.slow
def test_full_fig3_grid_reproduces_paper_claims():
    """The Fig. 3 grid end to end through benchmarks.sweep (one batched
    call) — asserts the paper's C2/C3/C4 qualitative claims."""
    from benchmarks.sweep import fig3_batched

    f3 = fig3_batched(target_cs=60, seeds=(0,), verbose=False)
    claims = f3["claims"]
    assert claims["C2"] and claims["C3"] and claims["C4"], claims

"""Docs hygiene: every relative markdown link in README/docs/reports
resolves to a real file, every docs page is indexed in docs/README.md,
and no page references modules deleted from the tree.

Doubles as the CI link-check (the workflow runs this file after the
benchmark steps so freshly generated reports/*.md are covered too).
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: ``[text](target)`` — target split from an optional title/anchor.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

#: Modules that used to exist; docs must not point at them anymore.
_DELETED = ("benchmarks/roofline.py", "benchmarks.roofline")


def _md_files():
    paths = [os.path.join(REPO, "README.md")]
    for sub in ("docs", "reports"):
        d = os.path.join(REPO, sub)
        if os.path.isdir(d):
            paths += sorted(os.path.join(d, f) for f in os.listdir(d)
                            if f.endswith(".md"))
    return paths


def _relative_links(path):
    text = open(path, encoding="utf-8").read()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize("path", _md_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_relative_links_resolve(path):
    base = os.path.dirname(path)
    missing = []
    for target in _relative_links(path):
        if not target:
            continue
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            missing.append(target)
    assert not missing, (
        f"{os.path.relpath(path, REPO)} has dead relative links: {missing}")


@pytest.mark.parametrize("path", _md_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_no_references_to_deleted_modules(path):
    text = open(path, encoding="utf-8").read()
    hits = [d for d in _DELETED if d in text]
    assert not hits, (
        f"{os.path.relpath(path, REPO)} references deleted modules: {hits}")


def test_docs_index_lists_every_page():
    index = os.path.join(REPO, "docs", "README.md")
    assert os.path.exists(index), "docs/README.md index is missing"
    text = open(index, encoding="utf-8").read()
    pages = [f for f in os.listdir(os.path.join(REPO, "docs"))
             if f.endswith(".md") and f != "README.md"]
    unlisted = [p for p in pages if p not in text]
    assert not unlisted, f"docs/README.md does not link: {unlisted}"

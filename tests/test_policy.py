"""The shared policy core (repro.core.policy): the pure functions must be
exactly the semantics the class-based layers implement, since the DES, the
threaded lock, the serving window and the batched xdes backend all consume
them.  Checked here: EvalSWS equivalence against the stateful oracle,
clamp/correction/release laws, and the SimConfig encoding."""

import random

import numpy as np
import pytest

from repro.core import policy as P
from repro.core.oracle import EvalSWS
from repro.core.window import SpinningWindow


def test_eval_sws_delta_matches_stateful_oracle():
    rng = random.Random(0)
    oracle = EvalSWS(k=7)
    cnt = 0
    sws = 1
    for _ in range(500):
        spun = rng.random() < 0.6
        slept = rng.random() < 0.4
        want = oracle.eval_sws(spun, slept, sws)
        got, cnt = P.eval_sws_delta(spun, slept, sws, cnt, 7)
        assert got == want
        assert cnt == oracle.cnt
        sws = max(1, sws + got)


def test_eval_sws_grow_and_shrink():
    # late wake-up doubles; K clean rounds shrink by one
    delta, cnt = P.eval_sws_delta(spun=False, slept=True, sws=4, cnt=3, k=10)
    assert (delta, cnt) == (4, 0)
    delta, cnt = P.eval_sws_delta(spun=True, slept=False, sws=4, cnt=9, k=10)
    assert (delta, cnt) == (-1, 0)
    delta, cnt = P.eval_sws_delta(spun=True, slept=True, sws=4, cnt=0, k=10)
    assert (delta, cnt) == (0, 1)      # slept AND spun is not a late wake


def test_clamp_delta_bounds():
    for sws in range(1, 12):
        for delta in range(-12, 13):
            c = P.clamp_delta(sws, delta, 1, 8)
            assert 1 <= sws + c <= 8
            # clamp only ever moves the delta toward the bounds
            assert abs(c) <= abs(delta) or (sws + delta < 1
                                            or sws + delta > 8)


def test_wake_correction_c1_c2_laws():
    # C1: grow with sleepers — wake at most delta, at most the sleepers
    assert P.wake_correction(delta=2, thc=6, sws_pre=3) == 2   # 3 sleepers
    assert P.wake_correction(delta=4, thc=5, sws_pre=3) == 2   # only 2 exist
    assert P.wake_correction(delta=2, thc=3, sws_pre=3) == 0   # none outside
    # C2: shrink with excess spinners — suppress at most -delta, at most
    # the overflow past the new window
    assert P.wake_correction(delta=-2, thc=6, sws_pre=5) == -2  # 3 excess
    assert P.wake_correction(delta=-3, thc=4, sws_pre=5) == -2  # 2 excess
    assert P.wake_correction(delta=-2, thc=2, sws_pre=5) == 0   # fits
    # magnitude law holds for arbitrary states
    rng = random.Random(1)
    for _ in range(300):
        delta = rng.randint(-6, 6)
        if delta == 0:
            continue
        thc, sws_pre = rng.randint(0, 20), rng.randint(1, 12)
        corr = P.wake_correction(delta, thc, sws_pre)
        assert abs(corr) <= abs(delta)
        assert corr * delta >= 0         # same sign (or zero)


def test_latch_and_release_quota():
    # clean release: ship the pending corrections + the R16 promotion
    r, wuc = P.latch_wuc(3)
    assert (r, wuc) == (3, 0)
    assert P.release_quota(r, thc_pre=5, sws=2) == 4     # +1: sleepers exist
    assert P.release_quota(r, thc_pre=2, sws=2) == 3     # no sleepers
    # C2-suppressed release: no wake at all, debt shrinks by one
    r, wuc = P.latch_wuc(-2)
    assert (r, wuc) == (-1, -1)
    assert P.release_quota(r, thc_pre=9, sws=1) == 0


def test_arrival_rule():
    assert not P.should_sleep_on_arrival(thc_pre=0, sws=1)   # holder slot
    assert P.should_sleep_on_arrival(thc_pre=1, sws=1)
    assert not P.should_sleep_on_arrival(thc_pre=3, sws=4)
    assert P.should_sleep_on_arrival(thc_pre=4, sws=4)


def test_window_observe_consumes_same_correction():
    """The single-controller window must report exactly wake_correction."""
    win = SpinningWindow(max_size=8, initial=4)
    # force a grow via a late wake with 6 occupants (2 outside the window)
    corr = win.observe(late_wake=True, occupancy=6)
    assert win.sws == 8
    assert corr == P.wake_correction(4, 6, 4)


def test_sim_config_encoding_roundtrip():
    cfgs = [
        P.SimConfig("mutable", threads=8, cores=4, cs=(0, 2e-6),
                    ncs=(0, 1e-6), sws_init=2),
        P.SimConfig("ttas", threads=3, cores=20, cs=(1e-6, 1e-6),
                    ncs=(0, 4e-6), alpha=0.07),
        P.SimConfig("sleep", threads=16, cores=2, cs=(0, 9e-6),
                    ncs=(0, 9e-6)),
        P.SimConfig("adaptive", threads=5, cores=5, cs=(0, 2e-6),
                    ncs=(0, 2e-6), spin_budget=5e-6),
    ]
    arrs = P.encode_configs(cfgs)
    assert set(arrs) == set(P.CONFIG_FIELDS)
    assert arrs["policy"].tolist() == [P.MUTABLE, P.TTAS, P.SLEEP,
                                       P.ADAPTIVE]
    # unified A7 window encoding: spin/adaptive never sleep on arrival,
    # the sleep lock parks every waiter, mutable starts at sws_init
    assert arrs["sws_init"].tolist() == [2, 3, 1, 5]
    np.testing.assert_allclose(arrs["alpha"],
                               [0.02, 0.07, 0.0, 0.02], atol=1e-7)
    assert arrs["spin_budget"][3] == np.float32(5e-6)


def test_sim_config_validation():
    with pytest.raises(ValueError):
        P.SimConfig("nope", threads=2, cores=2, cs=(0, 1e-6), ncs=(0, 1e-6))
    with pytest.raises(ValueError):
        P.SimConfig("ttas", threads=0, cores=2, cs=(0, 1e-6), ncs=(0, 1e-6))

"""Discipline-row registry: dispatch semantics, the FIFO/MCS ticket-order
row (DES parity, no-barging property, Pallas bit-identity), the fused
transition kernel vs its XLA reference, the sharded sweep path, and the
scheduler-through-xdes ablation."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import policy as P
from repro.core import xdes
from repro.core.des import simulate
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
WAKE = 8e-6


# --------------------------------------------------------------------------
# Row registry + dispatch
# --------------------------------------------------------------------------
def test_every_policy_id_has_a_row():
    assert sorted(P.POLICY_ROW) == sorted(P.POLICY_IDS.values())
    assert P.POLICY_ROW[P.FIFO].name == "fifo"
    assert P.POLICY_ROW[P.MCS].name == "spin"       # legacy MCS = spin row


def test_discipline_flags_table():
    ids = np.arange(len(P.POLICY_IDS), dtype=np.int32)
    flags = P.discipline_flags(ids)
    assert len(flags) == len(P.DISCIPLINE_FLAG_ATTRS)
    by = {P.POLICY_NAMES[i]: tuple(int(f[i]) for f in flags) for i in ids}
    # (handoff, fifo_grant, budget_spin, wake_to_spin, repark, windowed,
    #  budget_scaled, backoff)
    assert by["ttas"] == (1, 0, 0, 0, 0, 0, 0, 0)
    assert by["sleep"] == (0, 0, 0, 0, 1, 0, 0, 0)
    assert by["adaptive"] == (1, 0, 1, 0, 1, 0, 0, 0)
    assert by["mutable"] == (1, 0, 0, 1, 0, 1, 0, 0)
    assert by["fifo"] == (1, 1, 0, 0, 0, 0, 0, 0)
    assert by["fissile"] == (1, 0, 1, 1, 0, 1, 1, 0)
    assert by["hapax"] == (0, 1, 0, 0, 0, 0, 0, 0)
    assert by["ttas_backoff"] == (0, 0, 0, 0, 0, 0, 0, 1)


def test_arrival_sleeps_dispatch():
    # mutable: A7 window rule
    assert P.discipline_arrival_sleeps(P.MUTABLE, 0, 4, 4, 0) == 1
    assert P.discipline_arrival_sleeps(P.MUTABLE, 0, 3, 4, 0) == 0
    # sleep lock: barge only as the first arrival on a free lock
    assert P.discipline_arrival_sleeps(P.SLEEP, 0, 0, 1, 1) == 0
    assert P.discipline_arrival_sleeps(P.SLEEP, 1, 0, 1, 1) == 1
    assert P.discipline_arrival_sleeps(P.SLEEP, 0, 0, 1, 0) == 1
    # spin family / adaptive / fifo / fissile / backoff never park on arrival
    for pid in (P.TAS, P.TTAS, P.MCS, P.ADAPTIVE, P.FIFO, P.FISSILE,
                P.TTAS_BACKOFF):
        assert P.discipline_arrival_sleeps(pid, 0, 99, 1, 0) == 0
    # hapax: barge only when the lock is free AND nobody is ahead
    assert P.discipline_arrival_sleeps(P.HAPAX, 0, 0, 1, 1) == 0
    assert P.discipline_arrival_sleeps(P.HAPAX, 0, 1, 1, 1) == 1
    assert P.discipline_arrival_sleeps(P.HAPAX, 0, 0, 1, 0) == 1


def test_release_quota_dispatch_matches_scalar_rules():
    # mutable row == the scalar R11-R17 reference
    for r_wuc in (-1, 0, 1, 3):
        for thc_pre, sws in ((2, 4), (5, 4)):
            want = P.release_quota(r_wuc, thc_pre, sws)
            got = P.discipline_release_quota(P.MUTABLE, r_wuc, thc_pre,
                                             sws, 1, 0)
            assert got == want, (r_wuc, thc_pre, sws)
    # sleep wakes one iff anyone is parked; adaptive only without a handoff
    assert P.discipline_release_quota(P.SLEEP, -1, 0, 1, 1, 0) == 1
    assert P.discipline_release_quota(P.SLEEP, -1, 0, 1, 0, 0) == 0
    assert P.discipline_release_quota(P.ADAPTIVE, -1, 0, 1, 1, 1) == 0
    assert P.discipline_release_quota(P.ADAPTIVE, -1, 0, 1, 1, 0) == 1
    # pure spin / fifo issue no wake-ups
    assert P.discipline_release_quota(P.TTAS, -1, 5, 1, 3, 1) == 0
    assert P.discipline_release_quota(P.FIFO, -1, 5, 1, 3, 1) == 0


def test_sim_config_accepts_fifo():
    c = SimConfig("fifo", threads=6, cores=4, cs=SHORT, ncs=SHORT)
    assert c.sws_start == 6                 # never parks on arrival
    assert c.alpha_eff == 0.0               # private-line spinning
    arrs = P.encode_configs([c])
    assert arrs["policy"][0] == P.FIFO


# --------------------------------------------------------------------------
# FIFO ticket order: unit-level grant test + the no-barging property
# --------------------------------------------------------------------------
def _one_step_state(policy_id, tickets, T=4):
    """A single config one step from a release: thread 0 holds the CS with
    zero work left, threads 1..T-1 spin with the given tickets."""
    import jax.numpy as jnp

    C = 1
    st = np.full((C, T), P.SPIN, np.int32)
    st[0, 0] = P.CS
    rem = np.full((C, T), np.inf, np.float32)
    rem[0, 0] = 0.0                          # holder done -> release now
    args = dict(
        st=jnp.asarray(st), rem=jnp.asarray(rem),
        wake_at=jnp.full((C, T), np.inf, jnp.float32),
        slept=jnp.zeros((C, T), jnp.int32),
        spun=jnp.ones((C, T), jnp.int32),
        ctr=jnp.ones((C, T), jnp.uint32),
        ticket=jnp.asarray(np.asarray(tickets, np.int32)[None, :]),
        completed_pt=jnp.zeros((C, T), jnp.int32),
        sws=jnp.full((C,), T, jnp.int32), cnt=jnp.zeros((C,), jnp.int32),
        ewma=jnp.zeros((C,), jnp.int32), wuc=jnp.zeros((C,), jnp.int32),
        permits=jnp.zeros((C,), jnp.int32),
        nticket=jnp.full((C,), 100, jnp.int32),
        completed=jnp.zeros((C,), jnp.int32),
        wake_count=jnp.zeros((C,), jnp.int32),
        now2=jnp.full((C,), 1e-6, jnp.float32),
        policy=jnp.full((C,), policy_id, jnp.int32),
        threads=jnp.full((C,), T, jnp.int32),
        dt=jnp.full((C,), 1e-7, jnp.float32),
        wake=jnp.full((C,), WAKE, jnp.float32),
        cs_lo=jnp.zeros((C,), jnp.float32),
        cs_hi=jnp.full((C,), 3.7e-6, jnp.float32),
        ncs_lo=jnp.zeros((C,), jnp.float32),
        ncs_hi=jnp.full((C,), 3.7e-6, jnp.float32),
        k=jnp.full((C,), 10, jnp.int32),
        sws_max=jnp.full((C,), T, jnp.int32),
        spin_budget=jnp.full((C,), 2e-6, jnp.float32),
        seed=jnp.zeros((C,), jnp.uint32),
        oracle=jnp.zeros((C,), jnp.int32),
        workload=jnp.zeros((C,), jnp.int32),
        wl_period=jnp.full((C,), 1e-4, jnp.float32),
        wl_duty=jnp.full((C,), 0.25, jnp.float32),
        wl_burst=jnp.full((C,), 8.0, jnp.float32),
        wl_spread=jnp.full((C,), 4.0, jnp.float32),
        stepi=jnp.zeros((C,), jnp.int32),
        arrival=jnp.zeros((C,), jnp.int32),
        arr_rate=jnp.zeros((C,), jnp.float32),
        q_cap=jnp.full((C,), 128, jnp.int32),
        slo=jnp.full((C,), 1e-3, jnp.float32),
        tb=jnp.zeros((C,), jnp.int32),
        fault=jnp.zeros((C,), jnp.int32),
        flt_rate=jnp.zeros((C,), jnp.float32),
        flt_scale=jnp.full((C,), 1e-4, jnp.float32),
        park_cost=jnp.ones((C,), jnp.float32),
    )
    return args


def test_fifo_release_grants_lowest_ticket_not_lowest_tid():
    from repro.kernels.ref import NO_TICKET, lock_transitions_ref

    # tickets inverse to thread ids: tid 3 holds the OLDEST ticket
    tickets = [NO_TICKET, 7, 6, 5]
    out = lock_transitions_ref(**_one_step_state(P.FIFO, tickets))
    st1 = np.asarray(out[0])[0]
    assert st1[3] == P.CS, st1               # min ticket wins ...
    assert st1[1] == P.SPIN and st1[2] == P.SPIN
    # ... while the spin row (legacy mcs id) grants the lowest tid
    out = lock_transitions_ref(**_one_step_state(P.MCS, tickets))
    st2 = np.asarray(out[0])[0]
    assert st2[1] == P.CS, st2


def test_fifo_no_barging_fairness():
    """Ticket grants serve every thread in arrival order, so per-thread
    completed-CS counts stay within a slot of each other; barging locks
    starve high tids under the same load."""
    cfgs = [SimConfig("fifo", threads=t, cores=c, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s)
            for (t, c, s) in ((8, 4, 0), (16, 8, 1), (6, 20, 2))]
    res = xdes.simulate_batch(cfgs, target_cs=150)
    assert (res.completed >= 120).all()
    for i in range(len(cfgs)):
        assert res.fairness_spread(i) <= 3, (
            i, res.completed_per_thread[i])
    # contrast: ttas on the oversubscribed machine is heavily unfair
    ttas = xdes.simulate_batch(
        [SimConfig("ttas", threads=8, cores=4, cs=SHORT, ncs=SHORT,
                   wake_latency=WAKE)], target_cs=150)
    assert ttas.fairness_spread(0) > 10


def test_fifo_des_model_is_fifo_and_parity_with_xdes():
    from repro.core.des import LockSim

    sim = LockSim("fifo", 8, 4, SHORT, SHORT, WAKE, seed=1)
    sim.run(target_cs=400)
    counts = [t.cs_done for t in sim.tasks]
    # random NCS lengths let a thread miss the odd queue round, so the
    # spread is a few CSes — far below the 10s a barging lock shows here
    assert max(counts) - min(counts) <= 6, counts
    # throughput parity band vs the exact DES (same band as the other
    # disciplines in test_xdes.py)
    for tc in (4, 20):
        d = simulate("fifo", threads=tc, cores=20, cs=SHORT, ncs=SHORT,
                     wake_latency=WAKE, target_cs=800, seed=0)
        x = xdes.simulate_batch(
            [SimConfig("fifo", threads=tc, cores=20, cs=SHORT, ncs=SHORT,
                       wake_latency=WAKE, seed=0)], target_cs=150)
        assert 0.7 * d.throughput < x.throughput[0] < 1.4 * d.throughput, (
            tc, x.throughput[0], d.throughput)


def test_fifo_pallas_backend_bit_identical():
    cfgs = [SimConfig("fifo", threads=t, cores=c, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s)
            for (t, c, s) in ((6, 6, 0), (8, 4, 1), (5, 12, 2))]
    r_ref = xdes.simulate_batch(cfgs, n_steps=300, backend="ref")
    r_pal = xdes.simulate_batch(cfgs, n_steps=300, backend="pallas")
    np.testing.assert_array_equal(r_ref.completed, r_pal.completed)
    np.testing.assert_array_equal(r_ref.completed_per_thread,
                                  r_pal.completed_per_thread)
    np.testing.assert_array_equal(r_ref.wake_count, r_pal.wake_count)
    np.testing.assert_allclose(r_ref.spin_cpu, r_pal.spin_cpu, rtol=1e-5)


# --------------------------------------------------------------------------
# The fused transition kernel vs its XLA reference on random state
# --------------------------------------------------------------------------
def test_transitions_kernel_matches_ref_on_random_state():
    from repro.kernels.lock_sim import lock_transitions_step
    from repro.kernels.ref import NO_TICKET, lock_transitions_ref

    rng = np.random.default_rng(11)
    C, T = 33, 29                           # non-multiples of block sizes
    ticket = rng.integers(0, 50, (C, T)).astype(np.int32)
    ticket[rng.random((C, T)) < 0.5] = NO_TICKET
    args = (
        rng.integers(0, 6, (C, T)).astype(np.int32),            # st
        rng.uniform(-1e-7, 1e-4, (C, T)).astype(np.float32),    # rem
        rng.uniform(0, 1e-4, (C, T)).astype(np.float32),        # wake_at
        rng.integers(0, 2, (C, T)).astype(np.int32),            # slept
        rng.integers(0, 2, (C, T)).astype(np.int32),            # spun
        rng.integers(0, 1000, (C, T)).astype(np.uint32),        # ctr
        ticket,
        rng.integers(0, 30, (C, T)).astype(np.int32),           # cpt
        rng.integers(1, 20, C).astype(np.int32),                # sws
        rng.integers(0, 12, C).astype(np.int32),                # cnt
        rng.integers(0, 257, C).astype(np.int32),               # ewma
        rng.integers(-3, 4, C).astype(np.int32),                # wuc
        rng.integers(0, 3, C).astype(np.int32),                 # permits
        np.full(C, 60, np.int32),                               # nticket
        rng.integers(0, 100, C).astype(np.int32),               # completed
        rng.integers(0, 100, C).astype(np.int32),               # wake_count
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # now2
        rng.integers(0, 5000, C).astype(np.int32),              # stepi
        rng.integers(0, 10, C).astype(np.int32),                # policy
        rng.integers(1, T + 1, C).astype(np.int32),             # threads
        rng.uniform(1e-8, 1e-6, C).astype(np.float32),          # dt
        np.full(C, WAKE, np.float32),                           # wake
        np.zeros(C, np.float32),                                # cs_lo
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # cs_hi
        np.zeros(C, np.float32),                                # ncs_lo
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # ncs_hi
        rng.integers(1, 31, C).astype(np.int32),                # k
        rng.integers(20, 33, C).astype(np.int32),               # sws_max
        np.full(C, 2e-6, np.float32),                           # spin_budget
        rng.integers(0, 2**31, C).astype(np.uint32),            # seed
        rng.integers(0, 4, C).astype(np.int32),                 # oracle
        rng.integers(0, 4, C).astype(np.int32),                 # workload
        rng.uniform(1e-5, 1e-3, C).astype(np.float32),          # wl_period
        rng.uniform(0.1, 0.9, C).astype(np.float32),            # wl_duty
        rng.uniform(1.0, 16.0, C).astype(np.float32),           # wl_burst
        rng.uniform(1.0, 8.0, C).astype(np.float32),            # wl_spread
        np.zeros(C, np.int32),                                  # arrival
        np.zeros(C, np.float32),                                # arr_rate
        np.full(C, 128, np.int32),                              # q_cap
        np.full(C, 1e-3, np.float32),                           # slo
        rng.integers(0, 2, C).astype(np.int32),                 # tb
        rng.integers(0, 5, C).astype(np.int32),                 # fault
        rng.uniform(0.0, 0.5, C).astype(np.float32),            # flt_rate
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # flt_scale
        rng.uniform(0.1, 100.0, C).astype(np.float32),          # park_cost
    )
    ref = lock_transitions_ref(*args)
    pal = lock_transitions_step(*args, block_configs=16)
    for name, a, b in zip(
            ("st", "rem", "wake_at", "slept", "spun", "ctr", "ticket",
             "completed_pt", "sws", "cnt", "ewma", "wuc", "permits",
             "nticket", "completed", "wake_count"), ref, pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


# --------------------------------------------------------------------------
# Sharded sweep: shard_map over the config axis == unsharded, bit for bit
# --------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
from repro.core import xdes
from repro.core.policy import SimConfig

assert len(jax.devices()) == 4
locks = ["ttas", "fifo", "sleep", "mutable", "adaptive", "mcs"]
cfgs = [SimConfig(l, threads=5, cores=4, cs=(0.0, 3.7e-6),
                  ncs=(0.0, 3.7e-6), wake_latency=8e-6) for l in locks]
r1 = xdes.simulate_batch(cfgs, n_steps=300, shard=False)
r2 = xdes.simulate_batch(cfgs, n_steps=300, shard=True)  # 6 rows, pad to 8
np.testing.assert_array_equal(r1.completed, r2.completed)
np.testing.assert_array_equal(r1.final_sws, r2.final_sws)
np.testing.assert_array_equal(r1.wake_count, r2.wake_count)
np.testing.assert_array_equal(r1.completed_per_thread,
                              r2.completed_per_thread)
np.testing.assert_allclose(r1.spin_cpu, r2.spin_cpu, rtol=1e-6)
print("SHARDED-OK", r1.completed.tolist())
"""


def test_sharded_simulate_batch_matches_unsharded():
    """Device count is locked at first backend init, so the 4-device mesh
    runs in a subprocess (same pattern as test_distributed.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH")]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED-OK" in proc.stdout


# --------------------------------------------------------------------------
# Scheduler policies through xdes
# --------------------------------------------------------------------------
def test_sched_scenario_row_schema():
    from repro.serve import SchedScenario

    sc = SchedScenario(slots=8, requests=20, decode_s=0.05, think_s=0.1,
                       prefill_s=0.01, seed=3)
    c = sc.to_sim_config("mutable")
    assert (c.lock, c.threads, c.cores) == ("mutable", 20, 8)
    assert c.wake_latency == 0.01 and c.cs == (0.0, 0.05)
    assert sc.to_sim_config("zero").lock == "sleep"
    assert sc.to_sim_config("max").lock == "ttas"
    with pytest.raises(ValueError):
        sc.to_sim_config("nope")


def test_xdes_policy_sweep_reproduces_scheduler_tradeoff():
    """The batched ablation must tell the bench's story: the mutable
    window buys near-best handoff throughput at a standby residency far
    below the pinned-max pool, and masks more promotions than zero."""
    from repro.serve import sample_sched_scenarios, xdes_policy_sweep

    out = xdes_policy_sweep(sample_sched_scenarios(12), target_cs=80)
    pol = out["policies"]
    assert set(pol) == {"zero", "max", "mutable"}
    assert pol["mutable"]["mean_ratio_to_best"] > 0.9
    assert (pol["mutable"]["mean_ratio_to_best"]
            >= pol["max"]["mean_ratio_to_best"] - 0.05)
    # residency ordering: zero holds nothing, max holds the most
    assert pol["zero"]["standby_s_per_handoff"] == 0.0
    assert (pol["mutable"]["standby_s_per_handoff"]
            < 0.5 * pol["max"]["standby_s_per_handoff"])
    # the window masks some cold promotions relative to zero
    assert (pol["mutable"]["cold_promotions_per_handoff"]
            < pol["zero"]["cold_promotions_per_handoff"])


# --------------------------------------------------------------------------
# Discipline-diagram grid plumbing
# --------------------------------------------------------------------------
def test_discipline_variants_sweep_oracles_only_for_windowed_rows():
    from repro.configs.catalog import (LOCK_ORACLES,
                                       lock_discipline_sweep,
                                       lock_discipline_variants)

    variants = lock_discipline_variants()
    windowed = [d for d in ("mutable", "fissile")]
    for d in windowed:
        fam = [v for v in variants if v["lock"] == d]
        assert [v["oracle"] for v in fam] == list(LOCK_ORACLES), d
    others = [v for v in variants if v["lock"] not in windowed]
    assert all(v["oracle"] == LOCK_ORACLES[0] for v in others)
    # ttas, mcs, fifo, sleep, adaptive, hapax, ttas_backoff
    assert len(others) == 7

    cfgs = lock_discipline_sweep(n_scenarios=3)
    V = len(variants)
    assert len(cfgs) == 3 * V
    for s in range(3):
        block = cfgs[s * V:(s + 1) * V]
        assert len({(c.threads, c.cores, c.cs, c.wake_latency)
                    for c in block}) == 1   # scenario-major row order
        assert [(c.lock, c.oracle) for c in block] \
            == [(v["lock"], v["oracle"]) for v in variants]


# --------------------------------------------------------------------------
# Related-work rows: Hapax FIFO admission, ttas_backoff, fissile budget
# --------------------------------------------------------------------------
def test_hapax_release_wakes_min_ticket_sleeper():
    """Hapax unlock is a head wake: the oldest-ticket sleeper (NOT the
    lowest tid) is promoted to WAKING; everyone else stays parked."""
    import jax.numpy as jnp

    from repro.kernels.ref import NO_TICKET, lock_transitions_ref

    args = _one_step_state(P.HAPAX, [NO_TICKET, 7, 6, 5])
    st = np.asarray(args["st"]).copy()
    st[0, 1:] = P.SLEEP_ST                   # hapax waiters park, never spin
    args["st"] = jnp.asarray(st)
    out = lock_transitions_ref(**args)
    st1 = np.asarray(out[0])[0]
    assert st1[3] == P.WAKING, st1           # oldest ticket woken first
    assert st1[1] == P.SLEEP_ST and st1[2] == P.SLEEP_ST


def test_hapax_no_barging_and_never_spins():
    """No-barging fairness (per-thread CS counts within a slot of each
    other) and the constant-time arrival path never burns spin CPU."""
    cfgs = [SimConfig("hapax", threads=t, cores=c, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s)
            for (t, c, s) in ((8, 4, 0), (16, 8, 1), (6, 20, 2))]
    res = xdes.simulate_batch(cfgs, target_cs=120)
    # every CS pays a wake round trip, so the step planner undershoots
    # the target; enough grants still land to read the fairness spread
    assert (res.completed >= 40).all()
    np.testing.assert_array_equal(res.spin_cpu, 0.0)
    for i in range(len(cfgs)):
        assert res.fairness_spread(i) <= 3, (
            i, res.completed_per_thread[i])


def test_hapax_des_grants_in_park_order():
    """DES twin: every grant to a parked waiter follows park order — the
    FIFO admission property, read off the event timeline."""
    from repro.core.des import LockSim

    sim = LockSim("hapax", 8, 4, SHORT, SHORT, WAKE, seed=2,
                  record_timeline=True)
    sim.run(target_cs=200)
    tl = sim.res.timeline
    parked, granted = [], []
    for i, (t, tid, ev) in enumerate(tl):
        nxt = tl[i + 1] if i + 1 < len(tl) else None
        prv = tl[i - 1] if i > 0 else None
        if ev == "arrive" and not (
                nxt and nxt[2] == "cs_start" and nxt[1] == tid
                and nxt[0] == t):
            parked.append(tid)               # contended arrival -> queue
        elif ev == "cs_start" and not (
                prv and prv[2] == "arrive" and prv[1] == tid
                and prv[0] == t):
            granted.append(tid)              # grant to a parked waiter
    assert len(parked) >= 50                 # the lock is actually contended
    assert granted == parked[:len(granted)]


def test_ttas_backoff_seed_determinism_and_no_sleeps():
    """Same seed -> bit-identical engine run; different salt-stream seed
    -> a different trajectory; the row never parks."""
    mk = lambda seed: [SimConfig("ttas_backoff", threads=8, cores=4,
                                 cs=SHORT, ncs=SHORT, wake_latency=WAKE,
                                 seed=seed)]
    a = xdes.simulate_batch(mk(7), n_steps=400)
    b = xdes.simulate_batch(mk(7), n_steps=400)
    c = xdes.simulate_batch(mk(8), n_steps=400)
    np.testing.assert_array_equal(a.completed, b.completed)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu)
    assert not (np.array_equal(a.completed_per_thread,
                               c.completed_per_thread)
                and np.array_equal(a.spin_cpu, c.spin_cpu))
    assert a.wake_count[0] == 0              # never parks


def test_ttas_backoff_delay_is_bounded():
    """A failed poll reschedules at most ``spin_budget * 2^BO_CAP``
    ahead, however large the attempt counter already is."""
    import jax.numpy as jnp

    from repro.kernels.ref import NO_TICKET, lock_transitions_ref

    budget = 2e-6
    args = _one_step_state(P.TTAS_BACKOFF, [NO_TICKET, 3, 10, 50])
    rem = np.asarray(args["rem"]).copy()
    rem[0, 0] = 1.0                          # holder keeps the CS: polls fail
    args["rem"] = jnp.asarray(rem)
    args["wake_at"] = jnp.zeros((1, 4), jnp.float32)   # all polls due now
    out = lock_transitions_ref(**args)
    st1 = np.asarray(out[0])[0]
    wa1 = np.asarray(out[2])[0]
    tk1 = np.asarray(out[6])[0]
    now = float(np.asarray(args["now2"])[0])
    dt = float(np.asarray(args["dt"])[0])
    assert (st1[1:] == P.SPIN).all()         # stayed runnable, no parks
    assert (tk1[1:] == np.array([4, 11, 51])).all()    # attempts increment
    assert (wa1[1:] > now).all()
    assert (wa1[1:] <= now + dt + budget * 2.0 ** P.BO_CAP).all()


def test_fissile_budget_monotone_in_park_cost_and_sws():
    """The fissile spin budget is ``spin_budget * sws * park_cost`` — the
    spin-for-about-a-park-round-trip rule — checked on the engine's
    arrival re-arm and the DES model, monotone along both axes."""
    import jax.numpy as jnp

    from repro.core.des import LockSim
    from repro.kernels.ref import NO_TICKET, lock_transitions_ref

    budget = 2e-6
    prev = 0.0
    for sws, pc in ((1, 1.0), (2, 1.0), (2, 8.0), (4, 64.0)):
        args = _one_step_state(P.FISSILE, [NO_TICKET] * 4)
        rem = np.asarray(args["rem"]).copy()
        rem[0, 0] = 1.0                      # holder busy: arrival must spin
        st = np.asarray(args["st"]).copy()
        st[0, 1] = P.NCS                     # thread 1 arrives this step
        rem[0, 1] = 0.0
        st[0, 2:] = P.NCS                    # keep the rest out of the way
        rem[0, 2:] = 1.0
        args["st"], args["rem"] = jnp.asarray(st), jnp.asarray(rem)
        args["sws"] = jnp.full((1,), sws, jnp.int32)
        args["park_cost"] = jnp.full((1,), pc, jnp.float32)
        out = lock_transitions_ref(**args)
        st1 = np.asarray(out[0])[0]
        rem1 = np.asarray(out[1])[0]
        assert st1[1] == P.SPIN
        want = np.float32(budget) * np.float32(sws) * np.float32(pc)
        np.testing.assert_allclose(rem1[1], want, rtol=1e-6)
        assert rem1[1] > prev
        prev = rem1[1]
    # DES twin exposes the same rule
    sims = [LockSim("fissile", 4, 4, SHORT, SHORT, WAKE, seed=0,
                    park_cost=pc) for pc in (0.1, 1.0, 10.0)]
    budgets = [s.model._budget() for s in sims]
    assert budgets == sorted(budgets) and budgets[0] < budgets[-1]
    sims[1].model.sws = 4
    assert sims[1].model._budget() == pytest.approx(4 * budgets[1])


def test_fissile_parks_less_as_parking_gets_expensive():
    """Behavioral consequence of the scaled budget: at park_cost=100 the
    fissile lock parks far less often than at park_cost=1 (same seeds),
    in both engines."""
    from repro.core.des import simulate

    def engine_wakes(pc):
        cfgs = [SimConfig("fissile", threads=8, cores=4, cs=SHORT,
                          ncs=SHORT, wake_latency=WAKE, seed=s,
                          park_cost=pc) for s in range(3)]
        return int(xdes.simulate_batch(cfgs, n_steps=2000).wake_count.sum())

    def des_wakes(pc):
        return sum(simulate("fissile", threads=8, cores=4, cs=SHORT,
                            ncs=SHORT, wake_latency=WAKE, target_cs=400,
                            seed=s, park_cost=pc).wake_count
                   for s in range(3))

    assert engine_wakes(100.0) < 0.5 * engine_wakes(1.0)
    assert des_wakes(100.0) < 0.5 * des_wakes(1.0)


@pytest.mark.parametrize("lock", ["fissile", "hapax", "ttas_backoff"])
def test_new_rows_des_parity_seed_averaged(lock):
    """Each new row's DES twin and the batched engine agree on
    throughput within the standard band, averaged over seeds, across
    subscription levels and the park-cost axis."""
    for tc, pc in ((4, 1.0), (12, 1.0), (12, 8.0)):
        seeds = (0, 1, 2)
        d = float(np.mean([
            simulate(lock, threads=tc, cores=8, cs=SHORT, ncs=SHORT,
                     wake_latency=WAKE, target_cs=400, seed=s,
                     park_cost=pc).throughput
            for s in seeds]))
        cfgs = [SimConfig(lock, threads=tc, cores=8, cs=SHORT, ncs=SHORT,
                          wake_latency=WAKE, seed=s, park_cost=pc)
                for s in seeds]
        x = float(np.mean(xdes.simulate_batch(cfgs,
                                              target_cs=150).throughput))
        assert 0.7 * d < x < 1.4 * d, (lock, tc, pc, x, d)


# --------------------------------------------------------------------------
# Hypothesis property suite for the new rows (skipped without hypothesis)
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                          # dev-only dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(threads=hst.integers(2, 10), cores=hst.integers(1, 16),
           seed=hst.integers(0, 2**16))
    def test_prop_hapax_fifo_admission(threads, cores, seed):
        from repro.core.des import LockSim

        sim = LockSim("hapax", threads, cores, SHORT, SHORT, WAKE,
                      seed=seed, record_timeline=True)
        res = sim.run(target_cs=60)
        assert res.completed_cs >= 60
        tl = sim.res.timeline
        parked, granted = [], []
        for i, (t, tid, ev) in enumerate(tl):
            nxt = tl[i + 1] if i + 1 < len(tl) else None
            prv = tl[i - 1] if i > 0 else None
            if ev == "arrive" and not (
                    nxt and nxt[2] == "cs_start" and nxt[1] == tid
                    and nxt[0] == t):
                parked.append(tid)
            elif ev == "cs_start" and not (
                    prv and prv[2] == "arrive" and prv[1] == tid
                    and prv[0] == t):
                granted.append(tid)
        assert granted == parked[:len(granted)]

    @settings(max_examples=20, deadline=None)
    @given(threads=hst.integers(2, 10), cores=hst.integers(1, 16),
           seed=hst.integers(0, 2**16))
    def test_prop_ttas_backoff_deterministic_never_sleeps(threads, cores,
                                                          seed):
        from repro.core.des import simulate

        a = simulate("ttas_backoff", threads=threads, cores=cores,
                     cs=SHORT, ncs=SHORT, wake_latency=WAKE,
                     target_cs=60, seed=seed)
        b = simulate("ttas_backoff", threads=threads, cores=cores,
                     cs=SHORT, ncs=SHORT, wake_latency=WAKE,
                     target_cs=60, seed=seed)
        assert a.completed_cs == b.completed_cs >= 60
        assert a.t_end == b.t_end and a.spin_cpu == b.spin_cpu
        assert a.wake_count == 0

    @settings(max_examples=20, deadline=None)
    @given(sws=hst.integers(1, 32),
           costs=hst.lists(hst.floats(0.01, 1000.0), min_size=2,
                           max_size=5, unique=True))
    def test_prop_fissile_budget_monotone(sws, costs):
        from repro.core.des import LockSim

        budgets = []
        for pc in sorted(costs):
            sim = LockSim("fissile", 4, 4, SHORT, SHORT, WAKE,
                          park_cost=pc)
            sim.model.sws = sws
            budgets.append(sim.model._budget())
        assert budgets == sorted(budgets)
        assert budgets[0] < budgets[-1]

"""Workload-row registry: registry/encoding semantics, randomized
xdes-vs-DES parity per workload row, ref-vs-Pallas bit-identity on the
workload-aware kernel body (per-step and blocked), seeded determinism of
the arrival-order randomization (incl. under sharding), and the workload
sweep / serve-scenario plumbing."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import policy as P
from repro.core import xdes
from repro.core.des import simulate
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
WAKE = 8e-6
WORKLOADS = ["constant", "bursty", "hetero", "jitter"]


# --------------------------------------------------------------------------
# Registry + encoding
# --------------------------------------------------------------------------
def test_workload_registry():
    assert sorted(P.WORKLOAD_IDS) == sorted(WORKLOADS)
    assert all(P.WORKLOAD_ROWS[n].wid == i
               for n, i in P.WORKLOAD_IDS.items())
    assert P.WORKLOAD_ROWS["bursty"].time_varying == 1
    assert P.WORKLOAD_ROWS["constant"].time_varying == 0


def test_workload_hold_scalar_semantics():
    # constant: the base draw, untouched
    assert P.workload_hold(P.WL_CONSTANT, 1, 2.0, 9.0, 1.0, 3.0, 8.0) == 2.0
    # bursty: OFF-phase NCS stretched by burst, CS and ON-phase untouched
    assert P.workload_hold(P.WL_BURSTY, 1, 2.0, 9.0, 1.0, 3.0, 8.0) == 16.0
    assert P.workload_hold(P.WL_BURSTY, 1, 2.0, 9.0, 0.0, 3.0, 8.0) == 2.0
    assert P.workload_hold(P.WL_BURSTY, 0, 2.0, 9.0, 1.0, 3.0, 8.0) == 2.0
    # hetero: both kinds scaled by the thread factor
    assert P.workload_hold(P.WL_HETERO, 0, 2.0, 9.0, 1.0, 3.0, 8.0) == 6.0
    # jitter: NCS takes the exponential deviate, CS the uniform
    assert P.workload_hold(P.WL_JITTER, 1, 2.0, 9.0, 1.0, 3.0, 8.0) == 9.0
    assert P.workload_hold(P.WL_JITTER, 0, 2.0, 9.0, 1.0, 3.0, 8.0) == 2.0


def test_workload_off_gate_and_scale():
    # duty 0.25: first quarter of the cycle is ON
    assert P.workload_off_gate(0.0, 0.1, 1.0, 0.25) == 0.0
    assert P.workload_off_gate(0.0, 0.6, 1.0, 0.25) == 1.0
    assert P.workload_off_gate(0.5, 0.6, 1.0, 0.25) == 0.0   # wrapped
    s = P.workload_thread_scale(0.0, 4.0)
    assert s == pytest.approx(0.25)
    assert P.workload_thread_scale(1.0, 4.0) == pytest.approx(4.0)
    assert P.workload_thread_scale(0.5, 4.0) == pytest.approx(1.0)


def test_sim_config_validates_and_encodes_workload():
    cfgs = [SimConfig("mutable", threads=2, cores=2, cs=SHORT, ncs=SHORT,
                      workload=w, arrival_phase=0.5) for w in WORKLOADS]
    arrs = P.encode_configs(cfgs)
    assert arrs["workload"].tolist() == [P.WORKLOAD_IDS[w]
                                         for w in WORKLOADS]
    assert arrs["arrival_phase"].tolist() == [np.float32(0.5)] * 4
    with pytest.raises(ValueError):
        SimConfig("mutable", threads=2, cores=2, cs=SHORT, ncs=SHORT,
                  workload="nope")
    with pytest.raises(ValueError):
        SimConfig("mutable", threads=2, cores=2, cs=SHORT, ncs=SHORT,
                  wl_duty=0.0)
    with pytest.raises(ValueError):
        SimConfig("mutable", threads=2, cores=2, cs=SHORT, ncs=SHORT,
                  arrival_phase=-1.0)


def test_counter_uniform_scalar_matches_kernel_hash():
    import jax.numpy as jnp

    from repro.kernels.ref import counter_uniform

    for seed in (0, 7, 123456, 2**31 + 5):
        for tid in (0, 1, 17):
            a = P.counter_uniform_scalar(seed ^ P.WL_PHASE_SALT, tid)
            b = float(counter_uniform(
                jnp.uint32(seed ^ P.WL_PHASE_SALT), jnp.int32(tid),
                jnp.uint32(0)))
            assert a == pytest.approx(b, abs=1e-7)


def test_workload_draw_finite_at_u_one():
    """counter_uniform's float32 cast rounds the top uint32 values to
    u == 1.0 (~6e-8 per draw); the exponential deviate must clamp so no
    row's dispatch sees inf/NaN (0.0 * inf poisons the masked select)."""
    import jax.numpy as jnp

    from repro.kernels.ref import workload_draw

    f = jnp.float32
    for wid in range(len(P.WORKLOAD_ROWS)):
        for is_ncs in (0, 1):
            v = workload_draw(f(1.0), f(0.0), f(3.7e-6), is_ncs,
                              jnp.int32(wid), f(1.0), f(2.0), f(8.0))
            assert np.isfinite(float(v)), (wid, is_ncs, float(v))


def test_plan_schedule_corrects_horizon_for_workload():
    """A bursty row's effective arrival gap is duty + (1-duty)*burst of
    the base (6.25x at the defaults), so the planner must size its
    horizon accordingly — and leave constant plans bit-identical."""
    base = SimConfig("ttas", threads=2, cores=8, cs=SHORT, ncs=SHORT,
                     wake_latency=WAKE)
    burst = SimConfig("ttas", threads=2, cores=8, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, workload="bursty")
    het = SimConfig("ttas", threads=2, cores=8, cs=SHORT, ncs=SHORT,
                    wake_latency=WAKE, workload="hetero")
    dt, steps = xdes.plan_schedule([base, burst, het], 100)
    assert dt[0] == dt[1] == dt[2]          # dt resolves the BASE scale
    assert steps[1] > 2 * steps[0]          # bursty horizon stretched
    assert steps[2] > steps[0]              # hetero mean scale ~1.35
    # and the corrected horizon actually reaches target_cs
    res = xdes.simulate_batch([burst], target_cs=120)
    assert res.completed[0] >= 120


# --------------------------------------------------------------------------
# Behaviour: rows actually reshape the workload
# --------------------------------------------------------------------------
def test_workload_rows_change_trajectories():
    base = SimConfig("mutable", threads=6, cores=4, cs=SHORT, ncs=SHORT,
                     wake_latency=WAKE, seed=3)
    rc = xdes.simulate_batch([base], n_steps=400)
    for w in ("bursty", "hetero", "jitter"):
        cw = SimConfig("mutable", threads=6, cores=4, cs=SHORT, ncs=SHORT,
                       wake_latency=WAKE, seed=3, workload=w,
                       wl_period=5e-5)
        rw = xdes.simulate_batch([cw], n_steps=400)
        assert (rw.completed[0] != rc.completed[0]
                or not np.array_equal(rw.completed_per_thread,
                                      rc.completed_per_thread)), w


def test_hetero_threads_complete_unevenly():
    """Per-thread scales spread the completed-CS counts far beyond the
    constant row's under a fair (FIFO) lock — heterogeneity is visible in
    who gets work done, not just in totals."""
    mk = lambda w: SimConfig("fifo", threads=8, cores=8, cs=SHORT,
                             ncs=SHORT, wake_latency=WAKE, seed=2,
                             workload=w, wl_spread=8.0)
    rc = xdes.simulate_batch([mk("constant")], target_cs=300)
    rh = xdes.simulate_batch([mk("hetero")], target_cs=300)
    assert rh.fairness_spread(0) > 3 * max(rc.fairness_spread(0), 1)


# --------------------------------------------------------------------------
# xdes vs DES parity per workload row (randomized shapes)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("workload", WORKLOADS)
def test_xdes_vs_des_parity_per_row(workload):
    """Seed-averaged throughput band per (workload, lock) cell: single
    realizations diverge under heterogeneity (the DES hands off to a
    random spinner, xdes to the lowest tid — WHO gets served drives the
    total when thread speeds differ), so the pin is the 3-seed mean.  The
    xdes side runs every (lock, seed) cell of the row in ONE call."""
    rng = np.random.default_rng(P.WORKLOAD_IDS[workload])
    locks = ("ttas", "mutable", "sleep")
    seeds = (0, 1, 2)
    cells = [(lock, int(rng.integers(4, 13)), int(rng.integers(4, 13)))
             for lock in locks]
    cfgs = [SimConfig(lock, threads=tc, cores=cores, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s, workload=workload,
                      wl_period=8e-5)
            for (lock, tc, cores) in cells for s in seeds]
    x = xdes.simulate_batch(cfgs, target_cs=150)
    xthr = x.throughput.reshape(len(cells), len(seeds)).mean(axis=1)
    for i, (lock, tc, cores) in enumerate(cells):
        dthr = np.mean([simulate(
            lock, threads=tc, cores=cores, cs=SHORT, ncs=SHORT,
            wake_latency=WAKE, target_cs=800, seed=s,
            **cfgs[i * len(seeds)].workload_kwargs()).throughput
            for s in seeds])
        assert 0.7 * dthr < xthr[i] < 1.4 * dthr, (
            workload, lock, tc, cores, xthr[i], dthr)


# --------------------------------------------------------------------------
# ref vs Pallas bit-identity on the workload-aware kernel body
# --------------------------------------------------------------------------
def _workload_batch(seed=0):
    """Every workload row x several disciplines/oracles, random shapes —
    the randomized parity surface for the new kernel body."""
    rng = np.random.default_rng(seed)
    cfgs = []
    for w in WORKLOADS:
        for lock, oracle in (("mutable", "paper"), ("mutable", "history"),
                             ("ttas", "paper"), ("fifo", "paper"),
                             ("sleep", "paper"), ("adaptive", "paper")):
            cfgs.append(SimConfig(
                lock, threads=int(rng.integers(2, 10)),
                cores=int(rng.integers(2, 10)), cs=SHORT, ncs=SHORT,
                wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
                oracle=oracle, workload=w, wl_period=5e-5,
                wl_duty=float(rng.uniform(0.1, 0.9)),
                wl_burst=float(rng.uniform(1, 12)),
                wl_spread=float(rng.uniform(1, 6)),
                arrival_phase=float(rng.uniform(0, 2))))
    return cfgs


def _assert_results_equal(a, b, msg=""):
    np.testing.assert_array_equal(a.completed, b.completed, err_msg=msg)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread, err_msg=msg)
    np.testing.assert_array_equal(a.wake_count, b.wake_count, err_msg=msg)
    np.testing.assert_array_equal(a.final_sws, b.final_sws, err_msg=msg)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu, err_msg=msg)


def test_workload_ref_vs_pallas_per_step():
    cfgs = _workload_batch(seed=11)
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                              backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                              backend="pallas")
    _assert_results_equal(ref, pal, "per-step")


@pytest.mark.parametrize("block_steps", [1, 32])
def test_workload_ref_vs_pallas_blocked(block_steps):
    cfgs = _workload_batch(seed=13)
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="pallas")
    _assert_results_equal(ref, pal, f"blocked B={block_steps}")
    scan = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                               backend="ref")
    _assert_results_equal(ref, scan, f"blocked==scan B={block_steps}")


# --------------------------------------------------------------------------
# Arrival-order randomization: seeded, deterministic, effective
# --------------------------------------------------------------------------
def test_arrival_phase_seeded_determinism():
    mk = lambda seed: SimConfig("ttas", threads=6, cores=4, cs=SHORT,
                                ncs=SHORT, wake_latency=WAKE, seed=seed,
                                arrival_phase=2.0)
    a = xdes.simulate_batch([mk(1)], n_steps=300)
    b = xdes.simulate_batch([mk(1)], n_steps=300)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread)
    # a different seed realizes a different arrival order
    c = xdes.simulate_batch([mk(2)], n_steps=300)
    assert not np.array_equal(a.completed_per_thread,
                              c.completed_per_thread)
    # and the offset actually changes the tid-order tie-break
    z = xdes.simulate_batch(
        [SimConfig("ttas", threads=6, cores=4, cs=SHORT, ncs=SHORT,
                   wake_latency=WAKE, seed=1)], n_steps=300)
    assert not np.array_equal(a.completed_per_thread,
                              z.completed_per_thread)


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
from repro.core import xdes
from repro.core.policy import SimConfig

assert len(jax.devices()) == 4
SHORT = (0.0, 3.7e-6)
cfgs = [SimConfig(l, threads=6, cores=4, cs=SHORT, ncs=SHORT,
                  wake_latency=8e-6, seed=i, workload=w, wl_period=5e-5,
                  arrival_phase=1.5)
        for i, (l, w) in enumerate(
            [("ttas", "bursty"), ("mutable", "hetero"),
             ("sleep", "jitter"), ("fifo", "bursty"),
             ("adaptive", "jitter"), ("mutable", "constant")])]
r1 = xdes.simulate_batch(cfgs, n_steps=300, shard=False)
r2 = xdes.simulate_batch(cfgs, n_steps=300, shard=True)  # pad 6 -> 8
for f in ("completed", "final_sws", "wake_count", "completed_per_thread",
          "spin_cpu"):
    np.testing.assert_array_equal(getattr(r1, f), getattr(r2, f), err_msg=f)
r3 = xdes.simulate_batch(cfgs, n_steps=300, shard=True)
np.testing.assert_array_equal(r2.completed_per_thread,
                              r3.completed_per_thread)
print("WORKLOAD-SHARD-OK", r1.completed.tolist())
"""


def test_workload_arrival_randomization_deterministic_under_sharding():
    """Workload rows + arrival_phase under a 4-device mesh: sharded ==
    unsharded bit-for-bit and repeat runs identical (the seeded-
    determinism contract).  Subprocess because the device count locks at
    first backend init (same pattern as test_disciplines.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH")]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "WORKLOAD-SHARD-OK" in proc.stdout


# --------------------------------------------------------------------------
# Sweep + serve plumbing
# --------------------------------------------------------------------------
def test_workload_sweep_catalog_shape():
    from repro.configs.catalog import (LOCK_WORKLOADS,
                                       lock_discipline_variants,
                                       lock_workload_sweep,
                                       lock_workload_variants)

    disc = lock_discipline_variants()
    variants = lock_workload_variants()
    assert len(variants) == len(LOCK_WORKLOADS) * len(disc)
    cfgs = lock_workload_sweep(n_scenarios=3)
    assert len(cfgs) == 3 * len(variants)
    B = len(variants)
    for s in range(3):
        block = cfgs[s * B:(s + 1) * B]
        # scenario-major: every row of the block shares its machine
        assert len({(c.threads, c.cores, c.cs, c.wake_latency)
                    for c in block}) == 1
        # workload-major within the block, disciplines minor
        assert [c.workload for c in block] == [
            w for w in LOCK_WORKLOADS for _ in disc]
        assert [(c.lock, c.oracle) for c in block[:len(disc)]] == [
            (v["lock"], v["oracle"]) for v in disc]
        # the bursty cycle is scenario-scaled
        assert block[0].wl_period == pytest.approx(
            16.0 * (block[0].cs[1] + block[0].ncs[1]))


def test_sched_scenario_workload_rows():
    from repro.serve import SchedScenario, sample_sched_scenarios

    sc = SchedScenario(slots=8, requests=20, decode_s=0.05, think_s=0.1,
                       prefill_s=0.01, seed=3, workload="bursty")
    c = sc.to_sim_config("mutable")
    assert c.workload == "bursty"
    assert c.wl_period == pytest.approx(8.0 * (0.05 + 0.1))
    # bursty sampling sees the same machines as the constant sweep
    base = sample_sched_scenarios(6)
    burst = sample_sched_scenarios(6, workload="bursty")
    for a, b in zip(base, burst):
        assert (a.slots, a.requests, a.decode_s, a.think_s) == \
            (b.slots, b.requests, b.decode_s, b.think_s)
        assert b.workload == "bursty"


def test_workload_grid_smoke():
    from benchmarks.sweep import workload_grid
    from repro.configs.catalog import lock_discipline_variants

    out = workload_grid(n_scenarios=4, target_cs=25, verbose=False)
    assert out["meta"]["n_configs"] == \
        4 * 4 * len(lock_discipline_variants())
    assert set(out["workloads"]) == set(WORKLOADS)
    for w, rows in out["workloads"].items():
        assert sum(r["wins"] for r in rows.values()) == 4, w
    assert all(0 < c["win_share"] <= 1 for c in out["phase"])

"""Property-based open-loop invariants (hypothesis): for ANY arrival
row, lock discipline, offered rate, queue bound and seed —

  * Little's law, sharp form: 0 <= occ_int - lat_sum <= in_flight * t_end
    (requests are counted in the occupancy integral for exactly their
    sojourn-so-far, up to float32 accumulation),
  * conservation: arrived == shed + departed + in_flight, exactly,
  * queue bound: in-flight occupancy never exceeds queue_cap + threads,
  * histogram totals: the latency histogram holds every departure.

The deterministic fixed-example twins of these checks live in
tests/test_open_loop.py (``check_open_invariants`` is shared)."""

import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dependency (requirements-dev.txt)")

from hypothesis import given, settings, strategies as st

from repro.core import xdes

# tests/ is not a package: pytest's rootdir import mode puts this
# directory on sys.path, so the shared helpers import flat.
from test_open_loop import OPEN_ROWS, check_open_invariants, open_cfg

LOCKS = ["tas", "ttas", "mcs", "sleep", "adaptive", "mutable", "fifo"]


@settings(max_examples=20, deadline=None)
@given(arrival=st.sampled_from(OPEN_ROWS),
       lock=st.sampled_from(LOCKS),
       rate=st.floats(min_value=1e4, max_value=2e6),
       threads=st.integers(min_value=1, max_value=10),
       cores=st.integers(min_value=1, max_value=10),
       queue_cap=st.integers(min_value=1, max_value=128),
       duty=st.floats(min_value=0.05, max_value=0.95),
       burst=st.floats(min_value=1.0, max_value=16.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_open_loop_invariants_hold(arrival, lock, rate, threads, cores,
                                   queue_cap, duty, burst, seed):
    cfg = open_cfg(lock, arrival=arrival, rate=rate, seed=seed,
                   threads=threads, cores=cores, queue_cap=queue_cap,
                   wl_duty=duty, wl_burst=burst)
    res = xdes.simulate_batch([cfg], n_steps=1024, dt=5e-8)
    check_open_invariants(res, 0, cfg)


@settings(max_examples=10, deadline=None)
@given(rate=st.floats(min_value=5e4, max_value=5e5),
       seed=st.integers(min_value=0, max_value=1000))
def test_littles_law_band_poisson(rate, seed):
    """L = lambda * W as a band under stable-ish Poisson traffic: the
    time-averaged occupancy brackets the departure-weighted sojourn (the
    gap is exactly the still-in-flight boundary term)."""
    cfg = open_cfg("mutable", rate=rate, seed=seed, threads=6, cores=6)
    res = xdes.simulate_batch([cfg], n_steps=8192, dt=5e-8)
    if int(res.departed[0]) < 20:
        return                      # too few departures to average
    L = float(res.occ_int[0]) / float(res.t_end[0])
    lam_w = float(res.lat_sum[0]) / float(res.t_end[0])
    fly = float(res.in_flight[0])
    assert lam_w <= L * (1 + 1e-3) + 1e-9
    assert L <= lam_w * (1 + 1e-3) + fly + 1e-6

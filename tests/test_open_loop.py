"""Open-loop arrival engine: registry/encoding semantics, the exact
Little's-law + conservation invariants behind the on-device accounting,
queue-bound/shedding behaviour, zero-arrival bit-identity to the closed
engine, ref-vs-Pallas and blocked-vs-scan bit-identity (histograms
included), same-seed latency-percentile determinism (the CI check),
streamed-vs-one-shot identity through the open summary columns, the
seeded randomized tie-break, refine_grid boundary-cell coverage, and the
arrival sweep / serve plumbing.  Randomized-input variants of the
invariants live in tests/test_open_loop_props.py (hypothesis)."""

from dataclasses import replace

import numpy as np
import pytest

from repro.core import policy as P
from repro.core import stream as xstream
from repro.core import xdes
from repro.core.des import LockSim
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
WAKE = 8e-6
OPEN_ROWS = ["poisson", "bursty"]


def open_cfg(lock="mutable", arrival="poisson", rate=2e5, seed=0,
             threads=4, cores=4, **kw) -> SimConfig:
    kw.setdefault("wl_period", 8e-5)
    return SimConfig(lock, threads=threads, cores=cores, cs=SHORT,
                     ncs=SHORT, wake_latency=WAKE, seed=seed,
                     arrival=arrival, arrival_rate=rate, **kw)


def check_open_invariants(res, i, cfg, rtol=1e-3, atol=1e-6):
    """The exact per-config open-loop accounting contract:

    * conservation: arrived == shed + departed + in_flight (integers),
    * occupancy bound: in_flight <= queue_cap + threads (the ring buffer
      plus one bound request per simulated thread),
    * Little's law, sharp form: requests are counted in the occupancy
      integral for exactly their sojourn-so-far, so
      ``0 <= occ_int - lat_sum <= in_flight * t_end`` up to float32
      accumulation error,
    * histogram totals: the latency histogram holds every departure.
    """
    arrived = int(res.arrived[i])
    shed = int(res.shed[i])
    departed = int(res.departed[i])
    fly = int(res.in_flight[i])
    assert arrived - shed - departed - fly == 0, (i, cfg.lock)
    assert 0 <= fly <= cfg.queue_cap + cfg.threads, (i, cfg.lock)
    assert 0 <= shed <= arrived
    assert int(res.slo_viol[i]) <= departed
    assert int(res.lat_hist[i].sum()) == departed, (i, cfg.lock)
    occ = float(res.occ_int[i])
    lat = float(res.lat_sum[i])
    slack = rtol * max(occ, lat) + atol
    assert occ - lat >= -slack, (i, cfg.lock, occ, lat)
    assert occ - lat <= fly * float(res.t_end[i]) + slack, (i, cfg.lock)


# --------------------------------------------------------------------------
# Registry + scalar semantics + validation
# --------------------------------------------------------------------------
def test_arrival_registry():
    assert sorted(P.ARRIVAL_IDS) == ["bursty", "closed", "poisson"]
    assert P.ARRIVAL_IDS["closed"] == P.AR_CLOSED == 0
    assert all(P.ARRIVAL_ROWS[n].aid == i
               for n, i in P.ARRIVAL_IDS.items())
    assert P.ARRIVAL_ROWS["bursty"].time_varying == 1
    assert P.ARRIVAL_ROWS["poisson"].time_varying == 0


def test_arrival_rate_scalar_semantics():
    # closed: rate 0 regardless of base; poisson: the base, untouched
    assert P.arrival_rate_at(P.AR_CLOSED, 5e4, 1.0, 8.0) == 0.0
    assert P.arrival_rate_at(P.AR_POISSON, 5e4, 1.0, 8.0) == 5e4
    # bursty: `burst` x base inside the ON window, base outside
    assert P.arrival_rate_at(P.AR_BURSTY, 5e4, 1.0, 8.0) == 4e5
    assert P.arrival_rate_at(P.AR_BURSTY, 5e4, 0.0, 8.0) == 5e4
    # time-averaged multipliers (saturation math + DES twin share these)
    assert P.arrival_mean_scale(P.AR_CLOSED, 0.25, 8.0) == 0.0
    assert P.arrival_mean_scale(P.AR_POISSON, 0.25, 8.0) == 1.0
    assert P.arrival_mean_scale(P.AR_BURSTY, 0.25, 8.0) == \
        pytest.approx(1.0 + 0.25 * 7.0)


def test_latency_histogram_readout():
    edges = P.latency_bin_edges()
    assert len(edges) == P.LAT_NBINS + 1
    assert edges[0] == P.LAT_BIN0
    np.testing.assert_allclose(edges[1:] / edges[:-1], np.sqrt(2.0))
    # nearest-rank readout at geometric bin midpoints
    hist = np.zeros((1, P.LAT_NBINS), np.int32)
    hist[0, 10] = 50
    hist[0, 20] = 50
    p50, p95, p99 = P.latency_percentiles(hist)
    assert p50[0] == pytest.approx(np.sqrt(edges[10] * edges[11]))
    assert p95[0] == p99[0] == pytest.approx(np.sqrt(edges[20] * edges[21]))
    # empty histogram reads NaN
    assert np.isnan(P.latency_percentiles(np.zeros((1, P.LAT_NBINS)))[0][0])


def test_sim_config_validates_open_fields():
    c = open_cfg(rate=1e5, queue_cap=17, slo=5e-4)
    assert c.open_loop and not open_cfg(arrival="closed", rate=0.0).open_loop
    assert c.arrival_kwargs() == dict(arrival="poisson", arrival_rate=1e5,
                                      queue_cap=17)
    with pytest.raises(ValueError):
        open_cfg(arrival="nope")
    with pytest.raises(ValueError):
        open_cfg(rate=-1.0)
    with pytest.raises(ValueError):
        open_cfg(queue_cap=0)
    with pytest.raises(ValueError):
        open_cfg(queue_cap=P.QUEUE_MAX + 1)
    with pytest.raises(ValueError):
        open_cfg(slo=0.0)
    with pytest.raises(ValueError):
        open_cfg(tie_break="coin")
    arrs = P.encode_configs([c])
    assert arrs["arrival"][0] == P.AR_POISSON
    assert arrs["q_cap"][0] == 17
    assert arrs["tb"][0] == P.TIE_BREAK_IDS["id"]


# --------------------------------------------------------------------------
# Exact invariants: Little's law, conservation, queue bound
# --------------------------------------------------------------------------
def _invariant_batch(seed=0):
    rng = np.random.default_rng(seed)
    cfgs = []
    for arrival in OPEN_ROWS:
        for lock in ("ttas", "mutable", "sleep", "fifo"):
            cfgs.append(open_cfg(
                lock, arrival=arrival,
                rate=float(rng.uniform(5e4, 8e5)),
                seed=int(rng.integers(0, 1000)),
                threads=int(rng.integers(2, 8)),
                cores=int(rng.integers(2, 8)),
                queue_cap=int(rng.integers(4, 64)),
                slo=float(rng.uniform(1e-5, 1e-3))))
    return cfgs


def test_littles_law_exact_invariant():
    """One batched call over both arrival rows x several locks at random
    rates spanning under- to over-saturation; every config must satisfy
    the sharp Little's-law inequality and exact request conservation."""
    cfgs = _invariant_batch(seed=1)
    res = xdes.simulate_batch(cfgs, n_steps=4000, dt=5e-8)
    assert int(np.asarray(res.arrived).sum()) > 0
    assert int(np.asarray(res.departed).sum()) > 0
    for i, c in enumerate(cfgs):
        check_open_invariants(res, i, c)


def test_littles_law_band():
    """L = lambda * W as a band on a long stable run: the occupancy
    integral over the horizon must agree with the departure rate times
    the mean sojourn within the dt-fidelity band (the boundary term —
    still-in-flight requests — is small when the system is stable)."""
    cfgs = [open_cfg(lock, rate=2e5, seed=3)
            for lock in ("ttas", "mutable", "sleep")]
    res = xdes.simulate_batch(cfgs, n_steps=40000, dt=5e-8)
    for i in range(len(cfgs)):
        assert res.departed[i] > 100
        L = float(res.occ_int[i]) / float(res.t_end[i])
        lam_w = float(res.lat_sum[i]) / float(res.t_end[i])
        assert lam_w <= L * (1 + 1e-3) + 1e-9
        assert L < 1.6 * lam_w + 0.1, (i, L, lam_w)


def test_queue_bound_and_shedding():
    """Offered load far past saturation with a tiny queue: the bound is
    never exceeded (in_flight <= cap + threads) and the overflow is shed,
    not lost — conservation still holds exactly."""
    cfgs = [open_cfg("ttas", rate=5e6, queue_cap=8, seed=s)
            for s in range(3)]
    res = xdes.simulate_batch(cfgs, n_steps=3000, dt=5e-8)
    for i, c in enumerate(cfgs):
        check_open_invariants(res, i, c)
        assert int(res.shed[i]) > 0, "saturated tiny queue must shed"


# --------------------------------------------------------------------------
# Zero-arrival row == closed-loop engine, bit for bit
# --------------------------------------------------------------------------
def test_zero_arrival_bit_identical_to_closed():
    """Forcing the open-loop machinery onto an all-closed batch must not
    move a single bit of the closed outputs — the closed row admits
    nothing, so the OPEN_STATE arrays stay inert."""
    cfgs = [SimConfig(lock, threads=5, cores=4, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s)
            for s, lock in enumerate(("ttas", "mutable", "sleep", "fifo"))]
    closed = xdes.simulate_batch(cfgs, n_steps=300)
    forced = xdes.simulate_batch(cfgs, n_steps=300, open_loop=True)
    assert closed.lat_hist is None and forced.lat_hist is not None
    for f in ("completed", "completed_per_thread", "wake_count",
              "final_sws", "spin_cpu"):
        np.testing.assert_array_equal(getattr(closed, f),
                                      getattr(forced, f), err_msg=f)
    assert int(np.asarray(forced.arrived).sum()) == 0
    assert int(np.asarray(forced.lat_hist).sum()) == 0
    assert int(np.asarray(forced.in_flight).sum()) == 0


# --------------------------------------------------------------------------
# ref vs Pallas / blocked vs scan bit-identity, histograms included
# --------------------------------------------------------------------------
def _parity_batch(seed=17):
    rng = np.random.default_rng(seed)
    cfgs = []
    for arrival in OPEN_ROWS:
        for lock, tb in (("mutable", "id"), ("mutable", "random"),
                         ("ttas", "id"), ("ttas", "random"),
                         ("sleep", "id"), ("fifo", "random"),
                         ("adaptive", "id")):
            cfgs.append(open_cfg(
                lock, arrival=arrival,
                rate=float(rng.uniform(5e4, 6e5)),
                seed=int(rng.integers(0, 1000)),
                threads=int(rng.integers(2, 9)),
                cores=int(rng.integers(2, 9)),
                queue_cap=int(rng.integers(4, 32)),
                wl_duty=float(rng.uniform(0.1, 0.9)),
                wl_burst=float(rng.uniform(1, 10)),
                tie_break=tb))
    return cfgs


def _assert_open_equal(a, b, msg=""):
    for f in ("completed", "completed_per_thread", "wake_count",
              "final_sws", "spin_cpu", "lat_hist", "arrived", "shed",
              "departed", "slo_viol", "lat_sum", "occ_int", "in_flight"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: {f}")


@pytest.mark.parametrize("block_steps", [1, 32])
def test_open_ref_vs_pallas_blocked(block_steps):
    cfgs = _parity_batch()
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps, backend="pallas")
    _assert_open_equal(ref, pal, f"ref==pallas B={block_steps}")
    scan = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                               backend="ref")
    _assert_open_equal(ref, scan, f"blocked==scan B={block_steps}")


def test_latency_percentile_determinism():
    """Same seed => identical on-device histograms and identical
    p50/p95/p99, across separate calls (the CI determinism check)."""
    cfgs = _parity_batch(seed=23)
    a = xdes.simulate_batch(cfgs, n_steps=300)
    b = xdes.simulate_batch(cfgs, n_steps=300)
    np.testing.assert_array_equal(a.lat_hist, b.lat_hist)
    np.testing.assert_array_equal(a.latency_quantiles(),
                                  b.latency_quantiles())
    np.testing.assert_array_equal(np.asarray(a.slo_frac),
                                  np.asarray(b.slo_frac), err_msg="slo")
    # a different seed realizes a different arrival stream
    c = xdes.simulate_batch([replace(cfgs[0], seed=cfgs[0].seed + 1)],
                            n_steps=300)
    assert not np.array_equal(c.lat_hist[0], a.lat_hist[0])


# --------------------------------------------------------------------------
# Streamed == one-shot through the open summary columns
# --------------------------------------------------------------------------
def test_streamed_open_loop_matches_one_shot():
    cfgs = _parity_batch(seed=5)
    one = xdes.simulate_batch(cfgs, n_steps=250, keep_per_thread=False)
    s = xstream.sweep_stream(cfgs, n_steps=250, chunk=4)
    assert s.n_chunks > 1
    for f in ("completed", "lat_hist", "arrived", "shed", "departed",
              "slo_viol", "lat_sum", "occ_int", "in_flight"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s, f)), np.asarray(getattr(one, f)),
            err_msg=f"stream: {f}")
    np.testing.assert_array_equal(s.latency_quantiles(),
                                  one.latency_quantiles())


# --------------------------------------------------------------------------
# DES parity per arrival row (the event-driven twin)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arrival", OPEN_ROWS)
def test_xdes_vs_des_open_loop_parity(arrival):
    """Seed-averaged departure throughput AND mean sojourn per arrival
    row: the fixed-increment engine vs the event-driven DES twin over the
    same horizon.  Single realizations see different arrival streams
    (counter RNG vs thinning), so the pin is the 3-seed mean in a
    [0.7, 1.4] band — the same fidelity contract as the workload rows."""
    seeds = (0, 1, 2)
    cfgs = [open_cfg("ttas", arrival=arrival, rate=2e5, seed=s,
                     wl_period=4e-4, wl_burst=4.0)
            for s in seeds]
    res = xdes.simulate_batch(cfgs, n_steps=40000, dt=5e-8)
    t_end = float(res.t_end[0])
    x_thr = float(np.asarray(res.departed).mean()) / t_end
    x_lat = float(np.nanmean(np.asarray(res.mean_latency)))

    d_thr, d_lat = [], []
    for s in seeds:
        sim = LockSim("ttas", 4, 4, SHORT, SHORT, WAKE, seed=s,
                      wl_period=4e-4, wl_burst=4.0,
                      **cfgs[0].arrival_kwargs())
        r = sim.run(target_cs=10**9, horizon=t_end)
        assert len(r.latencies) > 50
        d_thr.append(len(r.latencies) / t_end)
        d_lat.append(r.mean_latency)
    d_thr, d_lat = np.mean(d_thr), np.mean(d_lat)
    assert 0.7 * d_thr < x_thr < 1.4 * d_thr, (arrival, x_thr, d_thr)
    assert 0.7 * d_lat < x_lat < 1.4 * d_lat, (arrival, x_lat, d_lat)


# --------------------------------------------------------------------------
# Randomized same-step tie-break (satellite: DES-fidelity fix)
# --------------------------------------------------------------------------
def test_tie_break_registry_and_default():
    assert P.TIE_BREAK_IDS == {"id": 0, "random": 1}
    assert SimConfig("ttas", threads=2, cores=2, cs=SHORT,
                     ncs=SHORT).tie_break == "id"


def test_tie_break_id_is_the_default_bit_for_bit():
    """tie_break="id" must be byte-identical to a config that never
    mentions the field — the pre-tie-break engine behaviour is the
    default, so every committed artifact stays reproducible."""
    base = [SimConfig(lock, threads=6, cores=6, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s)
            for s, lock in enumerate(("tas", "ttas", "mutable", "fifo"))]
    a = xdes.simulate_batch(base, n_steps=400)
    b = xdes.simulate_batch([replace(c, tie_break="id") for c in base],
                            n_steps=400)
    for f in ("completed", "completed_per_thread", "wake_count",
              "final_sws", "spin_cpu"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
    # ... while "random" actually moves the handoff order
    c = xdes.simulate_batch([replace(x, tie_break="random")
                             for x in base], n_steps=400)
    assert not np.array_equal(a.completed_per_thread,
                              c.completed_per_thread)


def test_tie_break_random_ref_vs_pallas():
    cfgs = [SimConfig(lock, threads=7, cores=7, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=s, tie_break="random")
            for s, lock in enumerate(("tas", "ttas", "mutable",
                                      "adaptive", "sleep"))]
    ref = xdes.simulate_batch(cfgs, n_steps=300, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=300, backend="pallas")
    for f in ("completed", "completed_per_thread", "wake_count",
              "final_sws", "spin_cpu"):
        np.testing.assert_array_equal(getattr(ref, f), getattr(pal, f),
                                      err_msg=f)
    # seeded: repeat runs identical
    again = xdes.simulate_batch(cfgs, n_steps=300, backend="ref")
    np.testing.assert_array_equal(ref.completed_per_thread,
                                  again.completed_per_thread)


def test_tie_break_random_fixes_tas_starvation():
    """The deterministic lowest-tid tie-break systematically starves high
    tids under barging locks (the DES resolves such ties by RNG).  The
    randomized tie-break must collapse that artificial spread."""
    mk = lambda tb: SimConfig("tas", threads=8, cores=8, cs=SHORT,
                              ncs=SHORT, wake_latency=WAKE, seed=4,
                              tie_break=tb)
    rid = xdes.simulate_batch([mk("id")], target_cs=300)
    rnd = xdes.simulate_batch([mk("random")], target_cs=300)
    assert rnd.fairness_spread(0) < 0.5 * rid.fairness_spread(0), (
        rid.fairness_spread(0), rnd.fairness_spread(0))


def test_discipline_diagram_byte_identical_at_id_tie_break(tmp_path):
    """The discipline phase diagram predates the tie-break; "id" is the
    default and executes the exact pre-tie-break code path (pinned
    bit-for-bit above), so regenerating ``discipline_phase_diagram.csv``
    must be byte-for-byte reproducible — the artifact cannot drift just
    because the tie-break machinery landed.  (reports/ itself is
    gitignored, so the check regenerates at smoke scale rather than
    hashing a checked-in file.)"""
    from benchmarks.discipline_diagram import write_phase_diagram
    from benchmarks.sweep import discipline_grid

    blobs = []
    for sub in ("a", "b"):
        res = discipline_grid(n_scenarios=2, target_cs=25, verbose=False)
        csv_path, _ = write_phase_diagram(res, str(tmp_path / sub))
        blobs.append(open(csv_path, "rb").read())
    assert blobs[0] == blobs[1]
    assert blobs[0].startswith(b"cs,subscription")


# --------------------------------------------------------------------------
# refine_grid boundary-cell coverage (satellite)
# --------------------------------------------------------------------------
def _refine_case(**kw):
    from benchmarks.sweep import refine_grid

    kw.setdefault("nx", 5)
    kw.setdefault("ny", 3)
    kw.setdefault("factor", 2)
    kw.setdefault("target_cs", 25)
    kw.setdefault("verbose", False)
    return refine_grid(**kw)


def test_refine_grid_matches_dense_on_boundary_points():
    """Every dense point refine_grid reports must carry the same winner a
    brute-force dense run reports at that exact lattice point, and the
    reported point set must be EXACTLY the dense points whose enclosing
    coarse cell touches a phase boundary — interior cells never re-run."""
    from benchmarks.sweep import (LOCK_CORES, LOCK_SHORT, LOCK_WAKE,
                                  _product_columns,
                                  lock_discipline_variants)

    out = _refine_case(disciplines=("ttas", "sleep", "mutable"),
                       oracles=("paper",))
    nx, ny, factor = (out["meta"][k] for k in ("nx", "ny", "factor"))
    names = out["meta"]["variant_names"]
    grid = np.array([[names.index(w) for w in row]
                     for row in out["coarse"]])

    boundary = np.zeros((ny, nx), bool)
    boundary[:, 1:] |= grid[:, 1:] != grid[:, :-1]
    boundary[:, :-1] |= grid[:, 1:] != grid[:, :-1]
    boundary[1:, :] |= grid[1:, :] != grid[:-1, :]
    boundary[:-1, :] |= grid[1:, :] != grid[:-1, :]

    cs_coarse = np.array(out["axes"]["cs_us"]) * 1e-6
    th_coarse = np.array(out["axes"]["threads"])
    cs_dense = np.geomspace(1e-6, 4e-4, factor * nx)
    th_dense = np.unique(np.rint(np.linspace(2, 32, factor * ny))
                         .astype(np.int64))
    ix = np.clip(np.searchsorted(np.sqrt(cs_coarse[1:] * cs_coarse[:-1]),
                                 cs_dense), 0, nx - 1)
    iy = np.clip(np.searchsorted((th_coarse[1:] + th_coarse[:-1]) / 2.0,
                                 th_dense), 0, ny - 1)

    expected = {(round(float(c) * 1e6, 4), int(t))
                for j, t in enumerate(th_dense)
                for i, c in enumerate(cs_dense)
                if boundary[iy[j], ix[i]]}
    reported = {(d["cs_us"], d["threads"]) for d in out["dense"]}
    assert reported == expected      # no interior point, no missed point
    assert out["meta"]["n_dense"] == len(expected)

    # brute-force the FULL dense lattice and compare winners pointwise
    variants = lock_discipline_variants(("ttas", "sleep", "mutable"),
                                        ("paper",))
    V = len(variants)
    cs, th = np.meshgrid(cs_dense, th_dense)
    cs, th = cs.ravel(), th.ravel()
    Pn = cs.size
    cols = _product_columns(
        {"threads": th.astype(np.int64),
         "cores": np.full(Pn, LOCK_CORES, np.int64),
         "cs_hi": cs.astype(np.float64),
         "ncs_hi": np.full(Pn, LOCK_SHORT[1], np.float64),
         "wake": np.full(Pn, LOCK_WAKE, np.float64),
         "contention": np.ones(Pn, np.float64),
         "seed": np.zeros(Pn, np.int64)}, variants)
    red = xstream.CellReduce(V, np.arange(Pn, dtype=np.int32), Pn)
    res = xstream.sweep_stream(cols, target_cs=25, reduce=red)
    dense_win = {(round(float(c) * 1e6, 4), int(t)): names[w]
                 for c, t, w in zip(cs, th,
                                    np.asarray(res.wins).argmax(axis=1))}
    for d in out["dense"]:
        assert d["winner"] == dense_win[(d["cs_us"], d["threads"])], d


def test_refine_grid_uniform_winner_runs_no_dense_points():
    out = _refine_case(disciplines=("ttas",), oracles=("paper",))
    assert out["meta"]["n_dense"] == 0
    assert out["dense"] == []
    assert out["meta"]["n_configs"] == out["meta"]["n_coarse"]


# --------------------------------------------------------------------------
# Sweep + serve plumbing
# --------------------------------------------------------------------------
def test_arrival_sweep_catalog_shape():
    from repro.configs.catalog import (LOCK_ARRIVAL_RHOS, LOCK_ARRIVALS,
                                       lock_arrival_sweep,
                                       lock_arrival_variants,
                                       lock_discipline_variants)

    disc = lock_discipline_variants()
    variants = lock_arrival_variants()
    assert len(variants) == (len(LOCK_ARRIVALS) * len(LOCK_ARRIVAL_RHOS)
                             * len(disc))
    cfgs = lock_arrival_sweep(n_scenarios=2)
    assert len(cfgs) == 2 * len(variants)
    B = len(variants)
    for s in range(2):
        block = cfgs[s * B:(s + 1) * B]
        assert len({(c.threads, c.cores, c.cs, c.wake_latency)
                    for c in block}) == 1
        assert all(c.open_loop for c in block)
        # arrival-major, rho next, disciplines minor
        assert [c.arrival for c in block] == [
            a for a in LOCK_ARRIVALS
            for _ in LOCK_ARRIVAL_RHOS for _ in disc]
        # capacity: lock-serialization vs thread-turnover bound
        c0 = block[0]
        cs_hi, ncs_hi = c0.cs[1], c0.ncs[1]
        cap = min(1.0 / (0.5 * cs_hi),
                  min(c0.threads, c0.cores) / (0.5 * (cs_hi + ncs_hi)))
        assert c0.arrival_rate == pytest.approx(
            LOCK_ARRIVAL_RHOS[0] * cap)
        assert block[0].slo == pytest.approx(
            4.0 * (block[0].cs[1] + block[0].ncs[1]))


def test_arrival_grid_smoke_and_stream_identity():
    from benchmarks.sweep import arrival_grid

    one = arrival_grid(n_scenarios=2, target_cs=25, verbose=False,
                       stream=False)
    A, R = len(one["meta"]["arrivals"]), len(one["meta"]["rhos"])
    V = one["meta"]["n_variants"]
    assert one["meta"]["n_configs"] == 2 * A * R * V
    assert len(one["phase"]) == A * R
    for cell in one["phase"]:
        assert 0 < cell["win_share"] <= 1
        assert 0 < cell["lat_win_share"] <= 1
        assert 0.0 <= cell["mean_shed_frac"] <= 1.0
    st = arrival_grid(n_scenarios=2, target_cs=25, verbose=False,
                      stream=True, mem_mb=64)
    assert st["meta"]["n_chunks"] >= 1
    assert st["phase"] == one["phase"]
    assert st["variants"] == one["variants"]


def test_sched_scenario_open_loop_rows():
    from repro.serve import SchedScenario, sample_sched_scenarios

    sc = SchedScenario(slots=8, requests=20, decode_s=0.05, think_s=0.1,
                       prefill_s=0.01, seed=3, arrival="poisson",
                       arrival_rate_rps=12.0, slo_s=0.6)
    c = sc.to_sim_config("mutable")
    assert c.open_loop and c.arrival == "poisson"
    assert c.arrival_rate == pytest.approx(12.0)
    assert c.slo == pytest.approx(0.6)
    assert sc.capacity_rps > 0
    # open sampling sees the same machines as the closed sweep, with the
    # offered load tied to each scenario's own capacity
    base = sample_sched_scenarios(6)
    opened = sample_sched_scenarios(6, arrival="poisson")
    for a, b in zip(base, opened):
        assert (a.slots, a.requests, a.decode_s, a.think_s) == \
            (b.slots, b.requests, b.decode_s, b.think_s)
        assert b.arrival == "poisson"
        assert 0.3 * b.capacity_rps <= b.arrival_rate_rps \
            <= 1.2 * b.capacity_rps
        assert b.slo_s == pytest.approx(4.0 * (b.decode_s + b.think_s))


def test_continuous_batcher_sheds_at_queue_cap():
    from repro.serve import ContinuousBatcher, Request, SimulatedEngine

    b = ContinuousBatcher(SimulatedEngine(max_slots=2), queue_cap=2)
    reqs = [Request(rid=i, prompt=[2] * 4, max_new_tokens=2)
            for i in range(4)]
    admitted = [b.submit(r) for r in reqs]
    assert admitted == [True, True, False, False]
    assert b.stats.shed == 2 and b.stats.submitted == 4
    assert b.stats.summary()["shed_rate"] == pytest.approx(0.5)
    # the admitted half still drains to completion
    stats = b.run_until_drained()
    assert stats.completed == 2

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import base as cbase
from repro.configs import catalog
from repro.configs.inputs import concrete_batch

ARCHS = ["gemma3-4b", "llama3.2-1b", "qwen2.5-14b", "stablelm-3b",
         "granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
         "jamba-1.5-large-398b", "chameleon-34b", "rwkv6-1.6b",
         "whisper-large-v3"]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _tiny(arch):
    return catalog.tiny(cbase.get_config(arch))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, key):
    cfg = _tiny(arch)
    params = models.init_params(cfg, key)
    batch = concrete_batch(cfg, batch_size=2, seq_len=16, key=key)
    loss, metrics = models.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    # a plausible CE for random tokens: close to log(vocab)
    assert float(metrics["ce"]) < 2 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite(arch, key):
    cfg = _tiny(arch)
    params = models.init_params(cfg, key)
    batch = concrete_batch(cfg, batch_size=2, seq_len=16, key=key)
    grads = jax.grad(lambda p: models.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """Teacher-forced prefill logits at the last position must match the
    decode-step logits after feeding the same tokens one by one."""
    cfg = _tiny(arch)
    params = models.init_params(cfg, key)
    B, S = 2, 8
    batch = concrete_batch(cfg, batch_size=B, seq_len=S, key=key)
    tokens = batch["tokens"]

    pf_logits, _ = models.prefill(cfg, params, batch)
    assert pf_logits.shape == (B, cfg.vocab_size)

    cache = models.init_cache(cfg, B, max_seq=16)
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        enc_out = encdec.encode(cfg, params, batch["frames"].astype(
            jnp.bfloat16))
        cache["enc_kv"] = encdec.project_enc_kv_stack(cfg, params, enc_out)
    logits = None
    for t in range(S):
        logits, cache = models.decode_step(cfg, params, cache,
                                           tokens[:, t:t + 1])
    np.testing.assert_allclose(
        np.asarray(pf_logits, np.float32), np.asarray(logits, np.float32),
        rtol=0.15, atol=0.15,
        err_msg=f"{arch}: prefill/decode mismatch")


def test_decode_cache_len_tracks():
    cfg = _tiny("llama3.2-1b")
    params = models.init_params(cfg, jax.random.PRNGKey(1))
    cache = models.init_cache(cfg, 2, max_seq=8)
    tok = jnp.ones((2, 1), jnp.int32)
    _, cache = models.decode_step(cfg, params, cache, tok)
    _, cache = models.decode_step(cfg, params, cache, tok)
    assert np.all(np.asarray(cache["len"]) == 2)


def test_param_count_sane():
    # full-config closed-form counts should be in the right ballpark
    for arch, lo, hi in [("llama3.2-1b", 0.9e9, 1.6e9),
                         ("gemma3-4b", 3.0e9, 5.5e9),
                         ("qwen2.5-14b", 12e9, 16e9),
                         ("qwen3-moe-235b-a22b", 200e9, 260e9),
                         ("jamba-1.5-large-398b", 330e9, 440e9)]:
        n = models.param_count(cbase.get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_aux_loss_nonzero():
    cfg = _tiny("qwen3-moe-235b-a22b")
    params = models.init_params(cfg, jax.random.PRNGKey(2))
    batch = concrete_batch(cfg, 2, 16, jax.random.PRNGKey(3))
    _, metrics = models.loss_fn(cfg, params, batch)
    # balanced routing gives aux ~= 1.0 (E * sum f_e * P_e with f=P=1/E)
    assert 0.5 < float(metrics["aux"]) < 4.0


def test_gemma3_window_schedule():
    cfg = cbase.get_config("gemma3-4b")
    from repro.models.transformer import layer_schedules
    win, theta = layer_schedules(cfg)
    win = np.asarray(win).reshape(-1)
    theta = np.asarray(theta).reshape(-1)
    assert win.shape[0] == 34
    # every 6th layer global (window 0, theta 1M)
    assert all(win[i] == 0 for i in range(5, 34, 6))
    assert all(win[i] == 1024 for i in range(34) if i % 6 != 5)
    assert all(theta[i] == 1e6 for i in range(5, 34, 6))

"""Distributed-path tests on a small forced-device-count mesh.

Device count is locked at first backend init, so these run in a
subprocess with ``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cbase
from repro.configs.catalog import tiny
from repro.configs.inputs import concrete_batch
from repro.launch.mesh import make_test_mesh
from repro.sharding import profiles, specs as sh
from repro.train import TrainConfig, init_state, make_train_step

assert len(jax.devices()) == 8

# ---- 1. sharded tiny train step numerically matches single-device ---------
cfg = tiny(cbase.get_config("llama3.2-1b"))
tcfg = TrainConfig(warmup_steps=2, decay_steps=20, seed=0)
batch = concrete_batch(cfg, 8, 32, jax.random.PRNGKey(1))

state0 = init_state(cfg, tcfg, jax.random.PRNGKey(0))
ref_state, ref_metrics = jax.jit(make_train_step(cfg, tcfg))(
    jax.tree.map(lambda x: x, state0), batch)
ref_loss = float(ref_metrics["loss"])

mesh = make_test_mesh(data=2, model=2, pod=2)
rules = profiles.rules_for(cfg, mesh, "train")
state_shape = jax.eval_shape(lambda k: init_state(cfg, tcfg, k),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
shardings = sh.tree_shardings(sh.param_specs(state_shape, mesh, rules), mesh)
step_fn = make_train_step(cfg, tcfg)

def wrapped(s, b):
    with sh.use_mesh(mesh, rules):
        return step_fn(s, b)

jitted = jax.jit(wrapped, in_shardings=(shardings, None),
                 out_shardings=(shardings, None))
state0b = init_state(cfg, tcfg, jax.random.PRNGKey(0))
state0b = jax.device_put(state0b, shardings)
sh_state, sh_metrics = jitted(state0b, batch)
sh_loss = float(sh_metrics["loss"])
assert abs(sh_loss - ref_loss) < 5e-2, (sh_loss, ref_loss)
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(sh_state["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2,
                               rtol=3e-2)
print("SHARDED_TRAIN_OK", sh_loss)

# ---- 2. int8 EF compressed cross-pod grads track uncompressed -------------
tcfg_c = TrainConfig(warmup_steps=2, decay_steps=20, seed=0,
                     dp_compression="int8")
state_c = init_state(cfg, tcfg_c, jax.random.PRNGKey(0))
state_c = jax.device_put(
    state_c, sh.tree_shardings(sh.param_specs(
        jax.eval_shape(lambda k: init_state(cfg, tcfg_c, k),
                       jax.ShapeDtypeStruct((2,), jnp.uint32)),
        mesh, rules), mesh))
step_c = make_train_step(cfg, tcfg_c)

def wrapped_c(s, b):
    with sh.use_mesh(mesh, rules):
        return step_c(s, b)

state_c1, mc = jax.jit(wrapped_c)(state_c, batch)
lc = float(mc["loss"])
assert abs(lc - ref_loss) < 5e-2, (lc, ref_loss)
# parameters after one compressed step stay close to the exact ones
errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(ref_state["params"]),
                        jax.tree.leaves(state_c1["params"]))]
assert max(errs) < 5e-2, max(errs)
print("COMPRESSED_OK", lc, max(errs))

# ---- 3. decode with CP flash-decode matches unsharded ---------------------
from repro import models
params = ref_state["params"]
cache = models.init_cache(cfg, 8, max_seq=16)
tok = jnp.full((8, 1), 3, jnp.int32)
logits_ref, cache_ref = jax.jit(
    lambda p, c, t: models.decode_step(cfg, p, c, t))(params, cache, tok)

srules = profiles.rules_for(cfg, mesh, "decode")
cache_sh = sh.tree_shardings(
    sh.cache_specs(jax.eval_shape(
        lambda: models.init_cache(cfg, 8, 16)), mesh, srules), mesh)
params_sh = sh.tree_shardings(
    sh.param_specs(jax.eval_shape(
        lambda k: models.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32)), mesh, srules), mesh)

def dwrap(p, c, t):
    with sh.use_mesh(mesh, srules):
        return models.decode_step(cfg, p, c, t)

logits_sh, _ = jax.jit(dwrap, in_shardings=(params_sh, cache_sh, None),
                       out_shardings=(None, cache_sh))(
    jax.device_put(params, params_sh),
    jax.device_put(cache, cache_sh), tok)
np.testing.assert_allclose(np.asarray(logits_ref, np.float32),
                           np.asarray(logits_sh, np.float32),
                           atol=5e-2, rtol=5e-2)
print("CP_DECODE_OK")
"""


@pytest.mark.slow
def test_distributed_paths_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "SHARDED_TRAIN_OK" in out.stdout
    assert "COMPRESSED_OK" in out.stdout
    assert "CP_DECODE_OK" in out.stdout

"""The streaming sweep engine (`repro.core.stream`): streamed-vs-one-shot
bit-identity (ref and Pallas, bucketed and sharded), the memory-model
chunk planner's invariants, the array-native config feed against the
per-lambda legacy encoder, and the on-device phase-cell reduction."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import stream as xstream
from repro.core import xdes
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)
WAKE = 8e-6
LOCKS = ["ttas", "mcs", "fifo", "sleep", "adaptive", "mutable"]


def _mixed_batch(n=40, seed=0):
    """Mixed-discipline, mixed-regime batch — heterogeneous enough that
    chunking crosses discipline and shape boundaries."""
    rng = np.random.default_rng(seed)
    return [SimConfig(
        LOCKS[i % len(LOCKS)], threads=int(rng.integers(2, 12)),
        cores=int(rng.integers(2, 12)),
        cs=SHORT if i % 2 else LONG, ncs=SHORT if i % 3 else LONG,
        wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
        oracle=("paper", "aimd", "fixed", "history")[i % 4])
        for i in range(n)]


def _assert_stream_equal(s, b, msg=""):
    """StreamResult == BatchResult(keep_per_thread=False), bit for bit."""
    np.testing.assert_array_equal(s.completed, b.completed, err_msg=msg)
    np.testing.assert_array_equal(s.spin_cpu, b.spin_cpu, err_msg=msg)
    np.testing.assert_array_equal(s.wake_count, b.wake_count, err_msg=msg)
    np.testing.assert_array_equal(s.final_sws, b.final_sws, err_msg=msg)
    np.testing.assert_array_equal(s.t_end, b.t_end, err_msg=msg)
    np.testing.assert_array_equal(s.steps_run, b.steps_run, err_msg=msg)
    np.testing.assert_array_equal(s.fairness, b.fairness, err_msg=msg)
    np.testing.assert_array_equal(s.throughput, b.throughput, err_msg=msg)
    np.testing.assert_array_equal(s.sync_cpu_per_cs, b.sync_cpu_per_cs,
                                  err_msg=msg)


# --------------------------------------------------------------------------
# Streamed == one-shot, bit for bit
# --------------------------------------------------------------------------
def test_streamed_multichunk_matches_one_shot_ref():
    """Pinned horizon, 5 chunks of 8: chunk boundaries must be invisible
    in every summary column."""
    cfgs = _mixed_batch(40)
    one = xdes.simulate_batch(cfgs, n_steps=400, keep_per_thread=False)
    s = xstream.sweep_stream(cfgs, n_steps=400, chunk=8)
    assert s.n_chunks == 5 and s.chunk_size == 8
    _assert_stream_equal(s, one, "multi-chunk ref")


def test_streamed_chunk_size_invariance():
    """Any chunking of the same sweep gives the same bits (pinned
    horizon => early exit off => chunk-invariant by construction)."""
    cfgs = _mixed_batch(24, seed=3)
    base = xstream.sweep_stream(cfgs, n_steps=300, chunk=24)
    for chunk in (4, 8, 12):
        s = xstream.sweep_stream(cfgs, n_steps=300, chunk=chunk)
        np.testing.assert_array_equal(s.completed, base.completed,
                                      err_msg=f"chunk={chunk}")
        np.testing.assert_array_equal(s.spin_cpu, base.spin_cpu,
                                      err_msg=f"chunk={chunk}")
        np.testing.assert_array_equal(s.fairness, base.fairness,
                                      err_msg=f"chunk={chunk}")


def test_streamed_matches_one_shot_pallas():
    cfgs = _mixed_batch(16, seed=1)
    one = xdes.simulate_batch(cfgs, n_steps=200, backend="pallas",
                              keep_per_thread=False)
    s = xstream.sweep_stream(cfgs, n_steps=200, backend="pallas", chunk=4)
    assert s.n_chunks == 4
    _assert_stream_equal(s, one, "multi-chunk pallas")


def test_streamed_bucketed_matches_one_shot():
    """bucket_steps on both sides, early exit pinned off: the bucketed
    streamed sweep regroups rows by horizon AND chunks each bucket, and
    must still land every config's bits in its original slot."""
    cfgs = _mixed_batch(32, seed=2)
    one = xdes.simulate_batch(cfgs, target_cs=20, bucket_steps=True,
                              early_exit=False, keep_per_thread=False)
    s = xstream.sweep_stream(cfgs, target_cs=20, bucket_steps=True,
                             early_exit=False, chunk=8)
    assert s.n_chunks > 1
    _assert_stream_equal(s, one, "bucketed stream")


def test_streamed_single_chunk_early_exit_identity():
    """Auto-planned horizon (early exit ON, like simulate_batch): with
    everything in one chunk the exit step agrees with the one-shot call,
    so even the composition-dependent columns match bit for bit."""
    cfgs = _mixed_batch(16, seed=4)
    one = xdes.simulate_batch(cfgs, target_cs=20, keep_per_thread=False)
    s = xstream.sweep_stream(cfgs, target_cs=20, chunk=16)
    assert s.n_chunks == 1
    _assert_stream_equal(s, one, "single-chunk early exit")


def test_streamed_column_feed_matches_list_feed():
    """RAW column dict in == SimConfig list in, bit for bit."""
    from repro.core.policy import config_columns

    cfgs = _mixed_batch(20, seed=5)
    a = xstream.sweep_stream(cfgs, n_steps=250, chunk=4)
    b = xstream.sweep_stream(config_columns(cfgs), n_steps=250, chunk=4)
    np.testing.assert_array_equal(a.completed, b.completed)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu)
    np.testing.assert_array_equal(a.fairness, b.fairness)


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
from repro.core import stream as xstream
from repro.core import xdes
from repro.core.policy import SimConfig

assert len(jax.devices()) == 4
locks = ["ttas", "fifo", "sleep", "mutable", "adaptive", "mcs"]
cfgs = [SimConfig(l, threads=5, cores=4, cs=(0.0, 3.7e-6),
                  ncs=(0.0, 3.7e-6), wake_latency=8e-6, seed=s)
        for s in range(4) for l in locks]           # 24 configs
one = xdes.simulate_batch(cfgs, n_steps=300, shard=False,
                          keep_per_thread=False)
s = xstream.sweep_stream(cfgs, n_steps=300, shard=True, chunk=8)
assert s.n_chunks == 3 and s.chunk_size == 8
np.testing.assert_array_equal(s.completed, one.completed)
np.testing.assert_array_equal(s.spin_cpu, one.spin_cpu)
np.testing.assert_array_equal(s.final_sws, one.final_sws)
np.testing.assert_array_equal(s.wake_count, one.wake_count)
np.testing.assert_array_equal(s.fairness, one.fairness)
print("STREAM-SHARDED-OK", s.completed[:4].tolist())
"""


def test_streamed_sharded_matches_unsharded():
    """Device count locks at first backend init, so the 4-device mesh
    runs in a subprocess (same pattern as test_distributed.py).  Chunks
    shard over the mesh; the quantum keeps every chunk divisible."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH")]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "STREAM-SHARDED-OK" in proc.stdout


# --------------------------------------------------------------------------
# Chunk planner invariants (property-style sweeps, no hypothesis dep)
# --------------------------------------------------------------------------
def test_plan_chunks_respects_budget():
    """Over a lattice of (C, T, budget, quantum): the planned chunk's
    modelled bytes fit the budget unless the plan bottomed out at one
    quantum — and then a warning says so."""
    import warnings as w

    for C in (64, 1000, 100_000):
        for T in (2, 16, 64):
            for mem_mb in (0.5, 4, 64, 512):
                for quantum in (1, 5, 12):
                    with w.catch_warnings():
                        w.simplefilter("ignore")
                        chunk = xstream.plan_chunks(
                            C, T, mem_mb=mem_mb, quantum=quantum)
                    assert chunk % quantum == 0
                    assert chunk >= quantum
                    if chunk > quantum:
                        assert (chunk * xstream.bytes_per_config(T)
                                <= mem_mb * 2**20), (C, T, mem_mb, quantum)


def test_plan_chunks_never_exceeds_padded_sweep():
    """No point planning chunks bigger than the padded sweep itself."""
    for C in (3, 17, 100, 4096):
        chunk = xstream.plan_chunks(C, 8, mem_mb=10_000, quantum=1)
        assert chunk <= xdes._pad_quantum(C)


def test_plan_chunks_quantum_floor_warns():
    with pytest.warns(UserWarning, match="quantum floor"):
        assert xstream.plan_chunks(100, 64, mem_mb=0.001,
                                   quantum=12) == 12


def test_plan_chunks_rejects_bad_args():
    with pytest.raises(ValueError):
        xstream.plan_chunks(0, 8)
    with pytest.raises(ValueError):
        xstream.plan_chunks(8, 0)
    with pytest.raises(ValueError):
        xstream.plan_chunks(8, 8, quantum=0)


def test_memory_budget_env_override(monkeypatch):
    monkeypatch.setenv(xstream.ENV_MEM_MB, "37")
    assert xstream.memory_budget_bytes() == 37 * 2**20
    # explicit argument beats the env var
    assert xstream.memory_budget_bytes(2) == 2 * 2**20
    monkeypatch.delenv(xstream.ENV_MEM_MB)
    assert xstream.memory_budget_bytes(8.5) == int(8.5 * 2**20)


def test_sweep_stream_rejects_misaligned_chunk():
    cfgs = _mixed_batch(12)
    red = xstream.CellReduce(group=4, cell_ids=np.zeros(3, np.int32),
                             n_cells=1)
    with pytest.raises(ValueError, match="quantum"):
        xstream.sweep_stream(cfgs, n_steps=100, chunk=6, reduce=red)
    with pytest.raises(ValueError, match="multiple of reduce.group"):
        xstream.sweep_stream(_mixed_batch(10), n_steps=100, reduce=red)


# --------------------------------------------------------------------------
# Array-native config feed == SimConfig-list encoder, per catalog family
# --------------------------------------------------------------------------
def test_encode_columns_matches_list_per_family():
    """Every catalog row family: the column twin packs bit-equal engine
    arrays to encoding the equivalent SimConfig list — both through the
    polymorphic ``encode_configs`` front door (the supported path; the
    retired per-lambda encoder keeps exactly one parity pin below)."""
    from repro.configs.catalog import (lock_arrival_columns,
                                       lock_arrival_sweep,
                                       lock_discipline_columns,
                                       lock_discipline_sweep,
                                       lock_fault_columns,
                                       lock_fault_sweep,
                                       lock_oracle_columns,
                                       lock_oracle_sweep,
                                       lock_park_columns, lock_park_sweep,
                                       lock_scenario_columns,
                                       lock_scenario_sweep,
                                       lock_workload_columns,
                                       lock_workload_sweep)
    from repro.core.policy import encode_configs

    pairs = [
        ("scenario", lock_scenario_sweep(n_scenarios=23),
         lock_scenario_columns(n_scenarios=23)),
        ("oracle", lock_oracle_sweep(n_scenarios=7),
         lock_oracle_columns(n_scenarios=7)),
        ("discipline", lock_discipline_sweep(n_scenarios=7),
         lock_discipline_columns(n_scenarios=7)),
        ("workload", lock_workload_sweep(n_scenarios=5),
         lock_workload_columns(n_scenarios=5)),
        ("fault", lock_fault_sweep(n_scenarios=3),
         lock_fault_columns(n_scenarios=3)),
        ("arrival", lock_arrival_sweep(n_scenarios=3),
         lock_arrival_columns(n_scenarios=3)),
        ("park", lock_park_sweep(n_scenarios=2),
         lock_park_columns(n_scenarios=2)),
    ]
    for name, cfgs, cols in pairs:
        from_list = encode_configs(cfgs)
        packed = encode_configs(cols)
        assert set(packed) == set(from_list), name
        for k in packed:
            np.testing.assert_array_equal(packed[k], from_list[k],
                                          err_msg=f"{name}.{k}")
            assert packed[k].dtype == from_list[k].dtype, f"{name}.{k}"


def test_encode_configs_list_matches_legacy():
    """THE legacy-parity pin: the polymorphic front door on a plain
    SimConfig list == the retired per-field lambda table, bit for bit.
    Every other test goes through ``encode_configs``."""
    from repro.core.policy import encode_configs, encode_configs_legacy

    cfgs = _mixed_batch(30, seed=6)
    legacy = encode_configs_legacy(cfgs)
    packed = encode_configs(cfgs)
    for k in packed:
        np.testing.assert_array_equal(packed[k], legacy[k], err_msg=k)


# --------------------------------------------------------------------------
# On-device phase-cell reduction
# --------------------------------------------------------------------------
def test_cell_update_matches_host_argmax():
    """Random throughputs, 3 cells x group of 5, padded groups masked
    with cell id -1: device accumulation == numpy argmax accounting."""
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    group, n_groups, n_cells = 5, 12, 3
    completed = rng.integers(1, 1000,
                             size=n_groups * group).astype(np.int32)
    t_end = np.full(n_groups * group, 0.25, np.float32)
    cell_ids = rng.integers(0, n_cells, size=n_groups).astype(np.int32)
    cell_ids[-2:] = -1                   # two padded groups: ignored

    wins = xstream._cell_update(
        jnp.zeros((n_cells, group), jnp.int32), jnp.asarray(completed),
        jnp.asarray(t_end), jnp.asarray(cell_ids), group=group)
    wins = np.asarray(wins)

    expect = np.zeros((n_cells, group), np.int64)
    thr = (completed / t_end).reshape(n_groups, group)
    for g in range(n_groups):
        if cell_ids[g] >= 0:
            expect[cell_ids[g], thr[g].argmax()] += 1
    np.testing.assert_array_equal(wins, expect)
    assert wins.sum() == (cell_ids >= 0).sum()


def test_cell_reduce_validates():
    with pytest.raises(ValueError):
        xstream.CellReduce(group=0, cell_ids=np.zeros(2, np.int32),
                           n_cells=1)
    with pytest.raises(ValueError):
        xstream.CellReduce(group=2, cell_ids=np.asarray([0, 3], np.int32),
                           n_cells=2)          # cell id out of range


def test_sweep_stream_wins_match_host_fold():
    """End-to-end: the streamed on-device win matrix equals the host
    argmax over the returned throughput columns."""
    cfgs = _mixed_batch(24, seed=8)
    red = xstream.CellReduce(
        group=6, cell_ids=np.asarray([0, 1, 0, 1], np.int32), n_cells=2)
    s = xstream.sweep_stream(cfgs, n_steps=300, chunk=6, reduce=red)
    assert s.n_chunks == 4
    win = s.throughput.reshape(4, 6).argmax(axis=1)
    expect = np.zeros((2, 6), np.int64)
    for g in range(4):
        expect[red.cell_ids[g], win[g]] += 1
    np.testing.assert_array_equal(s.wins, expect)

"""The time-blocked fused rollout: bit-identity against the legacy
per-step scan (ref and Pallas, randomized mixed-discipline batches),
early-exit semantics, step-count bucketing, the step-cap diagnostics, and
the sharded padding path under all of the above."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import xdes
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)
WAKE = 8e-6
LOCKS = ["ttas", "mcs", "fifo", "sleep", "adaptive", "mutable"]
ORACLES = ["paper", "aimd", "fixed", "history"]


def _mixed_batch(seed=0):
    """Every discipline row x several oracle families, shapes and regimes
    mixed, on a deterministic draw — the randomized parity surface."""
    rng = np.random.default_rng(seed)
    cfgs = []
    for i, lock in enumerate(LOCKS):
        for j, oracle in enumerate(ORACLES[:2] if lock != "mutable"
                                   else ORACLES):
            cfgs.append(SimConfig(
                lock, threads=int(rng.integers(2, 12)),
                cores=int(rng.integers(2, 12)),
                cs=SHORT if (i + j) % 2 else LONG,
                ncs=SHORT if j % 2 else LONG,
                wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
                oracle=oracle))
    return cfgs


def _assert_results_equal(a, b, msg=""):
    np.testing.assert_array_equal(a.completed, b.completed, err_msg=msg)
    np.testing.assert_array_equal(a.completed_per_thread,
                                  b.completed_per_thread, err_msg=msg)
    np.testing.assert_array_equal(a.wake_count, b.wake_count, err_msg=msg)
    np.testing.assert_array_equal(a.final_sws, b.final_sws, err_msg=msg)
    np.testing.assert_array_equal(a.spin_cpu, b.spin_cpu, err_msg=msg)
    np.testing.assert_array_equal(a.t_end, b.t_end, err_msg=msg)


# --------------------------------------------------------------------------
# Blocked rollout == per-step scan, bit for bit
# --------------------------------------------------------------------------
def test_block_ref_matches_per_step_composition():
    """lock_sim_block_ref(B) == B manual (advance; transitions) steps on
    random state — the kernel-level parity pin."""
    import jax.numpy as jnp

    from repro.kernels.ref import (NO_TICKET, fault_rewind,
                                   lock_sim_block_ref, lock_sim_step_ref,
                                   lock_transitions_ref)

    rng = np.random.default_rng(7)
    C, T = 17, 9
    ticket = rng.integers(0, 50, (C, T)).astype(np.int32)
    ticket[rng.random((C, T)) < 0.5] = NO_TICKET
    state = [
        rng.integers(0, 6, (C, T)).astype(np.int32),            # st
        rng.uniform(-1e-7, 1e-4, (C, T)).astype(np.float32),    # rem
        rng.uniform(0, 1e-4, (C, T)).astype(np.float32),        # wake_at
        rng.integers(0, 2, (C, T)).astype(np.int32),            # slept
        rng.integers(0, 2, (C, T)).astype(np.int32),            # spun
        rng.integers(0, 1000, (C, T)).astype(np.uint32),        # ctr
        ticket,
        rng.integers(0, 30, (C, T)).astype(np.int32),           # cpt
        rng.integers(1, 9, C).astype(np.int32),                 # sws
        rng.integers(0, 12, C).astype(np.int32),                # cnt
        rng.integers(0, 257, C).astype(np.int32),               # ewma
        rng.integers(-3, 4, C).astype(np.int32),                # wuc
        rng.integers(0, 3, C).astype(np.int32),                 # permits
        np.full(C, 60, np.int32),                               # nticket
        rng.integers(0, 100, C).astype(np.int32),               # completed
        rng.integers(0, 100, C).astype(np.int32),               # wake_count
    ]
    spin_cpu = rng.uniform(0, 1e-3, C).astype(np.float32)
    alpha = rng.uniform(0.0, 0.2, C).astype(np.float32)
    cores = rng.integers(1, 12, C).astype(np.float32)
    has_budget = rng.integers(0, 2, C).astype(bool)
    ctx = (
        rng.integers(0, 10, C).astype(np.int32),                # policy
        rng.integers(1, T + 1, C).astype(np.int32),             # threads
        rng.uniform(1e-8, 1e-6, C).astype(np.float32),          # dt
        np.full(C, WAKE, np.float32),                           # wake
        np.zeros(C, np.float32),                                # cs_lo
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # cs_hi
        np.zeros(C, np.float32),                                # ncs_lo
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # ncs_hi
        rng.integers(1, 31, C).astype(np.int32),                # k
        rng.integers(12, 20, C).astype(np.int32),                # sws_max
        np.full(C, 2e-6, np.float32),                           # spin_budget
        rng.integers(0, 2**31, C).astype(np.uint32),            # seed
        rng.integers(0, 4, C).astype(np.int32),                 # oracle
        rng.integers(0, 4, C).astype(np.int32),                 # workload
        rng.uniform(1e-5, 1e-3, C).astype(np.float32),          # wl_period
        rng.uniform(0.1, 0.9, C).astype(np.float32),            # wl_duty
        rng.uniform(1.0, 16.0, C).astype(np.float32),           # wl_burst
        rng.uniform(1.0, 8.0, C).astype(np.float32),            # wl_spread
        np.zeros(C, np.int32),                                  # arrival
        np.zeros(C, np.float32),                                # arr_rate
        np.full(C, 128, np.int32),                              # q_cap
        np.full(C, 1e-3, np.float32),                           # slo
        rng.integers(0, 2, C).astype(np.int32),                 # tb
        rng.integers(0, 5, C).astype(np.int32),                 # fault
        rng.uniform(0.0, 0.5, C).astype(np.float32),            # flt_rate
        rng.uniform(1e-6, 1e-4, C).astype(np.float32),          # flt_scale
        rng.uniform(0.1, 100.0, C).astype(np.float32),          # park_cost
    )
    dt = ctx[2]
    B, step0 = 5, 11

    got = lock_sim_block_ref(*state, spin_cpu, step0, alpha, cores,
                             has_budget, *ctx, n_sub_steps=B)

    want, cpu = list(state), jnp.asarray(spin_cpu)
    for s in range(B):
        now2 = (jnp.int32(step0 + s).astype(jnp.float32) + 1.0) * dt
        rem, burn = lock_sim_step_ref(want[0], want[1], alpha, cores, dt,
                                      has_budget)
        rem = fault_rewind(want[0], rem, alpha, cores, dt,
                           jnp.int32(step0 + s).astype(jnp.float32) * dt,
                           ctx[11], *ctx[23:26])
        want = list(lock_transitions_ref(want[0], rem, *want[2:], now2,
                                         jnp.int32(step0 + s), *ctx))
        cpu = cpu + burn
    for name, a, b in zip(("st rem wake_at slept spun ctr ticket cpt sws "
                           "cnt ewma wuc permits nticket completed "
                           "wake_count").split(), got[:16], want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(got[16]), np.asarray(cpu),
                                  err_msg="spin_cpu")


@pytest.mark.parametrize("block_steps", [1, 7, 32, 512])
def test_blocked_rollout_bit_identical_to_scan(block_steps):
    cfgs = _mixed_batch()
    scan = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan")
    blk = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=block_steps)
    _assert_results_equal(scan, blk, f"block_steps={block_steps}")
    assert (blk.steps_run == 260).all()     # pinned horizon: no early exit


def test_blocked_pallas_bit_identical_to_ref():
    cfgs = _mixed_batch(seed=3)
    ref = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=32, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=260, rollout="blocked",
                              block_steps=32, backend="pallas")
    _assert_results_equal(ref, pal)
    scan = xdes.simulate_batch(cfgs, n_steps=260, rollout="scan",
                               backend="pallas")
    _assert_results_equal(scan, pal)


def test_block_kernel_handles_nonmultiple_blocks():
    """C not a multiple of block_configs, T not a multiple of the lane
    width — the padding path of the fused Pallas block kernel."""
    cfgs = [SimConfig("mutable", threads=t, cores=5, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=t) for t in (2, 3, 5, 9, 11)]
    ref = xdes.simulate_batch(cfgs, n_steps=200, backend="ref")
    pal = xdes.simulate_batch(cfgs, n_steps=200, backend="pallas")
    _assert_results_equal(ref, pal)


# --------------------------------------------------------------------------
# Early exit
# --------------------------------------------------------------------------
def test_early_exit_stops_early_and_matches_scan_prefix():
    cfgs = [SimConfig(lock, threads=4, cores=8, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=i)
            for i, lock in enumerate(LOCKS)]
    res = xdes.simulate_batch(cfgs, target_cs=50)
    assert (res.completed >= 50).all()
    assert (res.steps_run == res.steps_run[0]).all()
    executed = int(res.steps_run[0])
    assert executed < res.n_steps       # the planning margin was skipped
    # the early-exited state IS the scan state at the executed step count
    prefix = xdes.simulate_batch(cfgs, n_steps=executed, rollout="scan",
                                 dt=res.dt)
    _assert_results_equal(res, prefix)


def test_explicit_n_steps_disables_early_exit():
    cfgs = [SimConfig("ttas", threads=4, cores=8, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE)]
    res = xdes.simulate_batch(cfgs, n_steps=400, target_cs=5)
    assert (res.steps_run == 400).all()
    # ... unless explicitly re-enabled
    res2 = xdes.simulate_batch(cfgs, n_steps=400, target_cs=5,
                               early_exit=True)
    assert (res2.steps_run < 400).all() and (res2.completed >= 5).all()


def test_early_exit_never_fires_when_targets_not_reached():
    """A contended cell that cannot reach target_cs keeps the whole batch
    running to the planned horizon — exactly the fixed-horizon result, so
    phase-diagram artifacts are unchanged by the default early exit."""
    cfgs = [SimConfig("ttas", threads=20, cores=2, cs=LONG, ncs=SHORT,
                      wake_latency=WAKE, alpha=0.1, seed=0),
            SimConfig("sleep", threads=4, cores=8, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=1)]
    res = xdes.simulate_batch(cfgs, target_cs=2000)
    full = xdes.simulate_batch(cfgs, target_cs=2000, early_exit=False)
    assert (res.steps_run == res.n_steps).all()
    _assert_results_equal(res, full)


# --------------------------------------------------------------------------
# Bucketing
# --------------------------------------------------------------------------
def test_bucketed_matches_per_bucket_direct_runs():
    rng = np.random.default_rng(5)
    cfgs = [SimConfig("mutable", threads=int(rng.integers(2, 8)), cores=6,
                      cs=(0.0, float(hi)), ncs=(0.0, float(hi)),
                      wake_latency=WAKE, seed=i)
            for i, hi in enumerate(
                np.exp(rng.uniform(np.log(1e-6), np.log(4e-4), 12)))]
    res = xdes.simulate_batch(cfgs, target_cs=40, bucket_steps=True)
    _, steps = xdes.plan_schedule(cfgs, 40)
    buckets = xdes.plan_buckets(steps)
    assert len(buckets) > 1
    T = max(c.threads for c in cfgs)
    for idx in buckets:
        sub = xdes.simulate_batch([cfgs[i] for i in idx], target_cs=40,
                                  max_threads=T)
        np.testing.assert_array_equal(res.completed[idx], sub.completed)
        np.testing.assert_array_equal(res.spin_cpu[idx], sub.spin_cpu)
        np.testing.assert_array_equal(res.completed_per_thread[idx],
                                      sub.completed_per_thread)
        np.testing.assert_array_equal(res.t_end[idx], sub.t_end)
        np.testing.assert_array_equal(res.steps_run[idx], sub.steps_run)
    # every cell fully sampled, none pinned to the slowest cell's horizon
    assert (res.completed >= 40).all()
    assert res.steps_run.max() > 2 * res.steps_run.min()


def test_bucket_plan_shape():
    steps = np.asarray([100, 120, 250, 4000, 90, 4099])
    buckets = xdes.plan_buckets(steps)
    got = sorted(tuple(int(i) for i in b) for b in buckets)
    assert got == [(0, 1, 4), (2,), (3,), (5,)]


# --------------------------------------------------------------------------
# Step-cap diagnostics
# --------------------------------------------------------------------------
def test_step_cap_warning_names_offenders():
    cfgs = [SimConfig("sleep", threads=4, cores=8, cs=(0.0, 1.0),
                      ncs=(0.0, 1.0), wake_latency=1e-6, seed=0),
            SimConfig("ttas", threads=4, cores=8, cs=SHORT, ncs=SHORT,
                      wake_latency=WAKE, seed=1)]
    with pytest.warns(UserWarning) as rec:
        res = xdes.simulate_batch(cfgs, target_cs=300, n_steps=None,
                                  early_exit=False, block_steps=2048,
                                  max_threads=4)
    msg = "\n".join(str(w.message) for w in rec)
    assert "1/2 configs" in msg                     # how many truncated
    assert "worst offender is config 0" in msg      # and which one
    assert "sleep" in msg and "threads=4" in msg
    assert res.n_steps == xdes.MAX_STEPS


# --------------------------------------------------------------------------
# Sharded padding path: C % n_dev != 0 under blocked + early-exit +
# bucketed rollouts, bit-identical to shard=False (subprocess mesh, same
# pattern as tests/test_disciplines.py).
# --------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
from repro.core import xdes
from repro.core.policy import SimConfig

assert len(jax.devices()) == 4
SHORT = (0.0, 3.7e-6)
locks = ["ttas", "fifo", "sleep", "mutable", "adaptive", "mcs"]

# 6 rows pad to 8: pinned horizon, blocked rollout
cfgs = [SimConfig(l, threads=5, cores=4, cs=SHORT, ncs=SHORT,
                  wake_latency=8e-6, seed=i) for i, l in enumerate(locks)]
r1 = xdes.simulate_batch(cfgs, n_steps=300, shard=False)
r2 = xdes.simulate_batch(cfgs, n_steps=300, shard=True)
for f in ("completed", "final_sws", "wake_count", "completed_per_thread",
          "spin_cpu", "t_end", "steps_run"):
    np.testing.assert_array_equal(getattr(r1, f), getattr(r2, f), err_msg=f)

# early exit: the exit decision must be agreed across shards (psum), so
# the executed step count — and every value — matches unsharded exactly
cfgs = [SimConfig(l, threads=4, cores=8, cs=SHORT, ncs=SHORT,
                  wake_latency=8e-6, seed=i) for i, l in enumerate(locks)]
e1 = xdes.simulate_batch(cfgs, target_cs=50, shard=False)
e2 = xdes.simulate_batch(cfgs, target_cs=50, shard=True)
assert (e1.steps_run < e1.n_steps).all(), "early exit should fire"
for f in ("completed", "final_sws", "wake_count", "completed_per_thread",
          "spin_cpu", "t_end", "steps_run"):
    np.testing.assert_array_equal(getattr(e1, f), getattr(e2, f), err_msg=f)

# bucketed + sharded: each bucket pads independently (sizes 3 and 3)
rng = np.random.default_rng(2)
het = [SimConfig("mutable", threads=5, cores=4, cs=(0.0, float(hi)),
                 ncs=(0.0, float(hi)), wake_latency=8e-6, seed=i)
       for i, hi in enumerate([3e-6, 2e-4, 5e-6, 3e-4, 8e-6, 1.5e-4])]
b1 = xdes.simulate_batch(het, target_cs=40, bucket_steps=True, shard=False)
b2 = xdes.simulate_batch(het, target_cs=40, bucket_steps=True, shard=True)
assert len(set(b1.steps_run.tolist())) > 1, "expected >1 bucket"
for f in ("completed", "final_sws", "wake_count", "completed_per_thread",
          "spin_cpu", "t_end", "steps_run"):
    np.testing.assert_array_equal(getattr(b1, f), getattr(b2, f), err_msg=f)
print("SHARDED-BLOCKED-OK", r1.completed.tolist(), int(e1.steps_run[0]))
"""


def test_sharded_padding_blocked_early_exit_bucketed():
    """Device count is locked at first backend init, so the 4-device mesh
    runs in a subprocess (same pattern as test_distributed.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, ["src", env.get("PYTHONPATH")]))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED-BLOCKED-OK" in proc.stdout


# --------------------------------------------------------------------------
# Phase-diagram invariance: the new default path (blocked + early exit)
# reproduces the fixed-horizon scan values on the discipline grid, so
# regenerating reports/discipline_phase_diagram.csv changes nothing.
# --------------------------------------------------------------------------
def test_discipline_grid_values_unchanged_by_default_path():
    from repro.configs.catalog import lock_discipline_sweep

    cfgs = lock_discipline_sweep(n_scenarios=6)
    new = xdes.simulate_batch(cfgs, target_cs=25)
    legacy = xdes.simulate_batch(cfgs, target_cs=25, rollout="scan",
                                 early_exit=False)
    _assert_results_equal(new, legacy)

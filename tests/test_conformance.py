"""Auto-enumerating cross-registry conformance suite.

Every test matrix here is parameterized FROM the registries in
``repro.core.policy`` (``POLICY_IDS`` / ``DISCIPLINE_ROWS`` /
``WORKLOAD_ROWS`` / ``ARRIVAL_ROWS`` / ``FAULT_ROWS``) at import time —
never from a hand-kept list — so a newly registered discipline,
workload, arrival, or fault row joins the conformance matrix by virtue
of being registered, and a row missing its kernel/DES/alpha plumbing
fails loudly here instead of silently shrinking coverage.

What the matrix pins, for every enumerated combination:

* ref == Pallas bit-identity, on the per-step scan AND the fused
  blocked rollout at B in {1, 32} (`docs/disciplines.md`),
* :meth:`BatchResult.validate` — no non-finite leaks anywhere in the
  cross product,
* conservation: global completed-CS equals the per-thread ledger sum,
  and (open-loop rows) arrived == shed + departed + in_flight with the
  sharp Little's-law bound on the occupancy integral.
"""

import numpy as np
import pytest

from repro.core import policy as P
from repro.core import xdes
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 80e-6)
WAKE = 8e-6

# -- enumerated from the registries at import time ------------------------
LOCKS = sorted(P.POLICY_IDS)                     # every policy id
WORKLOADS = list(P.WORKLOAD_ROWS)
ARRIVALS = list(P.ARRIVAL_ROWS)
OPEN_ARRIVALS = [a for a in ARRIVALS if P.ARRIVAL_IDS[a] != P.AR_CLOSED]
FAULTS = list(P.FAULT_ROWS)
PARK_COSTS = (0.25, 1.0, 16.0)                   # M:N environment axis

ROLLOUTS = {
    "scan": dict(rollout="scan"),
    "blocked-1": dict(rollout="blocked", block_steps=1),
    "blocked-32": dict(rollout="blocked", block_steps=32),
}


def test_registry_closure():
    """The four registries are dense, named, and mutually consistent:
    every policy id belongs to exactly one discipline row, and every
    lock has a DEFAULT_ALPHA entry and a DES model twin."""
    from repro.core.des import _MODELS

    covered = [pid for row in P.DISCIPLINE_ROWS.values()
               for pid in row.policy_ids]
    assert sorted(covered) == sorted(P.POLICY_IDS.values())
    assert len(covered) == len(set(covered))     # a partition, no overlap
    assert sorted(P.POLICY_IDS.values()) == list(range(len(P.POLICY_IDS)))
    assert all(P.POLICY_NAMES[i] == n for n, i in P.POLICY_IDS.items())
    assert set(P.DEFAULT_ALPHA) == set(P.POLICY_IDS)
    assert set(_MODELS) == set(P.POLICY_IDS)
    for ids in (P.WORKLOAD_IDS, P.ARRIVAL_IDS, P.FAULT_IDS):
        assert sorted(ids.values()) == list(range(len(ids)))


# -------------------------------------------------------------------------
# The closed-loop matrix: lock x workload x fault, park_cost riding along
# -------------------------------------------------------------------------
def _closed_configs():
    rng = np.random.default_rng(0)
    cfgs = []
    for lock in LOCKS:
        for w in WORKLOADS:
            for flt in FAULTS:
                i = len(cfgs)
                cfgs.append(SimConfig(
                    lock, threads=int(rng.integers(2, 9)),
                    cores=int(rng.integers(2, 9)),
                    cs=SHORT if i % 2 else LONG, ncs=SHORT,
                    wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
                    workload=w,
                    fault=flt, fault_rate=0.0 if flt == "none" else 0.25,
                    park_cost=PARK_COSTS[i % len(PARK_COSTS)]))
    return cfgs


@pytest.fixture(scope="module")
def closed_matrix():
    cfgs = _closed_configs()
    runs = {(rk, backend): xdes.simulate_batch(cfgs, n_steps=220,
                                               backend=backend, **kw)
            for rk, kw in ROLLOUTS.items() for backend in ("ref", "pallas")}
    return cfgs, runs


def _assert_equal(a, b, fields, msg=""):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}: {f}")


CLOSED_FIELDS = ("completed", "completed_per_thread", "wake_count",
                 "final_sws", "spin_cpu", "t_end")


@pytest.mark.parametrize("rollout", list(ROLLOUTS))
def test_closed_matrix_ref_pallas_bit_identity(closed_matrix, rollout):
    cfgs, runs = closed_matrix
    _assert_equal(runs[rollout, "ref"], runs[rollout, "pallas"],
                  CLOSED_FIELDS, f"ref==pallas {rollout}")


@pytest.mark.parametrize("rollout", [k for k in ROLLOUTS if k != "scan"])
def test_closed_matrix_blocked_equals_scan(closed_matrix, rollout):
    cfgs, runs = closed_matrix
    _assert_equal(runs["scan", "ref"], runs[rollout, "ref"],
                  CLOSED_FIELDS, f"scan=={rollout}")


def test_closed_matrix_validates_and_conserves(closed_matrix):
    cfgs, runs = closed_matrix
    res = runs["scan", "ref"].validate("conformance matrix")
    per = np.asarray(res.completed_per_thread, np.int64)
    for i, c in enumerate(cfgs):
        assert per[i, c.threads:].sum() == 0, (i, c.lock)   # padded lanes
        assert per[i].sum() == int(res.completed[i]), (i, c.lock)
    # the matrix actually exercises the machine: most cells complete CSes
    assert (res.completed > 0).mean() > 0.9


# -------------------------------------------------------------------------
# The open-loop matrix: lock x open arrival rows
# -------------------------------------------------------------------------
def _open_configs():
    rng = np.random.default_rng(1)
    cfgs = []
    for lock in LOCKS:
        for a in OPEN_ARRIVALS:
            cfgs.append(SimConfig(
                lock, threads=int(rng.integers(2, 9)),
                cores=int(rng.integers(2, 9)), cs=SHORT, ncs=SHORT,
                wake_latency=WAKE, seed=int(rng.integers(0, 1000)),
                arrival=a, arrival_rate=float(rng.uniform(5e4, 6e5)),
                queue_cap=int(rng.integers(4, 32)),
                park_cost=PARK_COSTS[len(cfgs) % len(PARK_COSTS)]))
    return cfgs


OPEN_FIELDS = CLOSED_FIELDS + ("lat_hist", "arrived", "shed", "departed",
                               "slo_viol", "lat_sum", "occ_int",
                               "in_flight")


@pytest.fixture(scope="module")
def open_matrix():
    cfgs = _open_configs()
    runs = {(rk, backend): xdes.simulate_batch(cfgs, n_steps=260,
                                               backend=backend, **kw)
            for rk, kw in ROLLOUTS.items() for backend in ("ref", "pallas")}
    return cfgs, runs


@pytest.mark.parametrize("rollout", list(ROLLOUTS))
def test_open_matrix_ref_pallas_bit_identity(open_matrix, rollout):
    cfgs, runs = open_matrix
    _assert_equal(runs[rollout, "ref"], runs[rollout, "pallas"],
                  OPEN_FIELDS, f"ref==pallas {rollout}")
    _assert_equal(runs["scan", "ref"], runs[rollout, "ref"],
                  OPEN_FIELDS, f"scan=={rollout}")


def test_open_matrix_validates_and_conserves(open_matrix):
    """Request conservation + the sharp Little's-law bound (the same
    contract as tests/test_open_loop.py) across the whole matrix."""
    cfgs, runs = open_matrix
    res = runs["scan", "ref"].validate("open conformance matrix")
    assert int(np.asarray(res.arrived).sum()) > 0
    for i, c in enumerate(cfgs):
        arrived, shed = int(res.arrived[i]), int(res.shed[i])
        departed, fly = int(res.departed[i]), int(res.in_flight[i])
        assert arrived - shed - departed - fly == 0, (i, c.lock)
        assert 0 <= fly <= c.queue_cap + c.threads, (i, c.lock)
        assert int(res.lat_hist[i].sum()) == departed, (i, c.lock)
        occ, lat = float(res.occ_int[i]), float(res.lat_sum[i])
        slack = 1e-3 * max(occ, lat) + 1e-6
        assert occ - lat >= -slack, (i, c.lock)
        assert occ - lat <= fly * float(res.t_end[i]) + slack, (i, c.lock)

"""Data pipeline: sharded synthetic corpus + MutableLock'd prefetch."""

from .pipeline import DataConfig, PrefetchLoader, SyntheticCorpus

__all__ = ["DataConfig", "SyntheticCorpus", "PrefetchLoader"]

"""Data pipeline: sharded synthetic corpus + background prefetch threads.

This is one of the host-side subsystems that uses the paper's lock directly
(DESIGN.md §3.1).  Producers tokenize/pack batches on worker threads and
push into a bounded buffer; the trainer thread pops.  The buffer is guarded
by a :class:`~repro.core.mutlock.MutableLock` — handoffs are µs-scale when
the buffer is warm (spin pays off) and ms-scale when producers hit (possibly
slow, GIL-releasing) sources (sleep pays off): exactly the mixed regime the
mutable lock self-tunes for.  The *depth* of the prefetch buffer is itself a
spinning window: prefetched batches are "hot spinners" (RAM resident, zero
latency), a trainer arriving at an empty buffer is a "late wake-up" that
doubles the target depth, K clean gets shrink it by 1.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import MutableLock, MutableWait
from repro.core.window import SpinningWindow


# --------------------------------------------------------------------------
# Deterministic synthetic corpus, shardable by (host, worker)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    host_count: int = 1
    host_id: int = 0
    seed: int = 0
    pack_docs: bool = True      # emulate doc packing with EOS resets
    eos_id: int = 1


class SyntheticCorpus:
    """Deterministic per-(shard, step) token batches — same stream on every
    re-run/restart, so checkpoint-resume is reproducible bit-for-bit."""

    def __init__(self, dcfg: DataConfig):
        self.dcfg = dcfg
        assert dcfg.global_batch % dcfg.host_count == 0
        self.local_batch = dcfg.global_batch // dcfg.host_count

    def batch_at(self, step: int) -> dict:
        d = self.dcfg
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, d.host_id, step]))
        toks = rng.integers(2, d.vocab_size,
                            size=(self.local_batch, d.seq_len + 1),
                            dtype=np.int32)
        if d.pack_docs:
            # sprinkle EOS to emulate packed document boundaries
            doc_mask = rng.random((self.local_batch, d.seq_len + 1)) < 1 / 512
            toks = np.where(doc_mask, d.eos_id, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# --------------------------------------------------------------------------
# Prefetching loader
# --------------------------------------------------------------------------
class PrefetchLoader:
    """Bounded prefetch buffer with MutableLock'd handoff and window-tuned
    depth.

    ``get()`` blocks (MutableWait hybrid spin/sleep) until a batch is ready.
    """

    def __init__(self, corpus: SyntheticCorpus, workers: int = 2,
                 max_depth: int = 16, initial_depth: int = 2,
                 produce_cost_s: float = 0.0, lock_kind: str = "mutable"):
        from repro.core import make_lock
        self.corpus = corpus
        self.lock = make_lock(lock_kind) if lock_kind != "mutable" \
            else MutableLock(max_sws=4, record_stats=True)
        self.window = SpinningWindow(max_size=max_depth,
                                     initial=initial_depth)
        self.buf: dict[int, dict] = {}
        self.next_produce = 0
        self.next_consume = 0
        self.produce_cost_s = produce_cost_s
        self._stop = threading.Event()
        self._wait = MutableWait(max_spin_s=2e-3, sleep_s=1e-4)
        self.stats = {"gets": 0, "empty_gets": 0}
        self.workers = [threading.Thread(target=self._worker, daemon=True)
                        for _ in range(workers)]
        for w in self.workers:
            w.start()

    # -- producer side --------------------------------------------------------
    def _worker(self) -> None:
        while not self._stop.is_set():
            with self.lock:
                depth = len(self.buf)
                target = self.window.sws
                if depth >= target:
                    claim = None
                else:
                    claim = self.next_produce
                    self.next_produce += 1
            if claim is None:
                time.sleep(1e-4)
                continue
            if self.produce_cost_s:
                time.sleep(self.produce_cost_s)
            batch = self.corpus.batch_at(claim)
            with self.lock:
                self.buf[claim] = batch

    # -- consumer side --------------------------------------------------------
    def get(self) -> dict:
        self.stats["gets"] += 1
        step = self.next_consume
        with self.lock:
            hit = step in self.buf
        if not hit:
            self.stats["empty_gets"] += 1
        # window observation: empty buffer on arrival == late wake-up
        self.window.observe(late_wake=not hit,
                            occupancy=len(self.buf) + 1)
        ok = self._wait.wait(lambda: self._peek(step), timeout_s=30.0)
        if not ok:
            raise TimeoutError(f"batch {step} never arrived")
        with self.lock:
            batch = self.buf.pop(step)
        self.next_consume += 1
        return batch

    def _peek(self, step: int) -> bool:
        with self.lock:
            return step in self.buf

    def close(self) -> None:
        self._stop.set()
        for w in self.workers:
            w.join(timeout=2.0)

"""Optimizers from scratch (no optax): AdamW and Adafactor, pytree-based.

Both are written so that *optimizer state inherits the parameter sharding*
(ZeRO-1/3 falls out of the dry-run's param shardings: every state leaf has the
same shape as — or a reduced shape derived from — its parameter, so GSPMD
propagates the sharding).  Adafactor is the memory-lean choice for the
≥100 B-parameter architectures (jamba-398b, qwen3-moe-235b): factored second
moments are O(rows + cols) instead of O(rows·cols), and master weights are
optional.

API (mirrors the optax triple, but plain functions):

    opt = make_optimizer(tcfg)              # tcfg: TrainConfig
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"            # adamw | adafactor | sgd
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip_norm: float = 1.0
    # adafactor
    factored: bool = True
    master_weights: bool = False        # fp32 master copy (off: update in-place)
    # gradient accumulation (microbatches per optimizer step)
    grad_accum: int = 1
    # dtype of the accumulation buffer: float32 (exact) or bfloat16 (saves
    # 2 bytes/param of HBM on memory-bound frontier-scale train cells)
    accum_dtype: str = "float32"
    # int8 error-feedback gradient compression on the cross-pod all-reduce
    dp_compression: str = "none"        # none | int8
    seed: int = 0


# --------------------------------------------------------------------------
# LR schedule: linear warmup -> cosine decay to min_lr_ratio
# --------------------------------------------------------------------------
def lr_schedule(tcfg: TrainConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, tcfg.warmup_steps))
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / max(1, tcfg.decay_steps - tcfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = tcfg.min_lr_ratio + (1.0 - tcfg.min_lr_ratio) * cos
    return tcfg.learning_rate * warm * scale


# --------------------------------------------------------------------------
# Global-norm clipping
# --------------------------------------------------------------------------
def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# --------------------------------------------------------------------------
# Optimizer protocol
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable           # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params,
        updates)


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def make_adamw(tcfg: TrainConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32),
                "grad_norm": jnp.zeros((), jnp.float32),
                "lr": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip_norm)
        count = state["count"] + 1
        b1, b2 = tcfg.b1, tcfg.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        lr = lr_schedule(tcfg, step)

        def upd(m_, v_, p):
            mhat = m_ / c1
            vhat = v_ / c2
            u = mhat / (jnp.sqrt(vhat) + tcfg.eps)
            if tcfg.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                u = u + tcfg.weight_decay * p.astype(jnp.float32)
            return -lr * u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count,
                         "grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018): factored v, no m by default, relative
# update scale.  State per matrix param: v_row (rows,), v_col (cols,).
# --------------------------------------------------------------------------
def _factored_dims(shape):
    """Return (row_axis, col_axis) for factoring, or None for <2D params.
    The two largest trailing dims are factored (stacked-layer leading dims
    are treated as batch dims of independent factorizations)."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def make_adafactor(tcfg: TrainConfig) -> Optimizer:
    decay = 0.8  # beta2 schedule exponent: 1 - t^-0.8 (paper default)

    def init(params):
        def leaf(p):
            dims = _factored_dims(p.shape) if tcfg.factored else None
            if dims is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            r, c = dims
            # v_row: drop col axis; v_col: drop row axis
            row_shape = tuple(s for i, s in enumerate(p.shape) if i != c)
            col_shape = tuple(s for i, s in enumerate(p.shape) if i != r)
            return {"v_row": jnp.zeros(row_shape, jnp.float32),
                    "v_col": jnp.zeros(col_shape, jnp.float32)}

        st = {"v": jax.tree.map(leaf, params),
              "count": jnp.zeros((), jnp.int32),
              "grad_norm": jnp.zeros((), jnp.float32),
              "lr": jnp.zeros((), jnp.float32)}
        if tcfg.master_weights:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return st

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip_norm)
        count = state["count"] + 1
        t = count.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        lr = lr_schedule(tcfg, step)

        def leaf(g, v, p):
            g2 = jnp.square(g) + 1e-30
            dims = _factored_dims(p.shape) if tcfg.factored else None
            if dims is None:
                v_new = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v_new + tcfg.eps)
                new_v = {"v": v_new}
            else:
                r, c = dims
                vr = beta2 * v["v_row"] + (1 - beta2) * jnp.mean(g2, axis=c)
                vc = beta2 * v["v_col"] + (1 - beta2) * jnp.mean(g2, axis=r)
                # rank-1 reconstruction: v ~= vr vc / mean(vr)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                vhat = (jnp.expand_dims(vr / denom, c)
                        * jnp.expand_dims(vc, r))
                u = g * jax.lax.rsqrt(vhat + tcfg.eps)
                new_v = {"v_row": vr, "v_col": vc}
            # update clipping (adafactor d=1.0)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u)
            # relative step scale
            p_scale = jnp.maximum(
                jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), 1e-3)
            upd = -lr * p_scale * u
            if tcfg.weight_decay and p.ndim >= 2:
                upd = upd - lr * tcfg.weight_decay * p.astype(jnp.float32)
            return upd, new_v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = jax.tree.leaves(params)
        outs = [leaf(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_v = treedef.unflatten([o[1] for o in outs])
        new_state = {"v": new_v, "count": count,
                     "grad_norm": gnorm, "lr": lr}
        if tcfg.master_weights:
            master = jax.tree.map(lambda mp, u: mp + u,
                                  state["master"], updates)
            new_state["master"] = master
            updates = jax.tree.map(
                lambda mp, p: mp - p.astype(jnp.float32), master, params)
        return updates, new_state

    return Optimizer(init=init, update=update)


# --------------------------------------------------------------------------
# SGD (tests / ablations)
# --------------------------------------------------------------------------
def make_sgd(tcfg: TrainConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "grad_norm": jnp.zeros((), jnp.float32),
                "lr": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip_norm)
        lr = lr_schedule(tcfg, step)
        updates = jax.tree.map(lambda g: -lr * g, grads)
        return updates, {"count": state["count"] + 1,
                         "grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)


def make_optimizer(tcfg: TrainConfig) -> Optimizer:
    return {"adamw": make_adamw, "adafactor": make_adafactor,
            "sgd": make_sgd}[tcfg.optimizer](tcfg)

"""Training substrate: optimizers, schedules, train-step builders."""

from .optimizer import (Optimizer, TrainConfig, apply_updates,
                        clip_by_global_norm, global_norm, lr_schedule,
                        make_adafactor, make_adamw, make_optimizer, make_sgd)
from .train_step import init_state, make_eval_step, make_train_step

__all__ = [
    "TrainConfig", "Optimizer", "make_optimizer", "make_adamw",
    "make_adafactor", "make_sgd", "apply_updates", "lr_schedule",
    "global_norm", "clip_by_global_norm",
    "init_state", "make_train_step", "make_eval_step",
]

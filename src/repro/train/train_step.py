"""train_step / eval_step builders.

``make_train_step(cfg, tcfg)`` returns a pure function

    train_step(state, batch) -> (state', metrics)

where ``state = {"params", "opt", "step"}``.  Features:

* gradient accumulation (``tcfg.grad_accum`` microbatches via ``lax.scan``) —
  the memory lever for the hillclimb;
* optional **int8 error-feedback compression** of the cross-pod gradient
  all-reduce (``tcfg.dp_compression="int8"``): per-pod gradients are computed
  under ``shard_map`` over the ``pod`` axis, quantized to int8 with a per-leaf
  scale, psummed in int8-widened-to-int32, dequantized, and the quantization
  residual is carried in the optimizer state and added back next step.  This
  cuts DCN gradient traffic 4x (bf16 -> int8/int32 mix) at equal fixed-point
  of the optimizer — the classic 1-bit-Adam/EF-SGD trick adapted to pods;
* loss/grads in the model's compute dtype, reductions in fp32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig
from repro.sharding.compat import shard_map

from .optimizer import TrainConfig, apply_updates, make_optimizer


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = models.init_params(cfg, key)
    opt = make_optimizer(tcfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.dp_compression == "int8":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _split_microbatches(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for every leaf.

    The microbatch (B/n) must stay divisible by the batch-sharding degree,
    or GSPMD silently replicates the whole batch per device (measured: ~15x
    per-device FLOPs on the 512-chip mesh — EXPERIMENTS §Perf iteration 4).
    """
    from repro.sharding import specs as sh
    mesh, rules = sh.current_mesh(), sh.current_rules()
    if mesh is not None:
        axes = rules.resolve("batch")
        axes = (axes,) if isinstance(axes, str) else (axes or ())
        dp = 1
        for a in axes:
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        b = jax.tree.leaves(batch)[0].shape[0]
        if (b // n) % dp != 0:
            raise ValueError(
                f"microbatch {b}//{n}={b//n} not divisible by the "
                f"batch-sharding degree {dp}; lower grad_accum")
    def re(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(re, batch)


def _grads_plain(cfg, params, batch, accum: int,
                 accum_dtype: str = "float32"):
    """Standard grads (GSPMD inserts all data-parallel reductions)."""
    def loss(p, b):
        return models.loss_fn(cfg, p, b)

    if accum <= 1:
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        return l, metrics, grads

    micro = _split_microbatches(batch, accum)
    adt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[accum_dtype]

    def body(carry, mb):
        g_acc, l_acc, a_acc = carry
        (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
        g_acc = jax.tree.map(
            lambda a, b_: a + b_.astype(adt), g_acc, g)
        return (g_acc, l_acc + l, a_acc + metrics["aux"]), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
    (grads, l_tot, aux_tot), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        micro)
    inv = 1.0 / accum
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
    return l_tot * inv, {"ce": l_tot * inv, "aux": aux_tot * inv}, grads


# --------------------------------------------------------------------------
# int8 error-feedback compressed cross-pod gradient reduction
# --------------------------------------------------------------------------
def _quantized_psum(g, axis: str):
    """int8 stochastic-free quantized psum of a fp32 leaf over ``axis``."""
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jax.lax.pmax(jnp.maximum(scale, 1e-12), axis)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    deq = total.astype(jnp.float32) * scale / n
    residual = g - q.astype(jnp.float32) * scale   # local quantization error
    return deq, residual


def _grads_compressed(cfg, params, batch, ef, accum: int, pod_axis: str):
    """Per-pod grads under shard_map + int8 EF psum across pods.

    Called *inside* an outer shard_map over the pod axis with params
    replicated and batch split on its leading dim.
    """
    l, metrics, grads = _grads_plain(cfg, params, batch, accum)  # noqa: E501 (compressed path keeps f32)
    grads = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    # flatten-unflatten (a tree.map with tuple leaves would mistake the
    # params 'stack' tuple for a (deq, res) pair)
    flat, treedef = jax.tree.flatten(grads)
    pairs = [_quantized_psum(g, axis=pod_axis) for g in flat]
    deq = treedef.unflatten([p[0] for p in pairs])
    res = treedef.unflatten([p[1] for p in pairs])
    l = jax.lax.pmean(l, pod_axis)
    metrics = jax.tree.map(lambda m: jax.lax.pmean(m, pod_axis), metrics)
    return l, metrics, deq, res


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    opt = make_optimizer(tcfg)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.dp_compression == "int8":
            from repro.sharding import specs as sh
            mesh = sh.current_mesh()
            assert mesh is not None and "pod" in mesh.axis_names, (
                "int8 DP compression needs a 'pod' mesh axis")
            from jax.sharding import PartitionSpec as P
            rep = P()                    # params replicated across pods
            bspec = jax.tree.map(lambda _: P("pod"), batch)
            pspec = jax.tree.map(lambda _: rep, params)
            efspec = jax.tree.map(lambda _: rep, state["ef"])

            # inside the pod-manual region the model runs WITHOUT sharding
            # annotations: XLA 0.8's partitioner check-fails on GSPMD
            # constraints under a partially-manual mesh; the jit-level
            # in_shardings still drive data/model propagation.
            from repro.sharding.specs import MeshRules
            inner_rules = MeshRules(**{
                f: None for f in MeshRules.__dataclass_fields__})

            def body(p, b, e):
                with sh.use_mesh(mesh, inner_rules):
                    return _grads_compressed(cfg, p, b, e,
                                             tcfg.grad_accum, "pod")

            loss, metrics, grads, ef = shard_map(
                body, mesh=mesh, axis_names={"pod"},
                in_specs=(pspec, bspec, efspec),
                out_specs=(P(), jax.tree.map(lambda _: P(), {
                    "ce": 0, "aux": 0}), pspec, efspec),
                check_vma=False)(params, batch, state["ef"])
        else:
            loss, metrics, grads = _grads_plain(cfg, params, batch,
                                                tcfg.grad_accum,
                                                tcfg.accum_dtype)
            ef = None

        updates, opt_state = opt.update(grads, state["opt"], params,
                                        state["step"])
        new_params = apply_updates(params, updates)
        new_state = {"params": new_params, "opt": opt_state,
                     "step": state["step"] + 1}
        if ef is not None:
            new_state["ef"] = ef
        out_metrics = {"loss": loss, **metrics,
                       "grad_norm": opt_state.get("grad_norm", 0.0),
                       "lr": opt_state.get("lr", 0.0)}
        return new_state, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = models.loss_fn(cfg, params, batch)
        return {"loss": loss, **metrics}
    return eval_step

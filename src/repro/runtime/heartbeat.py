"""Heartbeat / straggler detection built on MutableWait (DESIGN.md §3.3).

At 1000+ nodes the controller's job is: notice quickly when a host stops
making progress (failure) or slows down (straggler), without burning a core
on polling.  Heartbeats arrive at step granularity; the monitor's wait for
"all peers reported step k" is a textbook spin-vs-sleep trade-off — exactly
the paper's problem, so the wait uses the self-tuned hybrid policy.

This module is hardware-independent: hosts push timestamps into a
HeartbeatBoard (in production backed by a KV store / coordination service;
here an in-process object, exercised by threads in tests).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core import MutableLock, MutableWait


@dataclass
class PeerState:
    host_id: int
    last_step: int = -1
    last_seen_s: float = 0.0
    failed: bool = False


class HeartbeatBoard:
    """Shared board of per-host progress, MutableLock-guarded."""

    def __init__(self, n_hosts: int):
        self.lock = MutableLock(max_sws=4)
        self.peers = {i: PeerState(i) for i in range(n_hosts)}

    def beat(self, host_id: int, step: int) -> None:
        with self.lock:
            p = self.peers[host_id]
            p.last_step = max(p.last_step, step)
            p.last_seen_s = time.monotonic()
            p.failed = False

    def mark_failed(self, host_id: int) -> None:
        with self.lock:
            self.peers[host_id].failed = True

    def snapshot(self) -> dict[int, PeerState]:
        with self.lock:
            return {i: PeerState(p.host_id, p.last_step, p.last_seen_s,
                                 p.failed)
                    for i, p in self.peers.items()}


@dataclass
class MonitorReport:
    step: int
    ready: list = field(default_factory=list)
    stragglers: list = field(default_factory=list)
    failed: list = field(default_factory=list)


class StragglerMonitor:
    """Watches a HeartbeatBoard: detects failures (silence > dead_after_s)
    and stragglers (behind the median by > lag_steps)."""

    def __init__(self, board: HeartbeatBoard, dead_after_s: float = 5.0,
                 lag_steps: int = 2):
        self.board = board
        self.dead_after_s = dead_after_s
        self.lag_steps = lag_steps
        self.wait = MutableWait(max_spin_s=2e-3, sleep_s=2e-3)

    def wait_for_step(self, step: int, timeout_s: float = 30.0
                      ) -> MonitorReport:
        """Block until every live host reported ``step`` (or timeout);
        returns who is ready / straggling / presumed dead."""

        def everyone_there() -> bool:
            snap = self.board.snapshot()
            now = time.monotonic()
            return all(p.last_step >= step or p.failed
                       or p.last_seen_s == 0.0
                       or now - p.last_seen_s > self.dead_after_s
                       for p in snap.values())

        self.wait.wait(everyone_there, timeout_s=timeout_s)
        snap = self.board.snapshot()
        now = time.monotonic()
        rep = MonitorReport(step=step)
        steps = sorted(p.last_step for p in snap.values() if not p.failed)
        median = steps[len(steps) // 2] if steps else 0
        for p in snap.values():
            if (p.failed or p.last_seen_s == 0.0
                    or now - p.last_seen_s > self.dead_after_s):
                rep.failed.append(p.host_id)
            elif p.last_step < median - self.lag_steps:
                rep.stragglers.append(p.host_id)
            elif p.last_step >= step:
                rep.ready.append(p.host_id)
        return rep

"""Distributed runtime: heartbeats, straggler/failure detection, elastic
re-meshing, and the hot-spare spinning window."""

from .elastic import ElasticMesh, HotSparePool, MeshPlan, SpareStats
from .heartbeat import HeartbeatBoard, MonitorReport, StragglerMonitor

__all__ = ["HeartbeatBoard", "StragglerMonitor", "MonitorReport",
           "ElasticMesh", "MeshPlan", "HotSparePool", "SpareStats"]

"""Elastic scaling: re-mesh + reshard on membership change, and the paper's
spinning window applied to HOT SPARES.

Two pieces:

1. :class:`ElasticMesh` — given the current healthy host set, derives the
   largest usable mesh (shrinking the data/pod axes first, never the model
   axis, so parameter shardings stay compatible), and restores a checkpoint
   into the new topology (``checkpoint.load_pytree`` re-``device_put``s every
   leaf under the new shardings — that is the whole reshard).

2. :class:`HotSparePool` — the mutable-lock insight at cluster scale:
   *hot spares* are standby hosts kept with the framework booted and the
   latest checkpoint pre-staged (spinning: they cost reserved capacity but
   replace a failed host in seconds); *cold spares* must be provisioned +
   restore from scratch (sleeping: free until needed, wake-up latency =
   minutes).  A failure that finds no hot spare is a **late wake-up** →
   the pool target doubles; K consecutive failures absorbed by hot spares →
   shrink by one.  This is `SpinningWindow` verbatim — the oracle never
   changed, only the resource.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.oracle import EvalSWS, Oracle
from repro.core.window import SpinningWindow


# --------------------------------------------------------------------------
# Re-meshing
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshPlan:
    pod: int
    data: int
    model: int
    hosts_used: int
    hosts_idle: int

    @property
    def shape(self):
        return ((self.pod, self.data, self.model) if self.pod > 1
                else (self.data, self.model))

    @property
    def axis_names(self):
        return (("pod", "data", "model") if self.pod > 1
                else ("data", "model"))


class ElasticMesh:
    """Chooses the mesh for the currently-healthy host set.

    Chips per host is fixed (TPU vm topology); the model axis is preserved
    (changing it would re-partition every weight); the data axis shrinks to
    the largest power-of-two-ish divisor the survivors support.  Training
    keeps the same GLOBAL batch by raising grad-accum, so the loss curve is
    unaffected by elasticity (the standard elastic-DP contract).
    """

    def __init__(self, chips_per_host: int = 4, model_axis: int = 16,
                 global_batch: int = 256):
        self.chips_per_host = chips_per_host
        self.model_axis = model_axis
        self.global_batch = global_batch

    def plan(self, healthy_hosts: int) -> MeshPlan:
        chips = healthy_hosts * self.chips_per_host
        if chips < self.model_axis:
            raise ValueError(
                f"{healthy_hosts} hosts x {self.chips_per_host} chips cannot "
                f"hold the model axis ({self.model_axis})")
        data_max = chips // self.model_axis
        # largest data size that divides the global batch
        data = max(d for d in range(1, data_max + 1)
                   if self.global_batch % d == 0)
        pods = 1
        used = (pods * data * self.model_axis) // self.chips_per_host
        return MeshPlan(pod=pods, data=data, model=self.model_axis,
                        hosts_used=used, hosts_idle=healthy_hosts - used)

    def accum_for(self, plan: MeshPlan, base_accum: int = 1,
                  full_data: int = 16) -> int:
        """Scale grad-accum so tokens-per-optimizer-step stays constant."""
        return max(1, int(base_accum * full_data / plan.data))


# --------------------------------------------------------------------------
# Hot-spare pool (the paper's window over standby capacity)
# --------------------------------------------------------------------------
@dataclass
class SpareStats:
    failures: int = 0
    masked: int = 0              # failure absorbed by a hot spare
    exposed: int = 0             # failure had to cold-provision (late wake)
    recovery_s_total: float = 0.0
    hot_host_seconds: float = 0.0
    window_trace: list = field(default_factory=list)


class HotSparePool:
    """Self-tuned hot-spare target; drive with failure/heal events.

    ``hot_spinup_s`` — promote hot spare -> serving (seconds; checkpoint
    already staged).  ``cold_spinup_s`` — provision + restore (the wake-up
    latency the window exists to mask).
    """

    def __init__(self, max_spares: int, initial: int = 1,
                 oracle: Oracle | None = None, hot_spinup_s: float = 30.0,
                 cold_spinup_s: float = 600.0):
        from repro.core.oracle import FixedOracle
        # a static zero pool (cold-only ablation) must stay at zero; the
        # adaptive oracle keeps the paper's >=1 clamp so doubling can fire
        min_size = 0 if (initial == 0
                         and isinstance(oracle, FixedOracle)) else 1
        self.window = SpinningWindow(max_size=max_spares, initial=initial,
                                     min_size=min_size,
                                     oracle=oracle or EvalSWS(k=10))
        self.hot = initial
        self.cold_queue = 0          # spares warming up towards hot
        self.hot_spinup_s = hot_spinup_s
        self.cold_spinup_s = cold_spinup_s
        self.stats = SpareStats()

    def tick(self, dt_s: float) -> None:
        self.stats.hot_host_seconds += self.hot * dt_s

    def on_failure(self) -> float:
        """A host died.  Returns the recovery latency experienced."""
        self.stats.failures += 1
        if self.hot > 0:
            self.hot -= 1
            latency = self.hot_spinup_s
            self.stats.masked += 1
            late = False
        else:
            latency = self.cold_spinup_s
            self.stats.exposed += 1
            late = True
        self.stats.recovery_s_total += latency
        corr = self.window.observe(late_wake=late,
                                   occupancy=self.hot + self.cold_queue + 1)
        # refill towards the (possibly resized) target
        want = self.window.sws - self.hot - self.cold_queue
        if want > 0:
            self.cold_queue += want
        self.stats.window_trace.append(self.window.sws)
        return latency

    def on_spare_ready(self, n: int = 1) -> None:
        """Cold spares finished warming (call after cold_spinup_s)."""
        take = min(n, self.cold_queue)
        self.cold_queue -= take
        self.hot += take
        # C2: if the window shrank below the hot count, release capacity
        if self.hot > self.window.sws:
            self.hot = self.window.sws

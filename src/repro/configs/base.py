"""Config schema for every architecture the framework can instantiate.

A :class:`ModelConfig` fully determines a model: the decoder (or enc-dec)
stack is assembled from per-layer *mixer* (attention / mamba / rwkv6) and
*ffn* (dense / moe) choices.  Homogeneous stacks use a single scanned block;
heterogeneous stacks (jamba) use a scanned *period* of layers.

Shapes (assignment grid):

    train_4k      seq_len=4096    global_batch=256   -> train_step
    prefill_32k   seq_len=32768   global_batch=32    -> prefill_step
    decode_32k    seq_len=32768   global_batch=128   -> serve_step (1 new tok)
    long_500k     seq_len=524288  global_batch=1     -> serve_step; only for
                                                        sub-quadratic archs
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

MixerKind = Literal["attention", "mamba", "rwkv6", "none"]
FFNKind = Literal["dense", "moe", "rwkv_ffn"]


# --------------------------------------------------------------------------
# Per-layer building blocks
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    # sliding-window size; 0 = full (global) attention
    window: int = 0
    qkv_bias: bool = False
    out_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # gemma-style soft logit cap (0 = off)
    logit_softcap: float = 0.0
    # qk normalization (gemma3 / qwen3 style)
    qk_norm: bool = False
    causal: bool = True


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKV6Config:
    head_dim: int = 64
    lora_w: int = 64            # decay lora rank (token-shift ddlerp)
    lora_mix: int = 32


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                   # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # shared dense expert in parallel with routed experts (granite style: none)
    shared_d_ff: int = 0
    router_logit_softcap: float = 0.0


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the stack: a mixer + an ffn, pre-norm residual."""

    mixer: MixerKind = "attention"
    ffn: FFNKind = "dense"


# --------------------------------------------------------------------------
# Whole-model config
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    d_ff: int                    # dense-FFN hidden dim
    vocab_size: int

    attention: AttentionConfig | None = None
    mamba: MambaConfig | None = None
    rwkv6: RWKV6Config | None = None
    moe: MoEConfig | None = None

    # Homogeneous stack: layer_period == 1 and pattern == (LayerSpec(...),).
    # Heterogeneous (jamba): pattern length P; stack = P * (num_layers // P).
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)

    # per-layer attention window schedule for homogeneous stacks, as a
    # repeating pattern over layer index (gemma3: 5 local + 1 global).
    # None -> every attention layer uses attention.window.
    window_pattern: tuple[int, ...] | None = None
    # per-layer rope theta pattern, aligned with window_pattern (gemma3 uses
    # 10k for local layers and 1M for global layers).
    rope_theta_pattern: tuple[float, ...] | None = None

    # enc-dec (whisper): encoder stack config; decoder = the main stack with
    # cross-attention interleaved.
    encoder_layers: int = 0
    encoder_seq: int = 1500      # whisper: fixed 1500 frames post-conv
    is_encoder_decoder: bool = False

    # embeddings / head
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma multiplies embeddings by sqrt(d_model)
    norm_eps: float = 1e-6
    act: str = "silu"            # silu | gelu | relu_sq
    use_abs_pos: bool = False    # learned/sinusoidal absolute positions

    # numerics
    dtype: str = "bfloat16"      # activation/param compute dtype
    param_dtype: str = "bfloat16"
    logit_chunk: int = 512       # seq-chunked vocab loss (0 = unchunked)
    remat: str = "full"          # activation checkpointing: none|full|dots

    # modality frontend stub: inputs arrive as precomputed embeddings
    # ("tokens" for LM; "frames" for audio; "mixed" vlm = tokens incl. VQ ids)
    input_kind: str = "tokens"

    # ---- derived -----------------------------------------------------------
    @property
    def layers_per_period(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.layers_per_period == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"period {self.layers_per_period}")
        return self.num_layers // self.layers_per_period

    def num_params(self) -> int:
        """Closed-form parameter count (embeddings + stack), for rooflines."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for i in range(self.num_layers):
            spec = self.pattern[i % self.layers_per_period]
            n += self._mixer_params(spec.mixer) + self._ffn_params(spec.ffn)
            n += 2 * self.d_model            # two pre-norms
        n += self.d_model                    # final norm
        if self.is_encoder_decoder:
            a = self.attention
            per = (self._mixer_params("attention") + self._ffn_params("dense")
                   + 2 * self.d_model)
            n += self.encoder_layers * per
            # cross-attention in every decoder layer
            n += self.num_layers * (self._mixer_params("attention")
                                    + self.d_model)
        return n

    def active_params(self) -> int:
        """Per-token active parameters (MoE: top_k of num_experts)."""
        n = self.vocab_size * self.d_model   # logits matmul is per-token work
        for i in range(self.num_layers):
            spec = self.pattern[i % self.layers_per_period]
            n += self._mixer_params(spec.mixer)
            if spec.ffn == "moe":
                m = self.moe
                n += (3 * m.d_ff * self.d_model * m.top_k
                      + self.d_model * m.num_experts // max(1, self.d_model)
                      + (3 * m.shared_d_ff * self.d_model))
            else:
                n += self._ffn_params(spec.ffn)
        return n

    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "attention":
            a = self.attention
            q = d * a.num_heads * a.head_dim
            kv = 2 * d * a.num_kv_heads * a.head_dim
            o = a.num_heads * a.head_dim * d
            b = (a.num_heads + 2 * a.num_kv_heads) * a.head_dim if a.qkv_bias else 0
            return q + kv + o + b
        if kind == "mamba":
            m = self.mamba
            d_in = m.expand * d
            dt_rank = m.dt_rank or -(-d // 16)
            return (d * 2 * d_in                  # in_proj (x, z)
                    + d_in * m.d_conv + d_in      # conv
                    + d_in * (dt_rank + 2 * m.d_state)   # x -> dt,B,C
                    + dt_rank * d_in + d_in       # dt_proj
                    + d_in * m.d_state + d_in     # A_log, D
                    + d_in                        # rmsnorm gate
                    + d_in * d)                   # out_proj
        if kind == "rwkv6":
            d_in = self.d_ff and self.d_model  # r/k/v/g/o are d x d
            r = self.rwkv6
            return 4 * d * d + d * d + 2 * (r.lora_w * d + r.lora_w * d) \
                + 5 * (r.lora_mix * d * 2) + 10 * d
        if kind == "none":
            return 0
        raise ValueError(kind)

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "dense":
            return 3 * d * self.d_ff
        if kind == "moe":
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_ff + d * m.num_experts
            shared = 3 * d * m.shared_d_ff if m.shared_d_ff else 0
            return routed + shared
        if kind == "rwkv_ffn":
            # rwkv6 channel-mix: k (d x 3.5d), v (3.5d x d), r (d x d)
            return d * self.d_ff + self.d_ff * d + d * d
        raise ValueError(kind)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Shape grid
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules.  Returns (run?, reason-if-skipped)."""
    if shape.name == "long_500k":
        subquadratic = cfg.family in ("ssm", "hybrid")
        if not subquadratic:
            return False, ("long_500k skipped: full-attention arch "
                           "(quadratic); run only for SSM/hybrid")
    return True, ""


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: dict[str, "callable"] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    # import the per-arch modules lazily so `configs` has no import cycle
    from . import catalog  # noqa: F401  (populates _REGISTRY)
    try:
        return _REGISTRY[arch_id]()
    except KeyError as e:
        raise ValueError(
            f"unknown arch {arch_id!r}; options: {sorted(_REGISTRY)}") from e


def list_archs() -> list[str]:
    from . import catalog  # noqa: F401
    return sorted(_REGISTRY)

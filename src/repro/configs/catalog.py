"""The 10 assigned architectures (exact configs from the assignment grid)
plus the lock-simulation sweep specs consumed by ``benchmarks/sweep.py``.

Sources are public literature / HF configs as tagged in the assignment; each
function returns the FULL config.  ``tiny(cfg)`` derives the reduced-config
smoke-test variant of the same family (same pattern/mixers/ffn kinds, small
dims) — full configs are only ever lowered via the dry-run.
"""

from __future__ import annotations

import dataclasses

from repro.core.policy import SimConfig

from .base import (AttentionConfig, LayerSpec, MambaConfig, ModelConfig,
                   MoEConfig, RWKV6Config, register)


# --------------------------------------------------------------------------
# Dense transformers
# --------------------------------------------------------------------------
@register("gemma3-4b")
def gemma3_4b() -> ModelConfig:
    """34L d2560 8H kv4 hd256 dff10240 v262144; 5 local(1024):1 global,
    dual rope theta (10k local / 1M global), qk-norm, tied+scaled embed."""
    return ModelConfig(
        name="gemma3-4b", family="dense",
        num_layers=34, d_model=2560, d_ff=10240, vocab_size=262_144,
        attention=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=256,
                                  qk_norm=True, rope_theta=1_000_000.0),
        window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
        rope_theta_pattern=(10_000.0,) * 5 + (1_000_000.0,),
        pattern=(LayerSpec("attention", "dense"),),
        embed_scale=True, act="gelu", logit_chunk=512,
    )


@register("llama3.2-1b")
def llama32_1b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        num_layers=16, d_model=2048, d_ff=8192, vocab_size=128_256,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=64,
                                  rope_theta=500_000.0),
        pattern=(LayerSpec("attention", "dense"),),
        tie_embeddings=True, act="silu",
    )


@register("qwen2.5-14b")
def qwen25_14b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        num_layers=48, d_model=5120, d_ff=13824, vocab_size=152_064,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                                  qkv_bias=True, rope_theta=1_000_000.0),
        pattern=(LayerSpec("attention", "dense"),),
        tie_embeddings=False, act="silu",
    )


@register("stablelm-3b")
def stablelm_3b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, d_ff=6912, vocab_size=50_304,
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=80,
                                  rope_theta=10_000.0),
        pattern=(LayerSpec("attention", "dense"),),
        tie_embeddings=False, act="silu",
    )


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
@register("granite-moe-1b-a400m")
def granite_moe() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, d_ff=512, vocab_size=49_155,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=64,
                                  rope_theta=10_000.0),
        moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
        pattern=(LayerSpec("attention", "moe"),),
        tie_embeddings=True, act="silu",
    )


@register("qwen3-moe-235b-a22b")
def qwen3_moe() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, d_ff=1536, vocab_size=151_936,
        attention=AttentionConfig(num_heads=64, num_kv_heads=4, head_dim=128,
                                  qk_norm=True, rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536),
        pattern=(LayerSpec("attention", "moe"),),
        tie_embeddings=False, act="silu",
    )


# --------------------------------------------------------------------------
# Hybrid (jamba): period of 8 layers — attention at position 4, mamba
# elsewhere (1:7); MoE every other layer (odd positions, top-2 of 16).
# No positional encoding (jamba relies on mamba for position).
# --------------------------------------------------------------------------
@register("jamba-1.5-large-398b")
def jamba() -> ModelConfig:
    pattern = tuple(
        LayerSpec("attention" if j == 4 else "mamba",
                  "moe" if j % 2 == 1 else "dense")
        for j in range(8))
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, d_ff=24576, vocab_size=65_536,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                                  use_rope=False),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
        pattern=pattern,
        tie_embeddings=False, act="silu",
    )


# --------------------------------------------------------------------------
# VLM (chameleon): early-fusion — VQ image tokens share the text vocab, so
# the backbone is a dense decoder over mixed token streams (frontend = ids).
# --------------------------------------------------------------------------
@register("chameleon-34b")
def chameleon() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, d_ff=22016, vocab_size=65_536,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                                  qk_norm=True, rope_theta=10_000.0),
        pattern=(LayerSpec("attention", "dense"),),
        tie_embeddings=False, act="silu", input_kind="mixed",
    )


# --------------------------------------------------------------------------
# SSM (rwkv6 "Finch"): attention-free, data-dependent decay
# --------------------------------------------------------------------------
@register("rwkv6-1.6b")
def rwkv6_16b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        num_layers=24, d_model=2048, d_ff=7168, vocab_size=65_536,
        rwkv6=RWKV6Config(head_dim=64),
        pattern=(LayerSpec("rwkv6", "rwkv_ffn"),),
        tie_embeddings=False, act="relu_sq",
    )


# --------------------------------------------------------------------------
# Audio (whisper-large-v3): enc-dec backbone; conv/mel frontend stubbed
# (input_specs feeds (B, 1500, 1280) frame embeddings).
# --------------------------------------------------------------------------
@register("whisper-large-v3")
def whisper() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, d_ff=5120, vocab_size=51_866,
        attention=AttentionConfig(num_heads=20, num_kv_heads=20, head_dim=64,
                                  use_rope=False, out_bias=True),
        pattern=(LayerSpec("attention", "dense"),),
        encoder_layers=32, encoder_seq=1500, is_encoder_decoder=True,
        tie_embeddings=True, act="gelu", input_kind="frames",
    )


# --------------------------------------------------------------------------
# Reduced smoke-test variants
# --------------------------------------------------------------------------
def tiny(cfg: ModelConfig) -> ModelConfig:
    """Same family/pattern, laptop-sized: used by per-arch smoke tests."""
    kw: dict = dict(
        name=f"tiny-{cfg.name}",
        num_layers=2 * cfg.layers_per_period,
        d_model=64, d_ff=128, vocab_size=256, logit_chunk=0,
        remat="none",
    )
    if cfg.attention is not None:
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4,
            num_kv_heads=min(cfg.attention.num_kv_heads, 2)
            if cfg.attention.num_kv_heads < cfg.attention.num_heads else 4,
            head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff=32)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=4, dt_rank=8)
    if cfg.rwkv6 is not None:
        kw["rwkv6"] = dataclasses.replace(cfg.rwkv6, head_dim=16, lora_w=8,
                                          lora_mix=4)
    if cfg.window_pattern is not None:
        kw["window_pattern"] = tuple(min(w, 8) if w else 0
                                     for w in cfg.window_pattern)
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 16
    return dataclasses.replace(cfg, **kw)


# --------------------------------------------------------------------------
# Lock-simulation sweep specs (paper Fig. 3 + beyond-paper scenario sweep).
# Each spec is a list of repro.core.policy.SimConfig rows; benchmarks/
# sweep.py encodes a spec to struct-of-arrays form and simulates the whole
# batch in one jit-compiled repro.core.xdes call.
# --------------------------------------------------------------------------
LOCK_SHORT = (0.0, 3.7e-6)        # paper §4: uniform [0, 3.7) µs
LOCK_LONG = (0.0, 366e-6)         # uniform [0, 366) µs
LOCK_WAKE = 8e-6                  # order of a futex wake
LOCK_CORES = 20                   # the paper's test machine
LOCK_THREADS = (2, 4, 8, 12, 16, 20, 26, 32)
LOCK_DISCIPLINES = ("ttas", "mcs", "sleep", "adaptive", "mutable")
LOCK_REGIMES = {
    "cs_short_ncs_short": (LOCK_SHORT, LOCK_SHORT),   # Fig 3(a-c)
    "cs_long_ncs_short": (LOCK_LONG, LOCK_SHORT),     # Fig 3(d-f)
    "cs_short_ncs_long": (LOCK_SHORT, LOCK_LONG),     # Fig 3(g-i)
    "cs_long_ncs_long": (LOCK_LONG, LOCK_LONG),       # Fig 3(j-l)
}


def lock_fig3_grid(seeds=(0, 1)) -> list[SimConfig]:
    """The full Fig. 3 grid as one flat batch: regimes x locks x thread
    counts x seeds (row order matches the nested loops, so consumers can
    reshape to (regime, lock, threads, seed))."""
    return [
        SimConfig(lock, threads=tc, cores=LOCK_CORES, cs=cs, ncs=ncs,
                  wake_latency=LOCK_WAKE, seed=seed)
        for cs, ncs in LOCK_REGIMES.values()
        for lock in LOCK_DISCIPLINES
        for tc in LOCK_THREADS
        for seed in seeds
    ]


def sample_scenarios(n_scenarios: int, seed: int = 0) -> list[dict]:
    """Draw ``n_scenarios`` random machines/workloads from the adaptive-
    spin design space named in PAPERS.md: CS/NCS lengths log-uniform across
    the paper's two regimes, wake latency from fast-futex to slow-
    scheduler, cache-contention strength from uncontended to 4x the paper's
    default, and over- as well as under-subscribed machines.  The draw
    order is part of the contract (seeds are stable across sweeps)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_scenarios):
        out.append(dict(
            threads=int(rng.integers(2, 33)),
            cores=int(rng.integers(2, 33)),
            cs_hi=float(np.exp(rng.uniform(np.log(1e-6), np.log(4e-4)))),
            ncs_hi=float(np.exp(rng.uniform(np.log(1e-6), np.log(4e-4)))),
            wake=float(np.exp(rng.uniform(np.log(2e-6), np.log(5e-5)))),
            contention=float(rng.uniform(0.0, 4.0)),
            seed=i,
        ))
    return out


def lock_scenario_sweep(n_scenarios: int = 200, seed: int = 0,
                        locks=LOCK_DISCIPLINES) -> list[SimConfig]:
    """Beyond-paper scenario sweep: ``n_scenarios`` random machines/
    workloads (:func:`sample_scenarios`), each simulated under every
    discipline (default 200 x 5 = 1000 configurations).  The sampled
    contention multiplies each lock's own ``DEFAULT_ALPHA`` (MCS stays
    coherence-free, TAS stays the worst) so disciplines keep their
    hardware character across scenarios."""
    from repro.core.policy import DEFAULT_ALPHA

    return [
        SimConfig(lock, threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[lock],
                  seed=sc["seed"])
        for sc in sample_scenarios(n_scenarios, seed)
        for lock in locks
    ]


# -- oracle-family ablation grid -------------------------------------------
#: Default (oracle, K, sws_max) product axes of the oracle sweep.  ``K`` is
#: the family's knob (shrink period for paper/aimd/history, retrial budget
#: for fixed); ``sws_max`` None means the machine's core count (the paper
#: default).  4 x 3 x 2 = 24 combinations, 23 variants per scenario after
#: duplicate-trajectory pruning (see lock_oracle_variants).
LOCK_ORACLES = ("paper", "aimd", "fixed", "history")
LOCK_ORACLE_KS = (3, 10, 30)
LOCK_ORACLE_SWS_MAX = (None, 8)


def lock_oracle_variants(oracles=LOCK_ORACLES, ks=LOCK_ORACLE_KS,
                         sws_maxes=LOCK_ORACLE_SWS_MAX) -> list[dict]:
    """The flat ``(oracle, K, sws_max)`` product (variant-axis order of
    :func:`lock_oracle_sweep` rows).

    The ``fixed`` family pins the window at ``min(K, sws_max)``, so two
    fixed variants with the same explicit cap and ``K >= cap`` are the
    same trajectory — only the first is kept (ties would otherwise skew
    the win counts toward the lower-indexed duplicate)."""
    out, seen_fixed = [], set()
    for o in oracles:
        for k in ks:
            for m in sws_maxes:
                if o == "fixed" and m is not None:
                    pin = min(k, m)
                    if (pin, m) in seen_fixed:
                        continue
                    seen_fixed.add((pin, m))
                out.append(dict(oracle=o, k=k, sws_max=m))
    return out


def lock_oracle_sweep(n_scenarios: int = 200, seed: int = 0,
                      oracles=LOCK_ORACLES, ks=LOCK_ORACLE_KS,
                      sws_maxes=LOCK_ORACLE_SWS_MAX) -> list[SimConfig]:
    """Oracle-family ablation: every ``(oracle, K, sws_max)`` variant of
    the mutable lock on every random scenario — the ablation space of the
    glibc/Oracle-RDBMS retrial families (PAPERS.md) as one flat batch for
    a single :func:`repro.core.xdes.simulate_batch` call.

    Row order is scenario-major, variant-minor (reshape to
    ``(n_scenarios, n_variants)``); scenarios are drawn by
    :func:`sample_scenarios` with the same seed contract as
    :func:`lock_scenario_sweep`, so oracle results are comparable
    scenario-by-scenario with the discipline sweep."""
    from repro.core.policy import DEFAULT_ALPHA

    variants = lock_oracle_variants(oracles, ks, sws_maxes)
    return [
        SimConfig("mutable", threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA["mutable"],
                  seed=sc["seed"], oracle=v["oracle"], k=v["k"],
                  sws_max=v["sws_max"])
        for sc in sample_scenarios(n_scenarios, seed)
        for v in variants
    ]


# -- discipline x oracle diagram grid --------------------------------------
#: Discipline axis of the full "which lock wins where" diagram: every
#: DISCIPLINE_ROW is represented (spin via ttas+mcs, sleep, adaptive,
#: mutable, the FIFO/MCS ticket-handoff row, and the related-work rows:
#: Fissile spin-then-park, Hapax FIFO admission, TTAS with seeded
#: bounded-exponential backoff).
LOCK_DISCIPLINE_SET = ("ttas", "mcs", "fifo", "sleep", "adaptive", "mutable",
                       "fissile", "hapax", "ttas_backoff")


def lock_discipline_variants(disciplines=LOCK_DISCIPLINE_SET,
                             oracles=LOCK_ORACLES) -> list[dict]:
    """The ``(discipline, oracle)`` variant axis of the discipline diagram.

    Only *windowed* discipline rows (``DISCIPLINE_ROWS[...].windowed``,
    i.e. the mutable lock) read the oracle column, so non-windowed
    disciplines appear once — sweeping their oracle would duplicate
    trajectories and skew win counts toward the lower-indexed copy (the
    same pruning rule as :func:`lock_oracle_variants`)."""
    from repro.core.policy import POLICY_IDS, POLICY_ROW

    out = []
    for d in disciplines:
        fams = oracles if POLICY_ROW[POLICY_IDS[d]].windowed else oracles[:1]
        for o in fams:
            out.append(dict(lock=d, oracle=o))
    return out


def lock_discipline_sweep(n_scenarios: int = 200, seed: int = 0,
                          disciplines=LOCK_DISCIPLINE_SET,
                          oracles=LOCK_ORACLES) -> list[SimConfig]:
    """The full discipline x oracle diagram grid as one flat batch for a
    single (sharded) :func:`repro.core.xdes.simulate_batch` call.

    Row order is scenario-major, variant-minor (reshape to
    ``(n_scenarios, n_variants)``); scenarios follow the
    :func:`sample_scenarios` seed contract, so every sweep family sees the
    same machines scenario-by-scenario."""
    from repro.core.policy import DEFAULT_ALPHA

    variants = lock_discipline_variants(disciplines, oracles)
    return [
        SimConfig(v["lock"], threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[v["lock"]],
                  seed=sc["seed"], oracle=v["oracle"])
        for sc in sample_scenarios(n_scenarios, seed)
        for v in variants
    ]


# -- workload x discipline x oracle diagram grid ---------------------------
#: Workload axis of the "which lock wins under which workload" diagram:
#: every WORKLOAD_ROW (repro.core.policy) is represented.
LOCK_WORKLOADS = ("constant", "bursty", "hetero", "jitter")


def lock_workload_params(sc: dict) -> dict:
    """Scenario-scaled workload knobs: the bursty ON/OFF cycle is
    ``16 x (cs_hi + ncs_hi)`` — ~32 mean CS+NCS rounds, since uniform
    draws average half their hi — so every sweep horizon sees several
    phases of each thread's duty cycle regardless of the scenario's
    timescale; spread and burst factors stay at the registry defaults."""
    return dict(wl_period=16.0 * (sc["cs_hi"] + sc["ncs_hi"]),
                wl_duty=0.25, wl_burst=8.0, wl_spread=4.0)


def lock_workload_variants(workloads=LOCK_WORKLOADS,
                           disciplines=LOCK_DISCIPLINE_SET,
                           oracles=LOCK_ORACLES) -> list[dict]:
    """The ``(workload, discipline, oracle)`` variant axis of the workload
    diagram: the discipline x oracle variants (windowed-row pruning of
    :func:`lock_discipline_variants`) replicated under every workload
    row, workload-major."""
    return [dict(workload=w, **v)
            for w in workloads
            for v in lock_discipline_variants(disciplines, oracles)]


def lock_workload_sweep(n_scenarios: int = 100, seed: int = 0,
                        workloads=LOCK_WORKLOADS,
                        disciplines=LOCK_DISCIPLINE_SET,
                        oracles=LOCK_ORACLES) -> list[SimConfig]:
    """The full workload x discipline x oracle product as one flat batch
    for a single (sharded) :func:`repro.core.xdes.simulate_batch` call.

    Row order is scenario-major, then workload, then (discipline, oracle)
    variant — reshape to ``(n_scenarios, n_workloads, n_variants)``.
    Scenarios follow the :func:`sample_scenarios` seed contract, so every
    workload row sees the same machines scenario-by-scenario and results
    are comparable cell-by-cell with the discipline diagram."""
    from repro.core.policy import DEFAULT_ALPHA

    disc_variants = lock_discipline_variants(disciplines, oracles)
    return [
        SimConfig(v["lock"], threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[v["lock"]],
                  seed=sc["seed"], oracle=v["oracle"], workload=w,
                  **lock_workload_params(sc))
        for sc in sample_scenarios(n_scenarios, seed)
        for w in workloads
        for v in disc_variants
    ]


# -- fault x discipline x oracle diagram grid ------------------------------
#: Fault rows of the interference diagram: every FAULT_ROW
#: (repro.core.policy) is represented — the benign baseline plus
#: lock-holder preemption, CPU oversubscription, lost wake-ups with
#: timeout recovery, and timer jitter.
LOCK_FAULTS = ("none", "preempt", "oversub", "lostwake", "jitter")
#: Per-row fault intensity: the probability/fraction knob of each row at
#: a level where the spin-vs-sleep ranking visibly flips (preempt/oversub
#: strong enough to starve spinners, wake faults frequent enough to tax
#: sleepers) without collapsing every discipline to zero throughput.
LOCK_FAULT_RATES = {"none": 0.0, "preempt": 0.6, "oversub": 0.6,
                    "lostwake": 0.5, "jitter": 0.5}


def lock_fault_params(sc: dict) -> dict:
    """Scenario-scaled fault timescale: the off-CPU / recovery window is
    ``4 x (cs_hi + ncs_hi)`` — ~8 mean CS+NCS rounds, long enough that a
    preempted holder visibly stalls its waiters, short enough that every
    auto-planned horizon (~``target_cs/2`` rounds) samples dozens of
    windows (the DES parity band needs many windows per run — see
    docs/robustness.md)."""
    return dict(fault_scale=4.0 * (sc["cs_hi"] + sc["ncs_hi"]))


def lock_fault_variants(faults=LOCK_FAULTS,
                        disciplines=LOCK_DISCIPLINE_SET,
                        oracles=LOCK_ORACLES) -> list[dict]:
    """The ``(fault, discipline, oracle)`` variant axis of the fault
    diagram: the discipline x oracle variants (windowed-row pruning of
    :func:`lock_discipline_variants`) replicated under every fault row,
    fault-major."""
    return [dict(fault=f, fault_rate=LOCK_FAULT_RATES[f], **v)
            for f in faults
            for v in lock_discipline_variants(disciplines, oracles)]


def lock_fault_sweep(n_scenarios: int = 100, seed: int = 0,
                     faults=LOCK_FAULTS,
                     disciplines=LOCK_DISCIPLINE_SET,
                     oracles=LOCK_ORACLES) -> list[SimConfig]:
    """The full fault x discipline x oracle product as one flat batch for
    a single (sharded) :func:`repro.core.xdes.simulate_batch` call.

    Row order is scenario-major, then fault, then (discipline, oracle)
    variant — reshape to ``(n_scenarios, n_faults, n_variants)``.
    Scenarios follow the :func:`sample_scenarios` seed contract, so every
    fault row sees the same machines scenario-by-scenario and results are
    comparable cell-by-cell with the discipline diagram (the ``none`` row
    IS the discipline diagram's benign machine)."""
    from repro.core.policy import DEFAULT_ALPHA

    disc_variants = lock_discipline_variants(disciplines, oracles)
    return [
        SimConfig(v["lock"], threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[v["lock"]],
                  seed=sc["seed"], oracle=v["oracle"], fault=f,
                  fault_rate=LOCK_FAULT_RATES[f],
                  **lock_fault_params(sc))
        for sc in sample_scenarios(n_scenarios, seed)
        for f in faults
        for v in disc_variants
    ]


# -- arrival-rate x discipline diagram grid (open loop) --------------------
#: Arrival rows of the open-loop diagram (every non-closed ARRIVAL_ROW).
LOCK_ARRIVALS = ("poisson", "bursty")
#: Offered-load axis: fraction ``rho`` of each scenario's closed-form
#: service capacity, spanning under-load to past saturation (shedding).
LOCK_ARRIVAL_RHOS = (0.3, 0.6, 0.9, 1.2)


def lock_arrival_capacity(sc: dict) -> float:
    """Closed-form service-capacity estimate of a scenario (requests/s):
    the lock serializes at one CS per mean CS length, and below that the
    thread pool turns over a request per mean CS+NCS round per effective
    worker.  ``rho`` in :func:`lock_arrival_sweep` scales against this."""
    mean_cs = 0.5 * sc["cs_hi"]
    mean_round = 0.5 * (sc["cs_hi"] + sc["ncs_hi"])
    eff = min(sc["threads"], sc["cores"])
    return min(1.0 / max(mean_cs, 1e-12), eff / max(mean_round, 1e-12))


def lock_arrival_params(sc: dict) -> dict:
    """Scenario-scaled open-loop knobs: the latency SLO sits at 8 mean
    CS+NCS rounds — generous under light load, violated when queueing
    sets in — and the bursty arrival gate cycles with the same scenario-
    scaled period as the workload diagram (several phases per horizon)."""
    return dict(slo=4.0 * (sc["cs_hi"] + sc["ncs_hi"]),
                **lock_workload_params(sc))


def lock_arrival_variants(arrivals=LOCK_ARRIVALS, rhos=LOCK_ARRIVAL_RHOS,
                          disciplines=LOCK_DISCIPLINE_SET,
                          oracles=LOCK_ORACLES) -> list[dict]:
    """The ``(arrival, rho, discipline, oracle)`` variant axis of the
    arrival diagram: the discipline x oracle variants (windowed-row
    pruning of :func:`lock_discipline_variants`) replicated under every
    (arrival row, offered load) cell, arrival-major then rho."""
    return [dict(arrival=a, rho=r, **v)
            for a in arrivals
            for r in rhos
            for v in lock_discipline_variants(disciplines, oracles)]


def lock_arrival_sweep(n_scenarios: int = 50, seed: int = 0,
                       arrivals=LOCK_ARRIVALS, rhos=LOCK_ARRIVAL_RHOS,
                       disciplines=LOCK_DISCIPLINE_SET,
                       oracles=LOCK_ORACLES) -> list[SimConfig]:
    """The full arrival x load x discipline x oracle product as one flat
    batch for a single (sharded) :func:`repro.core.xdes.simulate_batch`
    call with ``open_loop=True``.

    Row order is scenario-major, then arrival, then rho, then
    (discipline, oracle) variant — reshape to
    ``(n_scenarios, n_arrivals, n_rhos, n_variants)``.  Scenarios follow
    the :func:`sample_scenarios` seed contract, so every arrival cell
    sees the same machines scenario-by-scenario and tail-latency results
    are comparable cell-by-cell with the discipline diagram."""
    from repro.core.policy import DEFAULT_ALPHA

    variants = lock_arrival_variants(arrivals, rhos, disciplines, oracles)
    return [
        SimConfig(v["lock"], threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[v["lock"]],
                  seed=sc["seed"], oracle=v["oracle"],
                  arrival=v["arrival"],
                  arrival_rate=v["rho"] * lock_arrival_capacity(sc),
                  **lock_arrival_params(sc))
        for sc in sample_scenarios(n_scenarios, seed)
        for v in variants
    ]


# -- park-cost x discipline x oracle diagram grid (M:N environments) -------
#: Park-cost axis of the M:N lightweight-thread diagram: how expensive is
#: one park/unpark round trip relative to the baseline OS futex?  0.1 is a
#: user-level M:N scheduler (park = a userspace context switch), 1 the OS
#: baseline, 10/100 oversubscribed or VM-mediated kernels — spanning three
#: orders of magnitude so every sleep-leaning row gets visibly re-priced.
LOCK_PARK_COSTS = (0.1, 1.0, 10.0, 100.0)


def lock_park_variants(park_costs=LOCK_PARK_COSTS,
                       disciplines=LOCK_DISCIPLINE_SET,
                       oracles=LOCK_ORACLES) -> list[dict]:
    """The ``(park_cost, discipline, oracle)`` variant axis of the park
    diagram: the discipline x oracle variants (windowed-row pruning of
    :func:`lock_discipline_variants`) replicated under every park-cost
    environment, park-cost-major."""
    return [dict(park_cost=p, **v)
            for p in park_costs
            for v in lock_discipline_variants(disciplines, oracles)]


def lock_park_sweep(n_scenarios: int = 50, seed: int = 0,
                    park_costs=LOCK_PARK_COSTS,
                    disciplines=LOCK_DISCIPLINE_SET,
                    oracles=LOCK_ORACLES) -> list[SimConfig]:
    """The full park-cost x discipline x oracle product as one flat batch
    for a single (sharded) :func:`repro.core.xdes.simulate_batch` call.

    Row order is scenario-major, then park_cost, then (discipline, oracle)
    variant — reshape to ``(n_scenarios, n_park_costs, n_variants)``.
    Scenarios follow the :func:`sample_scenarios` seed contract, so every
    park-cost environment sees the same machines scenario-by-scenario and
    results are comparable cell-by-cell with the discipline diagram (the
    ``park_cost=1`` slice IS the discipline diagram's machine)."""
    from repro.core.policy import DEFAULT_ALPHA

    disc_variants = lock_discipline_variants(disciplines, oracles)
    return [
        SimConfig(v["lock"], threads=sc["threads"], cores=sc["cores"],
                  cs=(0.0, sc["cs_hi"]), ncs=(0.0, sc["ncs_hi"]),
                  wake_latency=sc["wake"],
                  alpha=sc["contention"] * DEFAULT_ALPHA[v["lock"]],
                  seed=sc["seed"], oracle=v["oracle"], park_cost=p)
        for sc in sample_scenarios(n_scenarios, seed)
        for p in park_costs
        for v in disc_variants
    ]


# -- array-native column twins (the streaming-sweep feed) ------------------
# Each lock_*_sweep generator above has a *_columns twin emitting RAW
# struct-of-arrays columns (repro.core.policy.RAW_CONFIG_FIELDS) directly
# — no per-config SimConfig objects — for repro.core.stream.sweep_stream.
# The twins are pinned field-for-field equal (values AND dtypes) to
# repro.core.policy.config_columns of the corresponding list, so either
# form feeds the same plans and bit-identical simulations.

def sample_scenario_columns(n_scenarios: int, seed: int = 0) -> dict:
    """:func:`sample_scenarios` packed as (S,) column arrays — the same
    RNG draws in the same order (the seed contract), so array-native
    sweeps see exactly the machines the list path sees."""
    import numpy as np

    sc = sample_scenarios(n_scenarios, seed)
    return {k: np.asarray([s[k] for s in sc],
                          np.int64 if k in ("threads", "cores", "seed")
                          else np.float64)
            for k in ("threads", "cores", "cs_hi", "ncs_hi", "wake",
                      "contention", "seed")}


def _product_columns(sc: dict, variants: list[dict],
                     wl: dict | None = None) -> dict:
    """Scenario-major x variant-minor product as RAW columns: scenario
    feature columns repeated per variant, variant columns tiled per
    scenario, ``alpha = contention x DEFAULT_ALPHA[lock]`` per row.
    ``wl`` optionally carries per-scenario (S,) workload-knob columns
    (:func:`lock_workload_params` vectorized); missing knobs take the
    SimConfig defaults."""
    import numpy as np

    from repro.core.policy import (DEFAULT_ALPHA, DEFAULT_SPIN_BUDGET,
                                   ORACLE_IDS, POLICY_IDS, WORKLOAD_IDS)

    S, V = len(sc["seed"]), len(variants)
    rep = lambda a, dt: np.repeat(np.asarray(a, dt), V)
    tile = lambda a: np.tile(a, S)
    lock_names = [v.get("lock", "mutable") for v in variants]
    wl = wl or {}
    wlcol = lambda key, dflt: (rep(wl[key], np.float64) if key in wl
                               else np.full(S * V, dflt, np.float64))
    return {
        "lock": tile(np.asarray([POLICY_IDS[n] for n in lock_names],
                                np.int32)),
        "threads": rep(sc["threads"], np.int32),
        "cores": rep(sc["cores"], np.int32),
        "cs_lo": np.zeros(S * V, np.float64),
        "cs_hi": rep(sc["cs_hi"], np.float64),
        "ncs_lo": np.zeros(S * V, np.float64),
        "ncs_hi": rep(sc["ncs_hi"], np.float64),
        "wake_latency": rep(sc["wake"], np.float64),
        "alpha": rep(sc["contention"], np.float64)
        * tile(np.asarray([DEFAULT_ALPHA[n] for n in lock_names],
                          np.float64)),
        "sws_init": np.ones(S * V, np.int32),
        "sws_max": tile(np.asarray(
            [-1 if v.get("sws_max") is None else v["sws_max"]
             for v in variants], np.int32)),
        "k": tile(np.asarray([v.get("k", 10) for v in variants],
                             np.int32)),
        "spin_budget": np.full(S * V, DEFAULT_SPIN_BUDGET, np.float64),
        "seed": rep(sc["seed"], np.uint32),
        "oracle": tile(np.asarray(
            [ORACLE_IDS[v.get("oracle", "paper")] for v in variants],
            np.int32)),
        "workload": tile(np.asarray(
            [WORKLOAD_IDS[v.get("workload", "constant")]
             for v in variants], np.int32)),
        "wl_period": wlcol("wl_period", 1e-4),
        "wl_duty": wlcol("wl_duty", 0.25),
        "wl_burst": wlcol("wl_burst", 8.0),
        "wl_spread": wlcol("wl_spread", 4.0),
        "arrival_phase": np.zeros(S * V, np.float64),
    }


def lock_scenario_columns(n_scenarios: int = 200, seed: int = 0,
                          locks=LOCK_DISCIPLINES) -> dict:
    """Column twin of :func:`lock_scenario_sweep`."""
    return _product_columns(sample_scenario_columns(n_scenarios, seed),
                            [dict(lock=l) for l in locks])


def lock_oracle_columns(n_scenarios: int = 200, seed: int = 0,
                        oracles=LOCK_ORACLES, ks=LOCK_ORACLE_KS,
                        sws_maxes=LOCK_ORACLE_SWS_MAX) -> dict:
    """Column twin of :func:`lock_oracle_sweep`."""
    return _product_columns(sample_scenario_columns(n_scenarios, seed),
                            lock_oracle_variants(oracles, ks, sws_maxes))


def lock_discipline_columns(n_scenarios: int = 200, seed: int = 0,
                            disciplines=LOCK_DISCIPLINE_SET,
                            oracles=LOCK_ORACLES) -> dict:
    """Column twin of :func:`lock_discipline_sweep`."""
    return _product_columns(sample_scenario_columns(n_scenarios, seed),
                            lock_discipline_variants(disciplines, oracles))


def lock_workload_columns(n_scenarios: int = 100, seed: int = 0,
                          workloads=LOCK_WORKLOADS,
                          disciplines=LOCK_DISCIPLINE_SET,
                          oracles=LOCK_ORACLES) -> dict:
    """Column twin of :func:`lock_workload_sweep` (the scenario-scaled
    workload knobs of :func:`lock_workload_params` computed as columns)."""
    import numpy as np

    sc = sample_scenario_columns(n_scenarios, seed)
    S = len(sc["seed"])
    wl = dict(wl_period=16.0 * (sc["cs_hi"] + sc["ncs_hi"]),
              wl_duty=np.full(S, 0.25), wl_burst=np.full(S, 8.0),
              wl_spread=np.full(S, 4.0))
    return _product_columns(
        sc, lock_workload_variants(workloads, disciplines, oracles), wl)


def lock_fault_columns(n_scenarios: int = 100, seed: int = 0,
                       faults=LOCK_FAULTS,
                       disciplines=LOCK_DISCIPLINE_SET,
                       oracles=LOCK_ORACLES) -> dict:
    """Column twin of :func:`lock_fault_sweep` (the scenario-scaled fault
    window of :func:`lock_fault_params` computed as a column)."""
    import numpy as np

    from repro.core.policy import FAULT_IDS

    sc = sample_scenario_columns(n_scenarios, seed)
    variants = lock_fault_variants(faults, disciplines, oracles)
    V = len(variants)
    cols = _product_columns(sc, variants)
    cols["fault"] = np.tile(np.asarray(
        [FAULT_IDS[v["fault"]] for v in variants], np.int32), len(sc["seed"]))
    cols["fault_rate"] = np.tile(np.asarray(
        [v["fault_rate"] for v in variants], np.float64), len(sc["seed"]))
    cols["fault_scale"] = np.repeat(4.0 * (sc["cs_hi"] + sc["ncs_hi"]), V)
    return cols


def lock_arrival_columns(n_scenarios: int = 50, seed: int = 0,
                         arrivals=LOCK_ARRIVALS, rhos=LOCK_ARRIVAL_RHOS,
                         disciplines=LOCK_DISCIPLINE_SET,
                         oracles=LOCK_ORACLES) -> dict:
    """Column twin of :func:`lock_arrival_sweep` (capacity, SLO, and the
    burst-gate knobs of :func:`lock_arrival_params` computed as columns)."""
    import numpy as np

    from repro.core.policy import ARRIVAL_IDS, QUEUE_MAX

    sc = sample_scenario_columns(n_scenarios, seed)
    S = len(sc["seed"])
    variants = lock_arrival_variants(arrivals, rhos, disciplines, oracles)
    V = len(variants)
    wl = dict(wl_period=16.0 * (sc["cs_hi"] + sc["ncs_hi"]),
              wl_duty=np.full(S, 0.25), wl_burst=np.full(S, 8.0),
              wl_spread=np.full(S, 4.0))
    cols = _product_columns(sc, variants, wl)
    # vectorized lock_arrival_capacity (same float64 ops, same values)
    mean_cs = 0.5 * sc["cs_hi"]
    mean_round = 0.5 * (sc["cs_hi"] + sc["ncs_hi"])
    eff = np.minimum(sc["threads"], sc["cores"]).astype(np.float64)
    cap = np.minimum(1.0 / np.maximum(mean_cs, 1e-12),
                     eff / np.maximum(mean_round, 1e-12))
    cols["arrival"] = np.tile(np.asarray(
        [ARRIVAL_IDS[v["arrival"]] for v in variants], np.int32), S)
    cols["arrival_rate"] = (
        np.tile(np.asarray([v["rho"] for v in variants], np.float64), S)
        * np.repeat(cap, V))
    cols["queue_cap"] = np.full(S * V, QUEUE_MAX, np.int32)
    cols["slo"] = np.repeat(4.0 * (sc["cs_hi"] + sc["ncs_hi"]), V)
    cols["tie_break"] = np.zeros(S * V, np.int32)
    return cols


def lock_park_columns(n_scenarios: int = 50, seed: int = 0,
                      park_costs=LOCK_PARK_COSTS,
                      disciplines=LOCK_DISCIPLINE_SET,
                      oracles=LOCK_ORACLES) -> dict:
    """Column twin of :func:`lock_park_sweep`."""
    import numpy as np

    sc = sample_scenario_columns(n_scenarios, seed)
    variants = lock_park_variants(park_costs, disciplines, oracles)
    cols = _product_columns(sc, variants)
    cols["park_cost"] = np.tile(np.asarray(
        [v["park_cost"] for v in variants], np.float64), len(sc["seed"]))
    return cols


#: Named sweep registry (mirrors the model-config registry above).
LOCK_SWEEPS = {
    "fig3": lock_fig3_grid,
    "scenario": lock_scenario_sweep,
    "oracle": lock_oracle_sweep,
    "discipline": lock_discipline_sweep,
    "workload": lock_workload_sweep,
    "arrival": lock_arrival_sweep,
    "fault": lock_fault_sweep,
    "park": lock_park_sweep,
}

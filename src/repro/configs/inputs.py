"""ShapeDtypeStruct stand-ins for every model input — the dry-run pattern:
weak-type-correct, shardable, zero device allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import models
from .base import ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.input_kind == "frames":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               jnp.bfloat16)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.input_kind == "frames":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               jnp.bfloat16)
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, tokens) for serve_step: KV cache of seq_len positions, one
    new token."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: models.init_cache(cfg, B, S))
    tokens = _sds((B, 1), jnp.int32)
    return cache, tokens


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Inputs for the step function this shape exercises."""
    if shape.step == "train":
        return (train_inputs(cfg, shape),)
    if shape.step == "prefill":
        return (prefill_inputs(cfg, shape),)
    if shape.step == "decode":
        return decode_inputs(cfg, shape)
    raise ValueError(shape.step)


def concrete_batch(cfg: ModelConfig, batch_size: int, seq_len: int, key):
    """Small concrete batch for smoke tests / examples."""
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch_size, seq_len), 0, cfg.vocab_size,
                                jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.input_kind == "frames":
        batch["frames"] = jax.random.normal(
            k2, (batch_size, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch

"""RWKV6 ("Finch") mixer: data-dependent per-channel decay linear attention.

Time-mix recurrence per head (state S: (head_dim, head_dim) matrix):

    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

with w_t ∈ (0,1) computed *from the input* (the paper's data-dependent decay)
and u a learned "bonus" for the current token.

XLA path scans over time in chunks (checkpointed like the mamba scan); the
Pallas kernel (:mod:`repro.kernels.rwkv6_scan`) implements the chunked
intra/inter block form for TPU.

Channel-mix (rwkv_ffn) is the squared-relu K/V gating of the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RWKV6Config
from repro.sharding import specs as sh

from .layers import fan_in_init, normal, zeros

_CHUNK = 64


def init_rwkv6(key, rcfg: RWKV6Config, d_model: int, dtype):
    D = d_model
    H = D // rcfg.head_dim
    ks = jax.random.split(key, 12)
    p = {
        # ddlerp token-shift mixers: 5 targets (w,k,v,r,g) + base
        "mu_base": normal(ks[0], (D,), 0.02, jnp.float32),
        "mu_wkvrg": normal(ks[1], (5, D), 0.02, jnp.float32),
        "ddlerp_a": normal(ks[2], (D, 5 * rcfg.lora_mix), 0.02, jnp.float32),
        "ddlerp_b": normal(ks[3], (5, rcfg.lora_mix, D), 0.02, jnp.float32),
        # decay: w = exp(-exp(w0 + tanh(xw @ A) @ B))
        "w0": normal(ks[4], (D,), 0.02, jnp.float32) - 6.0,
        "lora_wa": normal(ks[5], (D, rcfg.lora_w), 0.02, jnp.float32),
        "lora_wb": normal(ks[6], (rcfg.lora_w, D), 0.02, jnp.float32),
        "u": normal(ks[7], (D,), 0.02, jnp.float32),
        "w_r": fan_in_init(ks[8], (D, D), dtype),
        "w_k": fan_in_init(ks[9], (D, D), dtype),
        "w_v": fan_in_init(ks[10], (D, D), dtype),
        "w_g": fan_in_init(ks[11], (D, D), dtype),
        "w_o": fan_in_init(jax.random.fold_in(key, 99), (D, D), dtype),
        "ln_w": zeros((D,), jnp.float32),
        "ln_b": zeros((D,), jnp.float32),
    }
    return p


def init_rwkv_ffn(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "mu_k": normal(ks[0], (d_model,), 0.02, jnp.float32),
        "mu_r": normal(ks[1], (d_model,), 0.02, jnp.float32),
        "w_k": fan_in_init(ks[2], (d_model, d_ff), dtype),
        "w_v": fan_in_init(ks[3], (d_ff, d_model), dtype),
        "w_r": fan_in_init(jax.random.fold_in(key, 7), (d_model, d_model), dtype),
    }


def _token_shift(x, last=None):
    """Previous token's x; first position uses ``last`` (decode cache) or 0."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """RWKV6 data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    sx = (xx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    base = xf + sx * p["mu_base"]
    low = jnp.tanh(jnp.einsum("btd,dm->btm", base, p["ddlerp_a"]))
    B, T, _ = x.shape
    low = low.reshape(B, T, 5, -1)
    adj = jnp.einsum("btsm,smd->sbtd", low, p["ddlerp_b"])  # (5, B, T, D)
    mixed = xf[None] + sx[None] * (p["mu_wkvrg"][:, None, None, :] + adj)
    return mixed  # f32 (5, B, T, D)


def _decay(p, xw):
    """w_t in (0, 1): exp(-exp(w0 + lora(xw)))."""
    lo = jnp.tanh(xw @ p["lora_wa"]) @ p["lora_wb"]
    return jnp.exp(-jnp.exp(p["w0"] + lo))


def _wkv_chunk_scan(r, k, v, w, u, head_dim: int, chunk: int = _CHUNK,
                    state0=None, return_state: bool = False):
    """Linear-attention scan.  r,k,v,w: (B, T, D) f32 (w in (0,1)).

    Per head h of size n: S_t = diag(w) S + kᵀv;  y = r (S + diag(u) kᵀv).
    """
    B, T, D = r.shape
    n = head_dim
    H = D // n
    rs = r.reshape(B, T, H, n)
    ks_ = k.reshape(B, T, H, n)
    vs = v.reshape(B, T, H, n)
    ws = w.reshape(B, T, H, n)
    uu = u.reshape(H, n)

    if T % chunk:
        pad = chunk - T % chunk
        rs, ks_, vs = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                       for a in (rs, ks_, vs))
        ws = jnp.pad(ws, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
    Tp = rs.shape[1]
    nc = Tp // chunk

    def per_chunk(S, xs):
        r_c, k_c, v_c, w_c = xs                     # (B, c, H, n)

        @jax.checkpoint
        def inner(S, r_c, k_c, v_c, w_c):
            def step(S, t):
                r_t, k_t, v_t, w_t = t              # (B, H, n)
                kv = k_t[..., :, None] * v_t[..., None, :]   # (B,H,n,n)
                y = jnp.einsum("bhk,bhkv->bhv", r_t,
                               S + uu[..., None] * kv)
                S = w_t[..., None] * S + kv
                return S, y

            ts = tuple(a.swapaxes(0, 1) for a in (r_c, k_c, v_c, w_c))
            S, ys = jax.lax.scan(step, S, ts)
            return S, ys.swapaxes(0, 1)             # (B, c, H, n)

        return inner(S, r_c, k_c, v_c, w_c)

    xs = tuple(a.reshape(B, nc, chunk, H, n).swapaxes(0, 1)
               for a in (rs, ks_, vs, ws))
    S0 = state0 if state0 is not None else jnp.zeros((B, H, n, n), jnp.float32)
    S, ys = jax.lax.scan(per_chunk, S0, xs)
    y = ys.swapaxes(0, 1).reshape(B, Tp, D)[:, :T]
    if return_state:
        return y, S
    return y


def _groupnorm(x, w, b, H: int, eps: float = 64e-5):
    """Per-head groupnorm (RWKV normalizes each head's output)."""
    B, T, D = x.shape
    n = D // H
    xh = x.reshape(B, T, H, n).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(B, T, D)
    return y * w + b


def rwkv6_forward(rcfg: RWKV6Config, params, x, shift_state=None,
                  wkv_state=None, return_state: bool = False):
    """x: (B, T, D).  Optional decode states (last token, S matrix)."""
    B, T, D = x.shape
    H = D // rcfg.head_dim
    xx = _token_shift(x, shift_state)
    mixed = _ddlerp(params, x, xx)                  # (5, B, T, D) f32
    xw, xk, xv, xr, xg = mixed
    w = _decay(params, xw)                          # (B, T, D) f32
    r = jnp.einsum("btd,de->bte", xr.astype(x.dtype), params["w_r"])
    k = jnp.einsum("btd,de->bte", xk.astype(x.dtype), params["w_k"])
    v = jnp.einsum("btd,de->bte", xv.astype(x.dtype), params["w_v"])
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg.astype(x.dtype),
                               params["w_g"]))
    out = _wkv_chunk_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, params["u"],
                          rcfg.head_dim, state0=wkv_state,
                          return_state=return_state)
    if return_state:
        out, S = out
    y = _groupnorm(out, params["ln_w"], params["ln_b"], H)
    y = (y * g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("btd,de->bte", y, params["w_o"])
    y = sh.shard(y, "batch", "seq", "dmodel")
    if return_state:
        return y, (x[:, -1], S)
    return y


def rwkv_ffn_forward(params, x, shift_state=None, return_state: bool = False):
    xx = _token_shift(x, shift_state)
    sx = (xx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xk = (xf + sx * params["mu_k"]).astype(x.dtype)
    xr = (xf + sx * params["mu_r"]).astype(x.dtype)
    k = jnp.einsum("btd,df->btf", xk, params["w_k"])
    k = sh.shard(k, "batch", "seq", "ffn")
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("btf,fd->btd", k, params["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["w_r"]))
    y = r * kv
    y = sh.shard(y, "batch", "seq", "dmodel")
    if return_state:
        return y, x[:, -1]
    return y


# -- decode ------------------------------------------------------------------
def rwkv6_decode_init(rcfg: RWKV6Config, d_model: int, batch: int, dtype):
    H = d_model // rcfg.head_dim
    return {
        "att_shift": jnp.zeros((batch, d_model), dtype),
        "ffn_shift": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, H, rcfg.head_dim, rcfg.head_dim),
                         jnp.float32),
    }


def rwkv6_decode_step(rcfg: RWKV6Config, params, ffn_params, x, cache,
                      norm1_fn, norm2_fn):
    """One token through time-mix + channel-mix with cached states."""
    h = norm1_fn(x)
    y, (att_shift, wkv) = rwkv6_forward(
        rcfg, params, h, shift_state=cache["att_shift"],
        wkv_state=cache["wkv"], return_state=True)
    x = x + y
    h = norm2_fn(x)
    y, ffn_shift = rwkv_ffn_forward(ffn_params, h,
                                    shift_state=cache["ffn_shift"],
                                    return_state=True)
    x = x + y
    return x, {"att_shift": att_shift, "ffn_shift": ffn_shift, "wkv": wkv}

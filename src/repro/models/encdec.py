"""Encoder-decoder backbone (whisper-large-v3).

Assignment note: the conv/mel frontend is a STUB — ``input_specs()`` feeds
precomputed frame embeddings (B, 1500, D), exactly as the shape grid
specifies for [audio] entries.  The backbone is the real deliverable:
32 encoder + 32 decoder layers, MHA (kv=20 ⇒ no GQA sharing), GELU MLPs,
LayerNorm, sinusoidal positions (whisper's decoder uses a learned table of
448 positions; the assigned shapes reach 32k, so we use the sinusoidal form
for both stacks — recorded in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import specs as sh

from . import attention as attn
from .layers import (dtype_of, init_embed, init_mlp_nogate, layernorm,
                     mlp_nogate, softmax_xent, unembed_logits, zeros, ones,
                     chunked_xent)


def _ln_init(d, dtype):
    return {"w": ones((d,), dtype), "b": zeros((d,), dtype)}


def _ln(p, x, eps=1e-5):
    return layernorm(x, p["w"], p["b"], eps)


def sinusoidal(positions, d_model):
    """positions (S,) or (B,S) -> (..., d_model) f32."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0)
                   * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _init_enc_layer(cfg, key):
    dtype = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_init(cfg.d_model, dtype),
            "attn": attn.init_attention(k1, cfg.attention, cfg.d_model, dtype),
            "ln2": _ln_init(cfg.d_model, dtype),
            "mlp": init_mlp_nogate(k2, cfg.d_model, cfg.d_ff, dtype)}


def _init_dec_layer(cfg, key):
    dtype = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _ln_init(cfg.d_model, dtype),
            "self_attn": attn.init_attention(k1, cfg.attention, cfg.d_model,
                                             dtype),
            "ln_x": _ln_init(cfg.d_model, dtype),
            "cross_attn": attn.init_attention(k2, cfg.attention, cfg.d_model,
                                              dtype),
            "ln2": _ln_init(cfg.d_model, dtype),
            "mlp": init_mlp_nogate(k3, cfg.d_model, cfg.d_ff, dtype)}


def init_params(cfg: ModelConfig, key):
    dtype = dtype_of(cfg.param_dtype)
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": init_embed(kt, cfg.vocab_size, cfg.d_model, dtype,
                            cfg.tie_embeddings),
        "encoder": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "enc_norm": _ln_init(cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "dec_norm": _ln_init(cfg.d_model, dtype),
    }


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------
def encode(cfg: ModelConfig, params, frames):
    """frames (B, S_enc, D) -> (B, S_enc, D)."""
    B, S, D = frames.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    x = frames + sinusoidal(pos, D).astype(frames.dtype)
    x = sh.shard(x, "batch", "seq", "dmodel")
    acfg = cfg.attention

    import dataclasses
    enc_acfg = dataclasses.replace(acfg, causal=False, use_rope=False)

    def body(h, p):
        hn = _ln(p["ln1"], h)
        y, _ = attn.self_attention(enc_acfg, p["attn"], hn, pos, 0, 1.0,
                                   cfg.norm_eps)
        h = h + y
        hn = _ln(p["ln2"], h)
        h = h + mlp_nogate(p["mlp"], hn, "gelu")
        return h, None

    wrapped = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(wrapped, x, params["encoder"])
    return _ln(params["enc_norm"], x)


def project_enc_kv_stack(cfg: ModelConfig, params, enc_out):
    """Per-decoder-layer cross K/V, stacked over layers."""
    def one(p):
        return attn.project_enc_kv(cfg.attention, p["cross_attn"], enc_out)
    return jax.vmap(one, in_axes=(0,))(params["decoder"])


# --------------------------------------------------------------------------
# Decoder (train / teacher-forced)
# --------------------------------------------------------------------------
def decode_train(cfg: ModelConfig, params, tokens, enc_out):
    from .layers import embed as embed_fn
    x = embed_fn(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    B, S, D = x.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    x = x + sinusoidal(pos, D).astype(x.dtype)
    enc_kv = project_enc_kv_stack(cfg, params, enc_out)

    def body(h, xs):
        p, ekv = xs
        hn = _ln(p["ln1"], h)
        y, _ = attn.self_attention(cfg.attention, p["self_attn"], hn, pos,
                                   0, 1.0, cfg.norm_eps)
        h = h + y
        hn = _ln(p["ln_x"], h)
        h = h + attn.cross_attention(cfg.attention, p["cross_attn"], hn, ekv,
                                     cfg.norm_eps)
        hn = _ln(p["ln2"], h)
        h = h + mlp_nogate(p["mlp"], hn, "gelu")
        return h, None

    wrapped = jax.checkpoint(body) if cfg.remat != "none" else body
    h, _ = jax.lax.scan(wrapped, x, (params["decoder"], enc_kv))
    return _ln(params["dec_norm"], h)


def loss_fn(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"].astype(
        dtype_of(cfg.dtype)))
    h = decode_train(cfg, params, batch["tokens"], enc_out)
    loss = chunked_xent(cfg, params["embed"], h, batch["labels"],
                        batch.get("mask"))
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


# --------------------------------------------------------------------------
# Prefill / decode
# --------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params, tokens, frames):
    from .layers import embed as embed_fn
    enc_out = encode(cfg, params, frames.astype(dtype_of(cfg.dtype)))
    enc_kv = project_enc_kv_stack(cfg, params, enc_out)
    x = embed_fn(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    B, S, D = x.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    x = x + sinusoidal(pos, D).astype(x.dtype)

    def body(h, xs):
        p, ekv = xs
        hn = _ln(p["ln1"], h)
        y, (k, v) = attn.self_attention(cfg.attention, p["self_attn"], hn,
                                        pos, 0, 1.0, cfg.norm_eps)
        h = h + y
        hn = _ln(p["ln_x"], h)
        h = h + attn.cross_attention(cfg.attention, p["cross_attn"], hn, ekv,
                                     cfg.norm_eps)
        hn = _ln(p["ln2"], h)
        h = h + mlp_nogate(p["mlp"], hn, "gelu")
        return h, (k, v)

    h, kv = jax.lax.scan(body, x, (params["decoder"], enc_kv))
    h = _ln(params["dec_norm"], h)
    logits = unembed_logits(params["embed"], h[:, -1], cfg.tie_embeddings)
    cache = {"k": kv[0], "v": kv[1], "enc_kv": enc_kv,
             "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    dtype = dtype_of(cfg.dtype)
    a = cfg.attention
    L = cfg.num_layers
    kv_shape = (L, batch, max_seq, a.num_kv_heads, a.head_dim)
    enc_kv_shape = (L, batch, cfg.encoder_seq, a.num_kv_heads, a.head_dim)
    return {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
            "enc_kv": (jnp.zeros(enc_kv_shape, dtype),
                       jnp.zeros(enc_kv_shape, dtype)),
            "len": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: ModelConfig, params, cache, tokens):
    from .layers import embed as embed_fn
    x = embed_fn(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    B, _, D = x.shape
    new_len = cache["len"] + 1
    pos = (new_len - 1)[:, None]
    x = x + sinusoidal(pos, D).astype(x.dtype)
    acfg = cfg.attention

    def body(h, xs):
        p, ck, cv, ekv = xs
        hn = _ln(p["ln1"], h)
        k, v = attn.decode_project_kv(acfg, p["self_attn"], hn, new_len, 1.0,
                                      cfg.norm_eps)
        # one-hot masked write — partitionable along batch AND kvseq (a
        # per-row scatter forces GSPMD to replicate the cache; §Perf cell C)
        onehot = (jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :]
                  == (new_len - 1)[:, None])[..., None, None]
        ck = jnp.where(onehot, k[:, :1].astype(ck.dtype), ck)
        cv = jnp.where(onehot, v[:, :1].astype(cv.dtype), cv)
        y = attn.decode_attention(acfg, p["self_attn"], hn, ck, cv, new_len,
                                  0, 1.0, cfg.norm_eps)
        h = h + y
        hn = _ln(p["ln_x"], h)
        h = h + attn.cross_attention(acfg, p["cross_attn"], hn, ekv,
                                     cfg.norm_eps)
        hn = _ln(p["ln2"], h)
        h = h + mlp_nogate(p["mlp"], hn, "gelu")
        return h, (ck, cv)

    h, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["enc_kv"]))
    h = _ln(params["dec_norm"], h)
    logits = unembed_logits(params["embed"], h[:, 0], cfg.tie_embeddings)
    return logits, dict(cache, k=nk, v=nv, len=new_len)

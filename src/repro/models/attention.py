"""GQA attention: training/prefill (blockwise online-softmax) and decode.

Design notes
------------
* **Blockwise path** (seq > _BLOCKWISE_MIN): ``lax.scan`` over KV blocks with
  an online-softmax carry — peak memory is O(S·block) instead of O(S²), which
  is what lets prefill_32k lower without a (32k)² score tensor.  This is also
  the pure-jnp oracle for the Pallas flash-attention kernel
  (:mod:`repro.kernels.flash_attention`).
* **Window as data**: the sliding-window size arrives as a (possibly traced)
  scalar so gemma3's 5-local:1-global schedule rides through a homogeneous
  scan-over-layers (window/theta are per-layer scan xs), keeping HLO size
  depth-independent.
* **Decode**: one new token against a sharded KV cache.  The softmax
  reductions over the KV-sequence dim are partitionable, so GSPMD inserts the
  pmax/psum combine (flash-decode) automatically when the cache is sharded
  over ``kvseq``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.sharding import specs as sh
from repro.sharding.compat import shard_map

from .layers import apply_rope, fan_in_init, rmsnorm, zeros

_BLOCKWISE_MIN = 8_192     # use the O(S·block) path above this many KV slots
_KV_BLOCK = 1_024
_NEG_INF = -1e30


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------
def init_attention(key, acfg: AttentionConfig, d_model: int, dtype):
    ks = jax.random.split(key, 6)
    H, KV, hd = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    p = {
        "wq": fan_in_init(ks[0], (d_model, H, hd), dtype, fan_axis=0),
        "wk": fan_in_init(ks[1], (d_model, KV, hd), dtype, fan_axis=0),
        "wv": fan_in_init(ks[2], (d_model, KV, hd), dtype, fan_axis=0),
        "wo": fan_in_init(ks[3], (H, hd, d_model), dtype, fan_axis=1),
    }
    if acfg.qkv_bias:
        p["bq"] = zeros((H, hd), dtype)
        p["bk"] = zeros((KV, hd), dtype)
        p["bv"] = zeros((KV, hd), dtype)
    if acfg.out_bias:
        p["bo"] = zeros((d_model,), dtype)
    if acfg.qk_norm:
        p["q_norm"] = zeros((hd,), dtype)
        p["k_norm"] = zeros((hd,), dtype)
    return p


def qkv_project(acfg: AttentionConfig, params, x, positions, rope_theta,
                norm_eps: float = 1e-6):
    """x: (B, S, D) -> q (B, S, H, hd), k/v (B, S, KV, hd), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if acfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if acfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], norm_eps)
        k = rmsnorm(k, params["k_norm"], norm_eps)
    if acfg.use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = sh.shard(q, "batch", "seq", "heads", None)
    # kvseq: context-parallel K/V (sequence-sharded) for archs whose head
    # count does not divide the model axis; None (default) leaves K/V
    # replicated over model and heads TP-sharded.
    k = sh.shard(k, "batch", "kvseq", "kvheads", None)
    v = sh.shard(v, "batch", "kvseq", "kvheads", None)
    return q, k, v


def _softcap(scores, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _expand_kv(k, n_rep: int, axis: int = 2):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by repetition (GQA)."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=axis)


# --------------------------------------------------------------------------
# Dense (materialized-scores) path — short sequences / tests oracle
# --------------------------------------------------------------------------
def attend_dense(acfg: AttentionConfig, q, k, v, q_pos, kv_pos, window,
                 kv_len=None):
    """q: (B, Sq, H, hd); k,v: (B, Sk, KV, hd); positions int32.

    window: scalar (0 = full) — may be traced.
    kv_len: optional scalar — valid KV prefix length (decode with a
            partially-filled cache).
    """
    H, KV = acfg.num_heads, acfg.num_kv_heads
    n_rep = H // KV
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)
    scale = 1.0 / math.sqrt(acfg.head_dim)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    scores = _softcap(scores, acfg.logit_softcap)

    mask = jnp.ones(scores.shape[-2:], bool)
    dq = q_pos[..., :, None]                     # (..., Sq, 1)
    dk = kv_pos[..., None, :]                    # (..., 1, Sk)
    if acfg.causal:
        mask = mask & (dq >= dk)
    w = jnp.asarray(window)
    mask = mask & jnp.where(w > 0, dq - dk < w, True)
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if kl.ndim == 1:                         # per-sequence lengths (B,)
            kl = kl[:, None, None]
        mask = mask & (dk < kl)
    if mask.ndim == scores.ndim - 1:             # batched positions
        mask = mask[:, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", p.astype(v.dtype), v)
    return out


# --------------------------------------------------------------------------
# Blockwise online-softmax path (memory O(S·block)); oracle for the Pallas
# flash kernel.  Scans over KV blocks; carry = (acc, row_max, row_sum).
# --------------------------------------------------------------------------
def attend_blockwise(acfg: AttentionConfig, q, k, v, q_pos, kv_pos, window,
                     kv_block: int = _KV_BLOCK):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = acfg.num_kv_heads
    n_rep = H // KV
    if Sk % kv_block != 0:
        pad = kv_block - Sk % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad),), constant_values=2**30)
        Sk += pad
    nk = Sk // kv_block
    scale = 1.0 / math.sqrt(hd)

    kb = k.reshape(B, nk, kv_block, KV, hd).swapaxes(0, 1)   # (nk, B, c, KV, hd)
    vb = v.reshape(B, nk, kv_block, KV, hd).swapaxes(0, 1)
    pb = kv_pos.reshape(nk, kv_block)

    def body(carry, xs):
        acc, m, l = carry                        # (B,Sq,H,hd), (B,H,Sq), (B,H,Sq)
        kc, vc, pc = xs
        kc = _expand_kv(kc, n_rep)
        vc = _expand_kv(vc, n_rep)
        s = jnp.einsum("bqhk,bchk->bhqc", q, kc).astype(jnp.float32) * scale
        s = _softcap(s, acfg.logit_softcap)
        dq = q_pos[:, None]                      # (Sq, 1)
        dk = pc[None, :]                         # (1, c)
        mask = jnp.ones((Sq, kv_block), bool)
        if acfg.causal:
            mask = mask & (dq >= dk)
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, dq - dk < w, True)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows: keep exp() finite
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqc,bchk->bqhk", p.astype(vc.dtype), vc)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None].astype(acc.dtype) \
            + pv.astype(acc.dtype)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    m0 = jnp.full((B, H, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Q-chunked path: scan over query chunks, full softmax over KV per chunk.
# Peak memory O(chunk * S) instead of O(S^2); the chunk body is remat'd so
# the backward never holds more than one chunk's scores.  The reductions
# over the KV dim are partitionable, so a ``kvseq``-sharded K/V lowers to
# context-parallel attention (partial max/sum + psum) under GSPMD.
# --------------------------------------------------------------------------
def attend_qchunk(acfg: AttentionConfig, q, k, v, q_pos, kv_pos, window,
                  q_chunk: int = 512):
    B, Sq, H, hd = q.shape
    nq = Sq // q_chunk
    qb = q.reshape(B, nq, q_chunk, H, hd).swapaxes(0, 1)   # (nq, B, c, H, hd)
    pb = q_pos.reshape(nq, q_chunk)

    def body(_, xs):
        qc, pc = xs
        out = attend_dense(acfg, qc, k, v, pc, kv_pos, window)
        return None, out

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = jax.lax.scan(body, None, (qb, pb))           # (nq, B, c, H, hd)
    return outs.swapaxes(0, 1).reshape(B, Sq, H, hd)


_Q_CHUNK = 512


# --------------------------------------------------------------------------
# Public entry points
# --------------------------------------------------------------------------
def self_attention(acfg: AttentionConfig, params, x, positions, window,
                   rope_theta, norm_eps: float = 1e-6,
                   static_window: int | None = None):
    """Training/prefill self-attention.  x: (B, S, D); positions: (S,).

    ``window`` may be a traced per-layer scalar (gemma3's schedule rides
    the layer scan as data).  ``static_window`` is its compile-time value
    when the arch has a homogeneous schedule — that is what lets the
    Pallas flash kernel (which specializes on the mask) take over as the
    production path (``REPRO_USE_PALLAS=1`` or a TPU backend).
    """
    B, S, D = x.shape
    q, k, v = qkv_project(acfg, params, x, positions, rope_theta, norm_eps)
    from repro.kernels import ops as kops
    if (static_window is not None and kops.use_pallas()
            and not sh.active()):
        out = kops.attention(q, k, v, causal=acfg.causal,
                             window=static_window,
                             softcap=acfg.logit_softcap)
    elif S > _Q_CHUNK and S % _Q_CHUNK == 0:
        out = attend_qchunk(acfg, q, k, v, positions, positions, window)
    else:
        out = attend_dense(acfg, q, k, v, positions, positions, window)
    y = jnp.einsum("bqhk,hkd->bqd", out.astype(x.dtype), params["wo"])
    if acfg.out_bias:
        y = y + params["bo"]
    return sh.shard(y, "batch", "seq", "dmodel"), (k, v)


def cross_attention(acfg: AttentionConfig, params, x, enc_kv, norm_eps=1e-6):
    """Decoder cross-attention.  enc_kv = (k, v): (B, Senc, KV, hd), already
    projected from the encoder output (computed once per sequence)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if acfg.qkv_bias:
        q = q + params["bq"]
    if acfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], norm_eps)
    k, v = enc_kv
    Sq, Sk = q.shape[1], k.shape[1]
    q_pos = jnp.zeros((Sq,), jnp.int32)
    kv_pos = jnp.zeros((Sk,), jnp.int32)
    noncausal = AttentionConfig(
        num_heads=acfg.num_heads, num_kv_heads=acfg.num_kv_heads,
        head_dim=acfg.head_dim, causal=False, use_rope=False,
        logit_softcap=acfg.logit_softcap)
    out = attend_dense(noncausal, q, k, v, q_pos, kv_pos, window=0)
    y = jnp.einsum("bqhk,hkd->bqd", out.astype(x.dtype), params["wo"])
    if acfg.out_bias:
        y = y + params["bo"]
    return y


def project_enc_kv(acfg: AttentionConfig, params, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    if acfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    if acfg.qk_norm:
        k = rmsnorm(k, params["k_norm"])
    return k, v


def decode_attention(acfg: AttentionConfig, params, x, cache_k, cache_v,
                     cache_len, window, rope_theta, norm_eps: float = 1e-6):
    """Single-step decode.  x: (B, 1, D); cache_k/v: (B, Smax, KV, hd) with
    ``cache_len`` valid slots (the new token's k/v must already be inserted
    by the caller).  Positions: new token at ``cache_len - 1``.

    The softmax over the cache sequence dim is expressed with partitionable
    reductions, so a ``kvseq``-sharded cache lowers to flash-decode (local
    max/sum + pmax/psum) under GSPMD.
    """
    B = x.shape[0]
    pos = (jnp.asarray(cache_len) - 1).astype(jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.broadcast_to(pos, (B, 1))
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if acfg.qkv_bias:
        q = q + params["bq"]
    if acfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], norm_eps)
    if acfg.use_rope:
        q = apply_rope(q, positions, rope_theta)

    Smax = cache_k.shape[1]
    kv_pos = jnp.arange(Smax, dtype=jnp.int32)[None, :]       # (1, Smax)
    kv_pos = jnp.broadcast_to(kv_pos, (B, Smax))
    out = attend_dense(acfg, q, cache_k, cache_v, positions, kv_pos, window,
                       kv_len=cache_len)
    y = jnp.einsum("bqhk,hkd->bqd", out.astype(x.dtype), params["wo"])
    if acfg.out_bias:
        y = y + params["bo"]
    return y


def decode_attention_cp(acfg: AttentionConfig, params, x, cache_k, cache_v,
                        k_new, v_new, cache_len, window, rope_theta,
                        norm_eps: float = 1e-6):
    """Context-parallel flash-decode via explicit shard_map: the KV cache
    stays sequence-sharded on the ``kvseq`` mesh axes; each shard computes a
    local partial softmax (max/sum) and a tiny (B, 1, H, hd) psum combines.

    GSPMD's auto-partitioning of the same math chooses to all-gather the
    (B, H, 1, S) attention weights instead (~0.5 GB/layer at 32k·128 —
    measured in §Perf cell C); writing the combine by hand removes those
    collectives entirely.
    """
    from repro.sharding import specs as shs
    from jax.sharding import PartitionSpec as P

    mesh = shs.current_mesh()
    rules = shs.current_rules()
    kv_axes = rules.resolve("kvseq")
    kv_axes = (kv_axes,) if isinstance(kv_axes, str) else kv_axes
    B, Smax = cache_k.shape[0], cache_k.shape[1]
    if (mesh is None or not kv_axes
            or Smax % math.prod(mesh.shape[a] for a in kv_axes) != 0):
        idx = (jnp.asarray(cache_len) - 1).astype(jnp.int32)
        onehot = (jnp.arange(Smax, dtype=jnp.int32)[None, :]
                  == idx[:, None])[..., None, None]
        ck = jnp.where(onehot, k_new[:, :1].astype(cache_k.dtype), cache_k)
        cv = jnp.where(onehot, v_new[:, :1].astype(cache_v.dtype), cache_v)
        y = decode_attention(acfg, params, x, ck, cv, cache_len, window,
                             rope_theta, norm_eps)
        return y, ck, cv

    pos = (jnp.asarray(cache_len) - 1).astype(jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.broadcast_to(pos, (B, 1))
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if acfg.qkv_bias:
        q = q + params["bq"]
    if acfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], norm_eps)
    if acfg.use_rope:
        q = apply_rope(q, positions, rope_theta)

    H, KV, hd = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    batch_axes = rules.resolve("batch")
    batch_axes = ((batch_axes,) if isinstance(batch_axes, str)
                  else (batch_axes or ()))
    batch_axes = tuple(a for a in batch_axes
                       if a in mesh.axis_names and a not in kv_axes)
    bspec = batch_axes if (batch_axes and B % math.prod(
        mesh.shape[a] for a in batch_axes) == 0) else None
    w = jnp.asarray(window)
    kl = jnp.asarray(cache_len)
    if kl.ndim == 0:
        kl = jnp.broadcast_to(kl, (B,))

    def body(q, k, v, kn, vn, kl, qpos):
        # k, v: (B, S_loc, KV, hd) local shard; kv positions are offset by
        # the shard index.  The new token's k/v is written as a LOCAL
        # scatter (only the owning shard touches memory, in place under
        # donation) before attending.
        ax = kv_axes[0] if len(kv_axes) == 1 else kv_axes
        shard_id = jax.lax.axis_index(ax)
        S_loc = k.shape[1]
        kv_pos = shard_id * S_loc + jnp.arange(S_loc, dtype=jnp.int32)
        local_idx = kl - 1 - shard_id * S_loc          # (B,)
        rows = jnp.arange(k.shape[0], dtype=jnp.int32)
        oob = jnp.where((local_idx >= 0) & (local_idx < S_loc),
                        local_idx, S_loc)              # drop if not ours
        k = k.at[rows, oob].set(kn[:, 0].astype(k.dtype), mode="drop")
        v = v.at[rows, oob].set(vn[:, 0].astype(v.dtype), mode="drop")
        # grouped-query einsum: never materialize the n_rep-expanded K/V
        # (the expand copies + f32 upcasts were the top traffic terms in
        # §Perf C iteration 3); f32 accumulate via preferred_element_type.
        Bl = q.shape[0]
        qg = q.reshape(Bl, 1, KV, n_rep, hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, acfg.logit_softcap)
        dq = qpos[:, :, None]                        # (B, 1, 1)
        dk = kv_pos[None, None, :]                   # (1, 1, S_loc)
        mask = (dq >= dk) & (dk < kl[:, None, None])
        mask = mask & jnp.where(w > 0, dq - dk < w, True)
        s = jnp.where(mask[:, None, None], s, _NEG_INF)  # (B,KV,g,1,S)
        m_loc = jnp.max(s, axis=-1)                  # (B, KV, g, 1)
        m_glob = jax.lax.pmax(m_loc, kv_axes)
        p = jnp.exp(s - m_glob[..., None])
        den = jax.lax.psum(jnp.sum(p, axis=-1), kv_axes)  # (B, KV, g, 1)
        num = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        num = jax.lax.psum(num, kv_axes)             # (B, 1, KV, g, hd)
        out = num / jnp.maximum(den, 1e-30)[:, None, :, :, 0][..., None]
        return out.reshape(Bl, 1, H, hd).astype(q.dtype), k, v

    kvspec = kv_axes[0] if len(kv_axes) == 1 else kv_axes
    out, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, kvspec, None, None),
                  P(bspec, kvspec, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec), P(bspec, None)),
        out_specs=(P(bspec, None, None, None),
                   P(bspec, kvspec, None, None),
                   P(bspec, kvspec, None, None)),
        check_vma=False)(q, cache_k, cache_v, k_new, v_new, kl, positions)
    y = jnp.einsum("bqhk,hkd->bqd", out.astype(x.dtype), params["wo"])
    if acfg.out_bias:
        y = y + params["bo"]
    return y, ck, cv


def decode_project_kv(acfg: AttentionConfig, params, x, cache_len, rope_theta,
                      norm_eps: float = 1e-6):
    """Project the new token's k/v (rope at position cache_len - 1)."""
    B = x.shape[0]
    pos = (jnp.asarray(cache_len) - 1).astype(jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.broadcast_to(pos, (B, 1))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if acfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    if acfg.qk_norm:
        k = rmsnorm(k, params["k_norm"], norm_eps)
    if acfg.use_rope:
        k = apply_rope(k, positions, rope_theta)
    return k, v

"""Mixture-of-Experts FFN: dense reference + expert-parallel production path.

Three execution paths, one semantics (top-k routing, renormalized gates,
capacity dropping on the EP path):

* :func:`moe_dense` — pure-jnp oracle: every expert applied to every token,
  combined with the gate matrix.  No dropping.  Used by tiny smoke tests and
  as the correctness reference for the EP path.
* :func:`moe_ep` — production training/prefill path: ``shard_map`` over the
  mesh; tokens sharded over (dp, model); sort-based capacity dispatch into an
  (E, C, D) buffer; ``all_to_all`` over the ``model`` (expert) axis; grouped
  expert matmuls; ``all_to_all`` back; scatter-add combine.  Expert weights
  may additionally be FSDP-sharded over the dp axes (all-gathered per layer
  inside the scan, which is the standard ZeRO-3 pattern).
* :func:`moe_decode` — decode path: one token per sequence, tokens
  replicated over the ``model`` axis; each device computes only its local
  experts' (masked) contribution and a ``psum`` combines.  Decode MoE is
  weight-bandwidth-bound, so the masked-compute overhead is irrelevant while
  the a2a is avoided entirely.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.sharding import specs as sh
from repro.sharding.compat import shard_map

from .layers import act_fn, fan_in_init


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------
def init_moe(key, mcfg: MoEConfig, d_model: int, dtype):
    ks = jax.random.split(key, 5)
    E, F = mcfg.num_experts, mcfg.d_ff
    p = {
        "router": fan_in_init(ks[0], (d_model, E), jnp.float32),
        "w_gate": fan_in_init(ks[1], (E, d_model, F), dtype, fan_axis=1),
        "w_in": fan_in_init(ks[2], (E, d_model, F), dtype, fan_axis=1),
        "w_out": fan_in_init(ks[3], (E, F, d_model), dtype, fan_axis=1),
    }
    if mcfg.shared_d_ff:
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, mcfg.shared_d_ff, dtype)
    return p


# --------------------------------------------------------------------------
# Routing (common)
# --------------------------------------------------------------------------
def route(mcfg: MoEConfig, router_w, tokens):
    """tokens (T, D) -> (gates (T, k) f32, eidx (T, k) i32, probs (T, E) f32)."""
    logits = tokens.astype(jnp.float32) @ router_w.astype(jnp.float32)
    if mcfg.router_logit_softcap:
        logits = jnp.tanh(logits / mcfg.router_logit_softcap) \
            * mcfg.router_logit_softcap
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, mcfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, eidx, probs


def aux_loss(mcfg: MoEConfig, probs, eidx, axis_names=()):
    """Switch-style load-balancing loss: E * sum_e f_e * P_e.

    f_e — fraction of routed assignments to expert e; P_e — mean router
    probability.  When called inside shard_map, ``axis_names`` psum-combines
    the statistics so the loss is the global one.
    """
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.float32)       # (T, k, E)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)             # (E,)
    p = jnp.mean(probs, axis=0)                               # (E,)
    cnt = jnp.ones((), jnp.float32)
    if axis_names:
        f = jax.lax.psum(f, axis_names)
        p = jax.lax.psum(p, axis_names)
        cnt = jax.lax.psum(cnt, axis_names)
    return E * jnp.sum((f / cnt) * (p / cnt))


# --------------------------------------------------------------------------
# Dense reference path
# --------------------------------------------------------------------------
def moe_dense(mcfg: MoEConfig, params, x, act: str, with_aux: bool = True):
    """x: (B, S, D).  Computes every expert on every token (oracle)."""
    B, S, D = x.shape
    tokens = x.reshape(B * S, D)
    gates, eidx, probs = route(mcfg, params["router"], tokens)
    E = mcfg.num_experts
    gate_mat = jnp.zeros((B * S, E), jnp.float32)
    gate_mat = gate_mat.at[jnp.arange(B * S)[:, None], eidx].set(gates)

    h = jnp.einsum("td,edf->etf", tokens, params["w_gate"])
    u = jnp.einsum("td,edf->etf", tokens, params["w_in"])
    y = act_fn(act)(h) * u
    y = jnp.einsum("etf,efd->etd", y, params["w_out"])        # (E, T, D)
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), gate_mat)
    out = out.reshape(B, S, D).astype(x.dtype)
    if mcfg.shared_d_ff:
        from .layers import mlp
        out = out + mlp(params["shared"], x, act)
    aux = aux_loss(mcfg, probs, eidx) if with_aux else jnp.zeros((), jnp.float32)
    return out, aux


# --------------------------------------------------------------------------
# Expert-parallel path (training / prefill)
# --------------------------------------------------------------------------
def _dispatch_local(mcfg: MoEConfig, tokens, gates, eidx, capacity):
    """Sort-based capacity dispatch on one device.

    Returns (send_buf (E, C, D), combine_idx, combine_gate, keep) where
    ``combine_idx[t*k + j]`` is the flat (E*C) slot of assignment j of token
    t (or an overflow slot that is masked by ``keep``).
    """
    T, D = tokens.shape
    K, E, C = mcfg.top_k, mcfg.num_experts, capacity
    eid_flat = eidx.reshape(T * K)
    gate_flat = gates.reshape(T * K)
    tok_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)

    order = jnp.argsort(eid_flat, stable=True)
    sorted_eid = eid_flat[order]
    # rank of each assignment within its expert segment
    seg_start = jnp.searchsorted(sorted_eid, jnp.arange(E, dtype=sorted_eid.dtype),
                                 side="left")
    rank = jnp.arange(T * K, dtype=jnp.int32) - seg_start[sorted_eid].astype(jnp.int32)
    keep_sorted = rank < C
    # overflow assignments scatter out-of-bounds and are dropped, so they can
    # never clobber a kept slot
    slot_sorted = jnp.where(keep_sorted,
                            sorted_eid.astype(jnp.int32) * C + rank,
                            E * C)

    send = jnp.zeros((E * C, D), tokens.dtype)
    src = tokens[tok_flat[order]]
    send = send.at[slot_sorted].set(src, mode="drop")

    # un-sort the bookkeeping so combine indexes align with (t, j) order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * K))
    slot = slot_sorted[inv]
    keep = keep_sorted[inv]
    return send.reshape(E, C, D), slot, gate_flat, keep, tok_flat


def _expert_ffn(w_gate, w_in, w_out, xs, act: str):
    """xs: (E_loc, C', D) grouped matmuls."""
    h = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xs, w_in)
    y = act_fn(act)(h) * u
    return jnp.einsum("ecf,efd->ecd", y, w_out)


def moe_ep(mcfg: MoEConfig, params, x, act: str, with_aux: bool = True):
    """Expert-parallel MoE over the active mesh.  x: (B, S, D) global."""
    mesh = sh.current_mesh()
    rules = sh.current_rules()
    ep_axis = "model"
    ep = mesh.shape[ep_axis]
    dp_axes = tuple(a for a in mesh.axis_names if a != ep_axis)
    fsdp_axes = rules.fsdp
    if isinstance(fsdp_axes, str):
        fsdp_axes = (fsdp_axes,)
    E = mcfg.num_experts
    assert E % ep == 0, f"experts {E} not divisible by ep={ep}"

    B, S, D = x.shape
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    if B % dp != 0:               # unshardable batch: dense fallback
        return moe_dense(mcfg, params, x, act, with_aux)
    # capacity is computed from *local* token count (static)
    seq_shard = ep if S % ep == 0 else 1
    t_loc = (B // dp) * (S // seq_shard)
    capacity = int(math.ceil(t_loc * mcfg.top_k / E * mcfg.capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)

    w_specs = {
        "router": P(*(None,) * 2),
        "w_gate": P(ep_axis, fsdp_axes, None),
        "w_in": P(ep_axis, fsdp_axes, None),
        "w_out": P(ep_axis, fsdp_axes, None),
    }
    if mcfg.shared_d_ff:
        w_specs["shared"] = {
            "w_gate": P(fsdp_axes, None), "w_in": P(fsdp_axes, None),
            "w_out": P(None, fsdp_axes)}
    x_spec = P(dp_axes, ep_axis if seq_shard > 1 else None, None)

    def body(wp, xl):
        # xl: (B_loc, S_loc, D)
        Bl, Sl, _ = xl.shape
        tokens = xl.reshape(Bl * Sl, D)
        if fsdp_axes:
            wg = jax.lax.all_gather(wp["w_gate"], fsdp_axes, axis=1, tiled=True)
            wi = jax.lax.all_gather(wp["w_in"], fsdp_axes, axis=1, tiled=True)
            wo = jax.lax.all_gather(wp["w_out"], fsdp_axes, axis=1, tiled=True)
        else:
            wg, wi, wo = wp["w_gate"], wp["w_in"], wp["w_out"]

        gates, eidx, probs = route(mcfg, wp["router"], tokens)
        send, slot, gate_flat, keep, tok_flat = _dispatch_local(
            mcfg, tokens, gates, eidx, capacity)

        # (E, C, D) -> (ep, E_loc, C, D) -> a2a -> rows become source shards
        send = send.reshape(ep, E // ep, capacity, D)
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (ep, E_loc, C, D); flatten source shard into capacity
        xs = recv.transpose(1, 0, 2, 3).reshape(E // ep, ep * capacity, D)
        ys = _expert_ffn(wg, wi, wo, xs, act)
        back = ys.reshape(E // ep, ep, capacity, D).transpose(1, 0, 2, 3)
        got = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        got = got.reshape(E * capacity, D)

        w = (gate_flat * keep.astype(jnp.float32))[:, None]
        contrib = got[slot].astype(jnp.float32) * w
        out = jnp.zeros((Bl * Sl, D), jnp.float32)
        out = out.at[tok_flat].add(contrib)
        out = out.astype(xl.dtype).reshape(Bl, Sl, D)
        if with_aux:
            aux = aux_loss(mcfg, probs, eidx,
                           axis_names=dp_axes + (ep_axis,))
        else:
            aux = jnp.zeros((), jnp.float32)
        return out, aux

    wanted = {k: params[k] for k in ("router", "w_gate", "w_in", "w_out")}
    specs_in = {k: w_specs[k] for k in wanted}
    out, aux = shard_map(
        body, mesh=mesh, in_specs=(specs_in, x_spec),
        out_specs=(x_spec, P()), check_vma=False)(wanted, x)
    if mcfg.shared_d_ff:
        from .layers import mlp
        out = out + mlp(params["shared"], x, act)
    return sh.shard(out, "batch", "seq", "dmodel"), aux


# --------------------------------------------------------------------------
# Decode path: tokens replicated over the expert axis; masked local compute
# + psum combine (no all_to_all on the latency-critical path).
# --------------------------------------------------------------------------
def moe_decode(mcfg: MoEConfig, params, x, act: str):
    mesh = sh.current_mesh()
    if mesh is None or mcfg.num_experts % mesh.shape["model"] != 0:
        out, _ = moe_dense(mcfg, params, x, act, with_aux=False)
        return out
    ep_axis = "model"
    ep = mesh.shape[ep_axis]
    dp_axes = tuple(a for a in mesh.axis_names if a != ep_axis)
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    E, D = mcfg.num_experts, x.shape[-1]
    E_loc = E // ep

    w_specs = {
        "router": P(None, None),
        "w_gate": P(ep_axis, None, None),
        "w_in": P(ep_axis, None, None),
        "w_out": P(ep_axis, None, None),
    }
    # batch=1 (long-context decode): replicate tokens over the dp axes
    x_spec = P(dp_axes if x.shape[0] % dp == 0 else None, None, None)

    def body(wp, xl):
        Bl, Sl, _ = xl.shape
        tokens = xl.reshape(Bl * Sl, D)
        gates, eidx, _ = route(mcfg, wp["router"], tokens)
        my = jax.lax.axis_index(ep_axis) * E_loc
        # gate matrix restricted to local experts: (T, E_loc)
        local = (eidx >= my) & (eidx < my + E_loc)            # (T, k)
        gmat = jnp.zeros((tokens.shape[0], E_loc), jnp.float32)
        gmat = gmat.at[jnp.arange(tokens.shape[0])[:, None],
                       jnp.clip(eidx - my, 0, E_loc - 1)].add(
            gates * local.astype(jnp.float32), mode="drop")
        h = jnp.einsum("td,edf->etf", tokens, wp["w_gate"])
        u = jnp.einsum("td,edf->etf", tokens, wp["w_in"])
        y = act_fn(act)(h) * u
        y = jnp.einsum("etf,efd->etd", y, wp["w_out"])
        out = jnp.einsum("etd,te->td", y.astype(jnp.float32), gmat)
        out = jax.lax.psum(out, ep_axis)
        return out.astype(xl.dtype).reshape(Bl, Sl, D)

    wanted = {k: params[k] for k in ("router", "w_gate", "w_in", "w_out")}
    out = shard_map(body, mesh=mesh, in_specs=(w_specs, x_spec),
                        out_specs=x_spec, check_vma=False)(wanted, x)
    if mcfg.shared_d_ff:
        from .layers import mlp
        out = out + mlp(params["shared"], x, act)
    return out


# --------------------------------------------------------------------------
# Dispatcher
# --------------------------------------------------------------------------
def moe_forward(mcfg: MoEConfig, params, x, act: str, mode: str = "train",
                with_aux: bool = True):
    """mode: train | prefill | decode."""
    mesh = sh.current_mesh()
    ep_ok = (mesh is not None and "model" in mesh.axis_names
             and mcfg.num_experts % mesh.shape["model"] == 0
             and mesh.shape["model"] > 1)
    if mode == "decode":
        return moe_decode(mcfg, params, x, act), jnp.zeros((), jnp.float32)
    if ep_ok:
        return moe_ep(mcfg, params, x, act, with_aux)
    return moe_dense(mcfg, params, x, act, with_aux)

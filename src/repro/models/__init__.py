"""Model zoo: one API over decoder-only and encoder-decoder stacks.

    init_params(cfg, key)            -> params pytree
    loss_fn(cfg, params, batch)      -> (loss, metrics)     [train_step]
    prefill(cfg, params, batch)      -> (logits, cache)     [prefill_step]
    decode_step(cfg, params, cache, tokens) -> (logits, cache')  [serve_step]
    init_cache(cfg, batch, max_seq)  -> empty decode cache
    param_count(cfg)                 -> exact N (eval_shape, no allocation)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import encdec, transformer


def init_params(cfg: ModelConfig, key):
    if cfg.is_encoder_decoder:
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch):
    if cfg.is_encoder_decoder:
        return encdec.loss_fn(cfg, params, batch)
    return transformer.loss_fn(cfg, params, batch)


def prefill(cfg: ModelConfig, params, batch):
    if cfg.is_encoder_decoder:
        return encdec.prefill(cfg, params, batch["tokens"], batch["frames"])
    return transformer.prefill(cfg, params, batch["tokens"])


def decode_step(cfg: ModelConfig, params, cache, tokens):
    if cfg.is_encoder_decoder:
        return encdec.decode_step(cfg, params, cache, tokens)
    return transformer.decode_step(cfg, params, cache, tokens)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.is_encoder_decoder:
        return encdec.init_cache(cfg, batch, max_seq)
    return transformer.init_cache(cfg, batch, max_seq)


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active params: total minus the non-selected experts."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = sum(1 for i in range(cfg.num_layers)
                       if cfg.pattern[i % cfg.layers_per_period].ffn == "moe")
    per_expert = 3 * cfg.d_model * m.d_ff
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


__all__ = ["init_params", "loss_fn", "prefill", "decode_step", "init_cache",
           "param_count", "active_param_count", "transformer", "encdec"]

"""Mamba-1 selective-state-space mixer (Jamba flavor).

XLA path: chunked scan — outer ``lax.scan`` over time chunks carrying the
(B, d_in, N) state, inner rematerialized scan within a chunk.  This bounds
both live memory (no (B, T, d_in, N) tensor) and backward residuals
(states checkpointed once per chunk).  The Pallas kernel
(:mod:`repro.kernels.mamba_scan`) implements the same chunking for TPU.

TP: d_in (the expanded channel dim) is sharded over ``model``; the scan is
per-channel so no collective appears between in_proj and out_proj.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig
from repro.sharding import specs as sh

from .layers import fan_in_init, normal, ones, rmsnorm, zeros

_CHUNK = 64


def dt_rank_of(mcfg: MambaConfig, d_model: int) -> int:
    return mcfg.dt_rank or -(-d_model // 16)


def init_mamba(key, mcfg: MambaConfig, d_model: int, dtype):
    d_in = mcfg.expand * d_model
    R = dt_rank_of(mcfg, d_model)
    N = mcfg.d_state
    ks = jax.random.split(key, 8)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    dt_init_std = R ** -0.5
    p = {
        "in_proj": fan_in_init(ks[0], (d_model, 2 * d_in), dtype),
        "conv_w": normal(ks[1], (mcfg.d_conv, d_in), 0.02, dtype),
        "conv_b": zeros((d_in,), dtype),
        "x_dt": fan_in_init(ks[2], (d_in, R), dtype),
        "x_b": fan_in_init(ks[3], (d_in, N), dtype),
        "x_c": fan_in_init(ks[4], (d_in, N), dtype),
        "dt_proj": normal(ks[5], (R, d_in), dt_init_std, dtype),
        "dt_bias": _dt_bias_init(ks[6], d_in),
        "a_log": jnp.log(a),                      # f32
        "d": ones((d_in,), jnp.float32),
        "norm": zeros((d_in,), dtype),
        "out_proj": fan_in_init(ks[7], (d_in, d_model), dtype),
    }
    return p


def _dt_bias_init(key, d_in, dt_min=1e-3, dt_max=0.1):
    u = jax.random.uniform(key, (d_in,), jnp.float32)
    dt = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
    # inverse softplus
    return jnp.log(jnp.expm1(dt))


def _causal_conv(x, w, b):
    """Depthwise causal conv; x: (B, T, d_in), w: (K, d_in)."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for j in range(K):
        shift = K - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[j]
    return out + b


def _ssm_chunk_scan(dt, Bmat, Cmat, x, a, chunk: int):
    """Selective scan, chunked.

    dt, x: (B, T, d_in) f32;  Bmat, Cmat: (B, T, N) f32;  a: (d_in, N) (< 0).
    Returns y: (B, T, d_in) f32.
    """
    Bsz, T, d_in = x.shape
    N = a.shape[-1]
    if T % chunk:
        pad = chunk - T % chunk
        dt, x = (jnp.pad(v, ((0, 0), (0, pad), (0, 0))) for v in (dt, x))
        Bmat, Cmat = (jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
                      for v in (Bmat, Cmat))
    Tp = dt.shape[1]
    nc = Tp // chunk

    def per_chunk(state, xs):
        dt_c, B_c, C_c, x_c = xs                      # (B, c, ...)

        @jax.checkpoint
        def inner(state, dt_c, B_c, C_c, x_c):
            def step(s, t):
                dt_t, B_t, C_t, x_t = t               # (B,d_in),(B,N),(B,N),(B,d_in)
                da = jnp.exp(dt_t[..., None] * a)     # (B, d_in, N)
                s = s * da + (dt_t * x_t)[..., None] * B_t[:, None, :]
                y = jnp.einsum("bdn,bn->bd", s, C_t)
                return s, y

            ts = (dt_c.swapaxes(0, 1), B_c.swapaxes(0, 1),
                  C_c.swapaxes(0, 1), x_c.swapaxes(0, 1))
            s, ys = jax.lax.scan(step, state, ts)
            return s, ys.swapaxes(0, 1)               # (B, c, d_in)

        state, y_c = inner(state, dt_c, B_c, C_c, x_c)
        return state, y_c

    xs = tuple(v.reshape(Bsz, nc, chunk, -1).swapaxes(0, 1)
               for v in (dt, Bmat, Cmat, x))
    s0 = jnp.zeros((Bsz, d_in, N), jnp.float32)
    _, ys = jax.lax.scan(per_chunk, s0, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, Tp, d_in)
    return y[:, :T]


def mamba_forward(mcfg: MambaConfig, params, x, chunk: int = _CHUNK):
    """x: (B, T, D) -> (B, T, D)."""
    B, T, D = x.shape
    d_in = mcfg.expand * D
    h = jnp.einsum("btd,de->bte", x, params["in_proj"])
    h = sh.shard(h, "batch", "seq", "ffn")
    xz, z = h[..., :d_in], h[..., d_in:]
    xz = _causal_conv(xz, params["conv_w"], params["conv_b"])
    xz = jax.nn.silu(xz)

    xf = xz.astype(jnp.float32)
    dt_low = jnp.einsum("bte,er->btr", xz, params["x_dt"])
    dt = jnp.einsum("btr,re->bte", dt_low, params["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    Bmat = jnp.einsum("bte,en->btn", xz, params["x_b"]).astype(jnp.float32)
    Cmat = jnp.einsum("bte,en->btn", xz, params["x_c"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"])

    y = _ssm_chunk_scan(dt, Bmat, Cmat, xf, a, chunk)
    y = y + xf * params["d"]
    y = y.astype(x.dtype)
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    return sh.shard(out, "batch", "seq", "dmodel")


# --------------------------------------------------------------------------
# Decode: O(1) per step.  Cache = {"conv": (B, K-1, d_in), "ssm": (B, d_in, N)}
# --------------------------------------------------------------------------
def mamba_decode_init(mcfg: MambaConfig, d_model: int, batch: int, dtype):
    d_in = mcfg.expand * d_model
    return {
        "conv": jnp.zeros((batch, mcfg.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, mcfg.d_state), jnp.float32),
    }


def mamba_decode_step(mcfg: MambaConfig, params, x, cache):
    """x: (B, 1, D); returns (y (B, 1, D), cache')."""
    B, _, D = x.shape
    d_in = mcfg.expand * D
    h = jnp.einsum("btd,de->bte", x, params["in_proj"])
    xz, z = h[..., :d_in], h[..., d_in:]

    window = jnp.concatenate([cache["conv"], xz], axis=1)     # (B, K, d_in)
    conv = jnp.einsum("bke,ke->be", window, params["conv_w"]) \
        + params["conv_b"]
    xc = jax.nn.silu(conv)[:, None, :]                        # (B, 1, d_in)
    new_conv = window[:, 1:]

    dt_low = jnp.einsum("bte,er->btr", xc, params["x_dt"])
    dt = jnp.einsum("btr,re->bte", dt_low, params["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])[:, 0]        # (B, d_in)
    Bm = jnp.einsum("bte,en->bn", xc, params["x_b"]).astype(jnp.float32)
    Cm = jnp.einsum("bte,en->bn", xc, params["x_c"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"])

    s = cache["ssm"]
    da = jnp.exp(dt[..., None] * a)
    xf = xc[:, 0].astype(jnp.float32)
    s = s * da + (dt * xf)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", s, Cm) + xf * params["d"]
    y = y.astype(x.dtype)[:, None, :]
    y = rmsnorm(y, params["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    return out, {"conv": new_conv, "ssm": s}

"""Decoder-stack assembly: init / forward / prefill / decode for every
decoder-only architecture (dense, MoE, hybrid, VLM, SSM).

The stack is organized as ``num_periods`` repetitions of a static
``pattern`` of layers (homogeneous models: pattern length 1).  Parameters
for pattern position ``j`` are stacked over periods and the whole stack runs
under one ``lax.scan`` with an optional rematerialized body — HLO size and
compile time are depth-independent (a 94-layer MoE compiles like a 1-layer
one).

Per-layer attention schedules (sliding-window size, rope theta) are *data*:
they ride through the scan as xs, which is what lets gemma3's 5-local:1-global
pattern share the homogeneous scan.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.sharding import specs as sh

from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import rwkv6 as rwkv
from .layers import (chunked_xent, dtype_of, embed, init_embed, init_mlp,
                     mlp, rmsnorm, unembed_logits, zeros)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, spec: LayerSpec, key):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {"norm1": zeros((cfg.d_model,), dtype),
         "norm2": zeros((cfg.d_model,), dtype)}
    if spec.mixer == "attention":
        p["attn"] = attn.init_attention(ks[0], cfg.attention, cfg.d_model,
                                        dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = mam.init_mamba(ks[0], cfg.mamba, cfg.d_model, dtype)
    elif spec.mixer == "rwkv6":
        p["rwkv"] = rwkv.init_rwkv6(ks[0], cfg.rwkv6, cfg.d_model, dtype)
    if spec.ffn == "dense":
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg.moe, cfg.d_model, dtype)
    elif spec.ffn == "rwkv_ffn":
        p["rwkvffn"] = rwkv.init_rwkv_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_stack, k_out = jax.random.split(key, 3)
    params = {"embed": init_embed(k_embed, cfg.vocab_size, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
              "final_norm": zeros((cfg.d_model,), dtype)}
    stack = []
    P = cfg.layers_per_period
    for j, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_stack, j),
                                cfg.num_periods)
        stacked = jax.vmap(lambda k: _init_layer(cfg, spec, k))(keys)
        stack.append(stacked)
    params["stack"] = tuple(stack)
    return params


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(shapes))


# --------------------------------------------------------------------------
# Per-layer schedules (window, rope theta) as scan data
# --------------------------------------------------------------------------
def layer_schedules(cfg: ModelConfig):
    L, P = cfg.num_layers, cfg.layers_per_period
    win, theta = [], []
    for l in range(L):
        spec = cfg.pattern[l % P]
        if spec.mixer == "attention" and cfg.attention is not None:
            if cfg.window_pattern is not None:
                w = cfg.window_pattern[l % len(cfg.window_pattern)]
            else:
                w = cfg.attention.window
            if cfg.rope_theta_pattern is not None:
                th = cfg.rope_theta_pattern[l % len(cfg.rope_theta_pattern)]
            else:
                th = cfg.attention.rope_theta
        else:
            w, th = 0, 1.0
        win.append(w)
        theta.append(th)
    win = jnp.asarray(win, jnp.int32).reshape(cfg.num_periods, P)
    theta = jnp.asarray(theta, jnp.float32).reshape(cfg.num_periods, P)
    return win, theta


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------
def _apply_layer(cfg, spec, p, h, positions, window, theta, mode,
                 collect_cache):
    """One layer; returns (h, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if spec.mixer == "attention":
        # homogeneous window schedules expose a static value so the Pallas
        # flash kernel (mask-specialized) can serve as the production path
        static_win = (cfg.attention.window if cfg.window_pattern is None
                      else None)
        y, (k, v) = attn.self_attention(cfg.attention, p["attn"],
                                        rmsnorm(h, p["norm1"], cfg.norm_eps),
                                        positions, window, theta,
                                        cfg.norm_eps,
                                        static_window=static_win)
        if collect_cache:
            cache = {"k": k, "v": v}
        h = h + y
    elif spec.mixer == "mamba":
        x_in = rmsnorm(h, p["norm1"], cfg.norm_eps)
        if collect_cache:
            y, st = _mamba_with_state(cfg, p["mamba"], x_in)
            cache = st
        else:
            y = mam.mamba_forward(cfg.mamba, p["mamba"], x_in)
        h = h + y
    elif spec.mixer == "rwkv6":
        x_in = rmsnorm(h, p["norm1"], cfg.norm_eps)
        if collect_cache:
            y, (shift, S) = rwkv.rwkv6_forward(cfg.rwkv6, p["rwkv"], x_in,
                                               return_state=True)
            cache = {"att_shift": shift, "wkv": S}
        else:
            y = rwkv.rwkv6_forward(cfg.rwkv6, p["rwkv"], x_in)
        h = h + y

    hn = rmsnorm(h, p["norm2"], cfg.norm_eps)
    if spec.ffn == "dense":
        h = h + mlp(p["mlp"], hn, cfg.act)
    elif spec.ffn == "moe":
        y, aux = moe_mod.moe_forward(cfg.moe, p["moe"], hn, cfg.act,
                                     mode=mode, with_aux=(mode == "train"))
        h = h + y
    elif spec.ffn == "rwkv_ffn":
        if collect_cache:
            y, shift = rwkv.rwkv_ffn_forward(p["rwkvffn"], hn,
                                             return_state=True)
            cache = dict(cache or {}, ffn_shift=shift)
        else:
            y = rwkv.rwkv_ffn_forward(p["rwkvffn"], hn)
        h = h + y
    return h, aux, cache


def _mamba_with_state(cfg, p, x):
    """Run the mamba layer AND return its final (conv, ssm) state for
    prefill→decode handoff: recompute the state from the last d_conv inputs
    and a full scan (prefill is not latency-critical for state extraction)."""
    y = mam.mamba_forward(cfg.mamba, p, x)
    # final conv window: last (d_conv - 1) post-in_proj activations
    d_in = cfg.mamba.expand * cfg.d_model
    h = jnp.einsum("btd,de->bte", x, p["in_proj"])[..., :d_in]
    K = cfg.mamba.d_conv
    conv_state = h[:, -(K - 1):, :]
    ssm = _mamba_final_state(cfg, p, x)
    return y, {"conv": conv_state, "ssm": ssm}


def _mamba_final_state(cfg, p, x):
    """Final SSM state after consuming x (scan carrying only the state)."""
    mcfg = cfg.mamba
    d_in = mcfg.expand * cfg.d_model
    h = jnp.einsum("btd,de->bte", x, p["in_proj"])[..., :d_in]
    hc = mam._causal_conv(h, p["conv_w"], p["conv_b"])
    hc = jax.nn.silu(hc)
    dt_low = jnp.einsum("bte,er->btr", hc, p["x_dt"])
    dt = jnp.einsum("btr,re->bte", dt_low, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    Bm = jnp.einsum("bte,en->btn", hc, p["x_b"]).astype(jnp.float32)
    Cm = jnp.einsum("bte,en->btn", hc, p["x_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    xf = hc.astype(jnp.float32)

    def step(s, t):
        dt_t, B_t, x_t = t
        da = jnp.exp(dt_t[..., None] * a)
        s = s * da + (dt_t * x_t)[..., None] * B_t[:, None, :]
        return s, None

    B = x.shape[0]
    s0 = jnp.zeros((B, d_in, mcfg.d_state), jnp.float32)
    ts = (dt.swapaxes(0, 1), Bm.swapaxes(0, 1), xf.swapaxes(0, 1))
    s, _ = jax.lax.scan(step, s0, ts)
    return s


def forward_hidden(cfg: ModelConfig, params, x, positions, mode: str = "train",
                   collect_cache: bool = False):
    """x: (B, S, D) embeddings -> (h, aux_total, cache|None)."""
    win, theta = layer_schedules(cfg)

    def period_fn(carry, xs):
        h, aux = carry
        # the carry crosses the remat boundary sequence-sharded (seqcarry
        # rule); gather it for the layer body, re-shard before returning.
        h = sh.shard(h, "batch", "seq", "dmodel")
        stack_j, win_j, theta_j = xs
        caches = []
        for j, spec in enumerate(cfg.pattern):
            h, a, c = _apply_layer(cfg, spec, stack_j[j], h, positions,
                                   win_j[j], theta_j[j], mode, collect_cache)
            aux = aux + a
            caches.append(c)
        h = sh.shard(h, "batch", "seqcarry", "dmodel")
        return (h, aux), tuple(caches) if collect_cache else None

    body = period_fn
    if cfg.remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(period_fn, policy=policy,
                              prevent_cse=False)

    (h, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (params["stack"], win, theta))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, caches


def embed_inputs(cfg: ModelConfig, params, batch):
    if cfg.input_kind == "frames":
        x = batch["frames"].astype(dtype_of(cfg.dtype))
    else:
        x = embed(params["embed"], batch["tokens"], cfg.embed_scale,
                  cfg.d_model)
    return x


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token CE (+ MoE aux).  batch: tokens (B,S), labels (B,S),
    optional mask (B,S)."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    h, aux, _ = forward_hidden(cfg, params, x, positions, mode="train")
    loss = chunked_xent(cfg, params["embed"], h, batch["labels"],
                        batch.get("mask"))
    # aux comes back summed over layers; report/penalize the per-MoE-layer mean
    n_moe = sum(1 for i in range(cfg.num_layers)
                if cfg.pattern[i % cfg.layers_per_period].ffn == "moe")
    aux = aux / max(1, n_moe)
    coef = cfg.moe.router_aux_coef if cfg.moe else 0.0
    total = loss + coef * aux
    return total, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params, tokens):
    """tokens (B, S) -> (last-token logits (B, V), cache at length S)."""
    x = embed(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    h, _, caches = forward_hidden(cfg, params, x, positions, mode="prefill",
                                  collect_cache=True)
    logits = unembed_logits(params["embed"], h[:, -1], cfg.tie_embeddings)
    cache = {"stack": caches,
             "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Empty decode cache sized for ``max_seq`` total positions."""
    dtype = dtype_of(cfg.dtype)
    entries = []
    for spec in cfg.pattern:
        n = cfg.num_periods
        if spec.mixer == "attention":
            a = cfg.attention
            shape = (n, batch, max_seq, a.num_kv_heads, a.head_dim)
            e = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        elif spec.mixer == "mamba":
            st = mam.mamba_decode_init(cfg.mamba, cfg.d_model, batch, dtype)
            e = jax.tree.map(lambda v: jnp.broadcast_to(v, (n,) + v.shape), st)
        elif spec.mixer == "rwkv6":
            st = rwkv.rwkv6_decode_init(cfg.rwkv6, cfg.d_model, batch, dtype)
            e = {"att_shift": jnp.broadcast_to(st["att_shift"],
                                               (n,) + st["att_shift"].shape),
                 "wkv": jnp.broadcast_to(st["wkv"], (n,) + st["wkv"].shape)}
            if spec.ffn == "rwkv_ffn":
                e["ffn_shift"] = jnp.broadcast_to(
                    st["ffn_shift"], (n,) + st["ffn_shift"].shape)
        else:
            e = {}
        entries.append(e)
    return {"stack": tuple(entries),
            "len": jnp.zeros((batch,), jnp.int32)}


def _decode_layer(cfg, spec, p, c, h, new_len, window, theta):
    """One layer, one token.  h: (B, 1, D).  Returns (h, cache')."""
    B = h.shape[0]
    if spec.mixer == "attention":
        hn = rmsnorm(h, p["norm1"], cfg.norm_eps)
        k, v = attn.decode_project_kv(cfg.attention, p["attn"], hn, new_len,
                                      theta, cfg.norm_eps)
        # cache insert happens inside the CP kernel (local scatter on the
        # owning shard; masked-write fallback without a mesh) — a global
        # per-row scatter forces GSPMD to replicate the cache (§Perf C).
        y, ck, cv = attn.decode_attention_cp(
            cfg.attention, p["attn"], hn, c["k"], c["v"], k, v, new_len,
            window, theta, cfg.norm_eps)
        h = h + y
        c = {"k": ck, "v": cv}
    elif spec.mixer == "mamba":
        hn = rmsnorm(h, p["norm1"], cfg.norm_eps)
        y, c = mam.mamba_decode_step(cfg.mamba, p["mamba"], hn, c)
        h = h + y
    elif spec.mixer == "rwkv6":
        hn = rmsnorm(h, p["norm1"], cfg.norm_eps)
        y, (shift, S) = rwkv.rwkv6_forward(
            cfg.rwkv6, p["rwkv"], hn, shift_state=c["att_shift"],
            wkv_state=c["wkv"], return_state=True)
        h = h + y
        c = dict(c, att_shift=shift, wkv=S)

    hn = rmsnorm(h, p["norm2"], cfg.norm_eps)
    if spec.ffn == "dense":
        h = h + mlp(p["mlp"], hn, cfg.act)
    elif spec.ffn == "moe":
        y, _ = moe_mod.moe_forward(cfg.moe, p["moe"], hn, cfg.act,
                                   mode="decode", with_aux=False)
        h = h + y
    elif spec.ffn == "rwkv_ffn":
        y, shift = rwkv.rwkv_ffn_forward(p["rwkvffn"], hn, return_state=True)
        h = h + y
        c = dict(c, ffn_shift=shift)
    return h, c


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens (B, 1) -> (logits (B, V), cache')."""
    x = embed(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    new_len = cache["len"] + 1                               # (B,)
    win, theta = layer_schedules(cfg)
    theta = theta  # (periods, P)

    def body(h, xs):
        stack_j, cache_j, win_j, theta_j = xs
        new_caches = []
        for j, spec in enumerate(cfg.pattern):
            h, cj = _decode_layer(cfg, spec, stack_j[j], cache_j[j], h,
                                  new_len, win_j[j], theta_j[j])
            new_caches.append(cj)
        return h, tuple(new_caches)

    h, new_stack = jax.lax.scan(
        body, x, (params["stack"], cache["stack"], win, theta))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed_logits(params["embed"], h[:, 0], cfg.tie_embeddings)
    return logits, {"stack": new_stack, "len": new_len}

"""Shared building blocks: inits, norms, rotary embeddings, losses.

Everything is a pure function over explicit pytrees (nested dicts of
jnp arrays); no framework dependency.  Activation sharding annotations go
through :mod:`repro.sharding.specs` and are identities when no mesh is
installed (CPU smoke tests).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding import specs as sh


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------
def normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def fan_in_init(key, shape, dtype, fan_axis: int = -2):
    """Truncated-normal-ish scaled init: std = 1/sqrt(fan_in)."""
    fan_in = shape[fan_axis] if len(shape) > 1 else shape[0]
    return normal(key, shape, 1.0 / math.sqrt(max(1, fan_in)), dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-6):
    """RMSNorm in f32 accumulation (returned in x.dtype)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
        "relu": jax.nn.relu,
    }[name]


# --------------------------------------------------------------------------
# Rotary position embeddings.  theta may be a traced scalar (per-layer rope
# schedules ride through scan xs), so inv_freq is computed inline.
# --------------------------------------------------------------------------
def apply_rope(x, positions, theta, head_dim: int | None = None):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = head_dim or x.shape[-1]
    half = hd // 2
    theta = jnp.asarray(theta, jnp.float32)
    exponent = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = theta ** (-exponent)                           # (half,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    cos = jnp.cos(angles)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def init_embed(key, vocab, d_model, dtype, tie: bool):
    # std = 1/sqrt(d): tied-embedding logits come out O(1) per component, and
    # embed_scale (gemma) multiplies by sqrt(d) to restore O(1) activations.
    k1, k2 = jax.random.split(key)
    p = {"tok": normal(k1, (vocab, d_model), 1.0 / math.sqrt(d_model), dtype)}
    if not tie:
        p["head"] = fan_in_init(k2, (d_model, vocab), dtype)
    return p


def embed(params, tokens, scale: bool, d_model: int):
    x = jnp.take(params["tok"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(d_model), x.dtype)
    return sh.shard(x, "batch", "seq", "dmodel")


def unembed_logits(params, x, tie: bool):
    w = params["tok"].T if tie else params["head"]
    logits = jnp.einsum("...d,dv->...v", x, w)
    if logits.ndim == 3:
        logits = sh.shard(logits, "batch", "seq", "vocab")
    return logits


# --------------------------------------------------------------------------
# Chunked softmax cross-entropy.  The full (B, S, V) logits tensor for e.g.
# gemma3 (V=262k) would be tens of GB per device; scanning over sequence
# chunks keeps the transient at (B, chunk, V/shard).
# --------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """Stable CE in f32; logits (..., V), labels (...) int32."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_xent(cfg, embed_params, x, labels, mask=None):
    """Scan over sequence chunks: embed->logits->CE without materializing
    (B, S, V).  x: (B, S, D) final hidden states; labels: (B, S)."""
    B, S, D = x.shape
    chunk = cfg.logit_chunk
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        logits = unembed_logits(embed_params, x, cfg.tie_embeddings)
        return softmax_xent(logits, labels, mask)

    n = S // chunk
    xs = (
        x.reshape(B, n, chunk, D).swapaxes(0, 1),         # (n, B, c, D)
        labels.reshape(B, n, chunk).swapaxes(0, 1),
        (mask.reshape(B, n, chunk).swapaxes(0, 1)
         if mask is not None else jnp.ones((n, B, chunk), jnp.float32)),
    )

    def body(carry, xm):
        tot, cnt = carry
        xc, yc, mc = xm
        logits = unembed_logits(embed_params, xc, cfg.tie_embeddings)
        lf = logits.astype(jnp.float32)
        m = jnp.max(lf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        gold = jnp.take_along_axis(lf, yc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    # remat: never keep a chunk's logits for the backward pass
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), xs)
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------------------
# Dense (SwiGLU / GeGLU) FFN
# --------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, dtype, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"w_gate": fan_in_init(ks[0], (d_model, d_ff), dtype),
         "w_in": fan_in_init(ks[1], (d_model, d_ff), dtype),
         "w_out": fan_in_init(ks[2], (d_ff, d_model), dtype)}
    if bias:
        p["b_in"] = zeros((d_ff,), dtype)
        p["b_out"] = zeros((d_model,), dtype)
    return p


def mlp(params, x, act: str):
    h = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_in"])
    if x.ndim == 3:
        h = sh.shard(h, "batch", "seq", "ffn")
        u = sh.shard(u, "batch", "seq", "ffn")
    y = act_fn(act)(h) * u
    out = jnp.einsum("...f,fd->...d", y, params["w_out"])
    if x.ndim == 3:
        out = sh.shard(out, "batch", "seq", "dmodel")
    return out


# --------------------------------------------------------------------------
# Whisper-style GELU MLP (no gate) — used by the encoder/decoder stacks that
# predate gated FFNs.
# --------------------------------------------------------------------------
def init_mlp_nogate(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 2)
    return {"w_in": fan_in_init(ks[0], (d_model, d_ff), dtype),
            "b_in": zeros((d_ff,), dtype),
            "w_out": fan_in_init(ks[1], (d_ff, d_model), dtype),
            "b_out": zeros((d_model,), dtype)}


def mlp_nogate(params, x, act: str = "gelu"):
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    if x.ndim == 3:
        h = sh.shard(h, "batch", "seq", "ffn")
    y = act_fn(act)(h)
    out = jnp.einsum("...f,fd->...d", y, params["w_out"]) + params["b_out"]
    if x.ndim == 3:
        out = sh.shard(out, "batch", "seq", "dmodel")
    return out

"""Checkpointing: atomic, async, resumable — the fault-tolerance anchor.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json         # pytree structure, shapes, dtypes, metadata
        leaf_000000.npy ...   # one file per leaf (host-local full arrays)
    <root>/LATEST             # text file holding the last committed step

Writes are crash-safe: leaves are written into ``step_X.tmp`` and the
directory is ``os.rename``d only after everything (incl. manifest) is
fsynced — a process killed mid-save leaves the previous checkpoint intact.
Saving runs on a background thread so the train loop never blocks on disk;
the writer's critical sections (claiming a pending save, committing LATEST)
are guarded by a :class:`~repro.core.mutlock.MutableLock` — commit is
µs-scale (spin-friendly) while serialization is ms-scale I/O (sleep-
friendly): the mixed regime the paper's lock self-tunes for.

Restore reassembles the pytree and ``device_put``s every leaf under the
sharding of a matching *template* state — which is how **elastic restart**
works: the same checkpoint restores onto a different mesh (fewer/more pods)
by passing the new template (see runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro.core import MutableLock, MutableWait


# --------------------------------------------------------------------------
# Pytree <-> flat leaves with stable paths
# --------------------------------------------------------------------------
def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                  # bfloat16 / fp8 extension dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _is_native(dt: np.dtype) -> bool:
    try:
        return np.dtype(str(dt)) == dt and dt.kind != "V"
    except TypeError:
        return False


def save_pytree(tree, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:06d}.npy"
        if _is_native(arr.dtype):
            np.save(os.path.join(out_dir, fname), arr)
            raw = False
        else:                              # bfloat16 etc: store raw bytes
            np.save(os.path.join(out_dir, fname),
                    np.frombuffer(arr.tobytes(), np.uint8))
            raw = True
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "raw": raw})
    tmp = os.path.join(out_dir, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, os.path.join(out_dir, "manifest.json"))


def load_pytree(in_dir: str, template):
    """Restore into the structure+shardings of ``template`` (a pytree of
    arrays or ShapeDtypeStructs with .sharding)."""
    with open(os.path.join(in_dir, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for p, tleaf in zip(paths, leaves):
        e = by_path.get(p)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(in_dir, e["file"]))
        if e.get("raw"):
            arr = np.frombuffer(arr.tobytes(),
                                _resolve_dtype(e["dtype"])).reshape(
                tuple(e["shape"]))
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise ValueError(f"shape mismatch for {p}: ckpt {arr.shape} "
                             f"vs template {tleaf.shape}")
        sharding = getattr(tleaf, "sharding", None)
        dtype = tleaf.dtype
        if arr.dtype != dtype:        # numpy can't cast to ml_dtypes directly
            arr = np.asarray(jax.numpy.asarray(arr).astype(dtype))
        if sharding is not None:
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Manager
# --------------------------------------------------------------------------
class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3,
                 async_save: bool = True):
        self.root = root
        self.keep_last = keep_last
        self.async_save = async_save
        os.makedirs(root, exist_ok=True)
        self.lock = MutableLock(max_sws=2)
        self._pending: tuple[int, object] | None = None
        self._inflight = False
        self._stop = threading.Event()
        self._saved_evt = threading.Event()
        self.save_count = 0
        self.last_save_s = 0.0
        if async_save:
            self._thread = threading.Thread(target=self._writer, daemon=True)
            self._thread.start()

    # -- public API -----------------------------------------------------------
    def save(self, step: int, state) -> None:
        """Snapshot state (device -> host copy happens here, synchronously,
        so the caller may donate/overwrite device buffers afterwards)."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if not self.async_save:
            self._write(step, host_state)
            return
        with self.lock:
            self._pending = (step, host_state)   # newest-wins coalescing
        self._saved_evt.clear()

    def wait(self, timeout_s: float = 60.0) -> bool:
        """Block until the queued save (if any) is committed."""
        if not self.async_save:
            return True
        w = MutableWait(max_spin_s=1e-3, sleep_s=5e-3)
        return w.wait(lambda: self._pending is None and not self._inflight,
                      timeout_s=timeout_s)

    def latest_step(self) -> int | None:
        path = os.path.join(self.root, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, template, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.root, f"step_{step:08d}")
        return step, load_pytree(d, template)

    def close(self) -> None:
        self._stop.set()
        if self.async_save:
            self._thread.join(timeout=10.0)

    # -- writer side ----------------------------------------------------------
    def _writer(self) -> None:
        while not self._stop.is_set():
            with self.lock:
                job, self._pending = self._pending, None
                if job is not None:
                    self._inflight = True
            if job is None:
                time.sleep(2e-3)
                continue
            try:
                self._write(*job)
            finally:
                self._inflight = False

    def _write(self, step: int, host_state) -> None:
        t0 = time.monotonic()
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(host_state, tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with self.lock:                       # commit LATEST atomically
            lp = os.path.join(self.root, "LATEST.tmp")
            with open(lp, "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            os.rename(lp, os.path.join(self.root, "LATEST"))
            self.save_count += 1
            self.last_save_s = time.monotonic() - t0
        self._gc()
        self._saved_evt.set()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

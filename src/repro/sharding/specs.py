"""Logical-axis sharding rules (GSPMD) for the whole framework.

Model code annotates activations/params with *logical* axis names; this
module resolves them to physical mesh axes.  Keeping the mapping in one
place lets the perf loop re-shard the entire model by editing a rule table
instead of touching model code (DESIGN.md §4).

Logical axes:

    batch    — global batch                (data parallel)
    seq      — sequence (activations)      (sequence parallel, long-context)
    kvseq    — KV-cache sequence           (decode-time SP)
    heads    — attention heads             (tensor parallel)
    kvheads  — KV heads                    (TP when divisible, else replicated)
    dmodel   — residual/model dim          (usually unsharded for activations)
    ffn      — MLP hidden dim              (tensor parallel)
    vocab    — embedding/logits vocab dim  (tensor parallel)
    expert   — MoE experts                 (expert parallel)
    fsdp     — parameter FSDP shards       (maps onto the data axis)

A rule value may be a mesh-axis name, a tuple of names, or None.
"""

from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshRules:
    """Resolution table: logical axis -> physical mesh axis (or axes)."""

    batch: tuple | str | None = ("pod", "data")
    seq: tuple | str | None = None
    # the scan-carry residual stream (what remat stores between layers);
    # sharding it over "model" is Megatron-SP-style sequence parallelism
    seqcarry: tuple | str | None = None
    kvseq: tuple | str | None = "model"
    heads: tuple | str | None = "model"
    kvheads: tuple | str | None = "model"
    dmodel: tuple | str | None = None
    ffn: tuple | str | None = "model"
    vocab: tuple | str | None = "model"
    expert: tuple | str | None = "model"
    fsdp: tuple | str | None = None          # set to ("pod","data") for FSDP

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        return getattr(self, logical)

    def with_overrides(self, **kw) -> "MeshRules":
        return replace(self, **kw)

    def strip(self, axis: str) -> "MeshRules":
        """Remove one physical axis from every rule (e.g. 'pod' when it is
        manualized by an enclosing shard_map)."""
        kw = {}
        for fld in self.__dataclass_fields__:
            axes = getattr(self, fld)
            if axes is None:
                continue
            if isinstance(axes, str):
                kw[fld] = None if axes == axis else axes
            else:
                kept = tuple(a for a in axes if a != axis)
                kw[fld] = (kept if len(kept) > 1
                           else (kept[0] if kept else None))
        return replace(self, **kw)

    def restrict(self, mesh: "Mesh") -> "MeshRules":
        """Drop references to axes the mesh does not have (e.g. 'pod' on a
        single-pod mesh)."""
        kw = {}
        for fld in self.__dataclass_fields__:
            axes = getattr(self, fld)
            if axes is None:
                continue
            if isinstance(axes, str):
                kw[fld] = axes if axes in mesh.axis_names else None
            else:
                kept = tuple(a for a in axes if a in mesh.axis_names)
                kw[fld] = (kept if len(kept) > 1
                           else (kept[0] if kept else None))
        return replace(self, **kw)


# --------------------------------------------------------------------------
# Thread-local sharding context.  When no mesh is installed (CPU smoke tests)
# every annotation is the identity, so model code runs unmodified.
# --------------------------------------------------------------------------
class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: MeshRules | None = None


_CTX = _Ctx()


@contextmanager
def use_mesh(mesh: Mesh, rules: MeshRules):
    """Install (mesh, rules); valid mesh-axis names are checked eagerly."""
    for fld in rules.__dataclass_fields__:
        axes = rules.resolve(fld)
        if axes is None:
            continue
        for ax in (axes,) if isinstance(axes, str) else axes:
            if ax not in mesh.axis_names:
                raise ValueError(
                    f"rule {fld}={axes!r} references unknown mesh axis {ax!r}"
                    f" (mesh has {mesh.axis_names})")
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> MeshRules:
    return _CTX.rules if _CTX.rules is not None else MeshRules()


def active() -> bool:
    return _CTX.mesh is not None


def _dim_ok(dim_size: int, axes, mesh: Mesh) -> bool:
    """Only shard a dimension the mesh divides evenly (e.g. 8 kv-heads on a
    16-way model axis -> replicate instead)."""
    if axes is None:
        return False
    n = 1
    for ax in (axes,) if isinstance(axes, str) else axes:
        n *= mesh.shape[ax]
    return dim_size % n == 0 and dim_size >= n


def logical_to_spec(shape: tuple[int, ...], logical: tuple[str | None, ...],
                    mesh: Mesh, rules: MeshRules) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible dims
    and axes already consumed by an earlier dimension."""
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    out = []
    for size, name in zip(shape, logical):
        axes = rules.resolve(name)
        if axes is not None and not isinstance(axes, str):
            axes = tuple(a for a in axes if a in mesh.axis_names)
            axes = axes or None
        if isinstance(axes, str) and axes not in mesh.axis_names:
            axes = None
        # an axis may appear in only one dim of a spec
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in flat) or not _dim_ok(size, flat, mesh):
                axes = None
            else:
                used.update(flat)
                axes = flat[0] if len(flat) == 1 else tuple(flat)
        out.append(axes)
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate an activation with logical axes (identity without a mesh)."""
    if not active() or not hasattr(x, "ndim"):
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    spec = logical_to_spec(x.shape, logical, _CTX.mesh, _CTX.rules)
    if all(a is None for a in spec):
        # no axis resolved: leave the tensor unconstrained (a P(None,...)
        # constraint would FORCE replication)
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def named_sharding(*axes) -> NamedSharding:
    assert active()
    return NamedSharding(_CTX.mesh, P(*axes))


# --------------------------------------------------------------------------
# Parameter sharding: path-pattern -> logical axes, resolved against shapes.
# Patterns are regexes over the '/'-joined pytree path.  First match wins.
# --------------------------------------------------------------------------
#: (regex, logical axes per dim — trailing dims matched right-aligned)
PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / lm head: shard the vocab dim
    (r"embed/tok$",            ("vocab", "fsdp")),
    (r"lm_head$",              ("fsdp", "vocab")),
    (r"pos_embed$",            (None, None)),
    # attention projections (stacked layers get an extra leading dim)
    (r"(attn|self_attn|cross_attn)/wq$",   ("fsdp", "heads", None)),
    (r"(attn|self_attn|cross_attn)/wk$",   ("fsdp", "kvheads", None)),
    (r"(attn|self_attn|cross_attn)/wv$",   ("fsdp", "kvheads", None)),
    (r"(attn|self_attn|cross_attn)/wo$",   ("heads", None, "fsdp")),
    (r"(attn|self_attn|cross_attn)/(bq)$", ("heads", None)),
    (r"(attn|self_attn|cross_attn)/(bk|bv)$", ("kvheads", None)),
    (r"(attn|self_attn|cross_attn)/(bo)$", (None,)),
    # dense mlp
    (r"mlp/w_(in|gate)$",      ("fsdp", "ffn")),
    (r"mlp/w_out$",            ("ffn", "fsdp")),
    (r"mlp/b_(in|gate)$",      ("ffn",)),
    (r"mlp/b_out$",            (None,)),
    # MoE: experts on the leading dim
    (r"moe/router$",           ("fsdp", None)),
    (r"moe/w_(in|gate)$",      ("expert", "fsdp", "ffn")),
    (r"moe/w_out$",            ("expert", "ffn", "fsdp")),
    # mamba
    (r"mamba/in_proj$",        ("fsdp", "ffn")),
    (r"mamba/conv_w$",         (None, "ffn")),
    (r"mamba/conv_b$",         ("ffn",)),
    (r"mamba/(x_dt|x_b|x_c)$", ("ffn", None)),
    (r"mamba/dt_proj$",        (None, "ffn")),
    (r"mamba/dt_bias$",        ("ffn",)),
    (r"mamba/a_log$",          ("ffn", None)),
    (r"mamba/d$",              ("ffn",)),
    (r"mamba/out_proj$",       ("ffn", "fsdp")),
    (r"mamba/norm$",           ("ffn",)),
    # rwkv6
    (r"rwkv/(w_r|w_k|w_v|w_g)$",  ("fsdp", "ffn")),
    (r"rwkv/w_o$",             ("ffn", "fsdp")),
    (r"rwkv/(mu_.*|w0|ddlerp_.*)$", None),      # small mixing vectors
    (r"rwkv/(lora_.*)$",       None),
    (r"rwkv/ln_(w|b)$",        (None,)),
    (r"rwkvffn/w_k$",          ("fsdp", "ffn")),
    (r"rwkvffn/w_v$",          ("ffn", "fsdp")),
    (r"rwkvffn/w_r$",          ("fsdp", None)),
    (r"rwkvffn/mu_.*$",        None),
    # norms & scalars: replicate
    (r".*(norm|ln)[^/]*$",     None),
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_logical_axes(path_str: str, ndim: int) -> tuple:
    """Match PARAM_RULES; right-align the logical axes to the array rank
    (stacked-layer params carry extra leading dims which stay unsharded,
    except FSDP which may claim the stack dim via rule override)."""
    for pat, logical in PARAM_RULES:
        if re.search(pat, path_str):
            if logical is None:
                return (None,) * ndim
            logical = tuple(logical)
            if len(logical) > ndim:      # un-stacked variant (e.g. biases)
                logical = logical[-ndim:]
            pad = (None,) * (ndim - len(logical))
            return pad + logical
    return (None,) * ndim


def param_specs(params_shape, mesh: Mesh, rules: MeshRules):
    """PartitionSpec pytree for a parameter pytree of ShapeDtypeStructs."""

    def one(path, leaf):
        logical = param_logical_axes(_path_str(path), len(leaf.shape))
        return logical_to_spec(leaf.shape, logical, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh, rules: MeshRules):
    specs = param_specs(params_shape, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# --------------------------------------------------------------------------
# Decode-cache sharding: KV caches sequence-sharded (flash-decode), SSM /
# linear-attention states sharded over their channel dims.
# --------------------------------------------------------------------------
CACHE_RULES: list[tuple[str, tuple]] = [
    # attention KV: (periods, B, S, KV, hd) — (^|/) also catches the
    # enc-dec cache whose k/v live at the pytree root (dry-run §Perf B.1:
    # the missing anchor replicated whisper's 43 GB cache per device)
    (r"(^|/)(k|v)$",        (None, "batch", "kvseq", "kvheads", None)),
    # whisper cross-attention KV: (L, B, enc_seq, KV, hd)
    (r"enc_kv",             (None, "batch", None, "kvheads", None)),
    # mamba: conv (periods, B, K-1, d_in), ssm (periods, B, d_in, N)
    (r"/conv$",             (None, "batch", None, "ffn")),
    (r"/ssm$",              (None, "batch", "ffn", None)),
    # rwkv6: wkv (periods, B, H, hd, hd); shifts (periods, B, D)
    (r"/wkv$",              (None, "batch", "heads", None, None)),
    (r"_shift$",            (None, "batch", None)),
    (r"/len$",              ("batch",)),
    (r".*",                 None),
]


def cache_logical_axes(path_str: str, ndim: int) -> tuple:
    for pat, logical in CACHE_RULES:
        if re.search(pat, path_str):
            if logical is None:
                return (None,) * ndim
            logical = tuple(logical)
            if len(logical) > ndim:
                logical = logical[-ndim:]
            return (None,) * (ndim - len(logical)) + logical
    return (None,) * ndim


def cache_specs(cache_shape, mesh: Mesh, rules: MeshRules):
    def one(path, leaf):
        logical = cache_logical_axes(_path_str(path), len(leaf.shape))
        return logical_to_spec(leaf.shape, logical, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def tree_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))

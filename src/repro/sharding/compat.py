"""Version-robust ``shard_map`` (sibling of ``repro.kernels.pallas_compat``).

Newer JAX promotes ``shard_map`` to ``jax.shard_map`` with ``check_vma``
and ``axis_names`` (the manual axes) keywords; the pinned JAX ships it as
``jax.experimental.shard_map.shard_map`` with the older ``check_rep`` /
``auto`` (the *non*-manual axes) spelling.  Model/train code writes the new
API and imports :func:`shard_map` from here; on old JAX the keywords are
translated (``axis_names`` -> ``auto`` = mesh axes minus manual ones,
``check_vma`` -> ``check_rep``).
"""

from __future__ import annotations

import jax

_new_shard_map = getattr(jax, "shard_map", None)

if _new_shard_map is not None:
    shard_map = _new_shard_map
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, check_rep=None, auto=None):
        if auto is None:
            if axis_names is not None:
                auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            else:
                auto = frozenset()
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        return _old_shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              auto=auto)

__all__ = ["shard_map"]

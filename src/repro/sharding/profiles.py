"""Per-architecture parallelism profiles — the table the perf loop edits.

Profiles resolve to :class:`~repro.sharding.specs.MeshRules`:

* **train** — DP over (pod, data); TP over model (heads/ffn/vocab/expert);
  FSDP (ZeRO-3 weights + optimizer state) over data; for deep/wide models
  the scan carry (the residual stream saved by remat between layers) is
  additionally sequence-sharded over model (``seqcarry``) — Megatron-SP
  style, 16x less activation checkpoint memory.
  For archs whose head count does not divide the model axis (gemma3: 8H,
  qwen2.5: 40H, whisper: 20H on a 16-way axis) attention is instead
  **context-parallel**: K/V sequence-sharded (``kvseq``), softmax combined
  with partial max/sum (flash-decode style) by GSPMD.
* **serve** — KV caches sequence-sharded over model (flash-decode);
  weights replicated over data for low latency, except ≥30 B-param models
  which FSDP weights over data (ZeRO-inference) to fit HBM.

``overrides`` lets the hillclimb re-shard a cell without touching code:
``--set seqcarry=model --set fsdp=pod,data``.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig

from .specs import MeshRules


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def train_rules(cfg: ModelConfig, mesh, overrides: dict | None = None
                ) -> MeshRules:
    model_sz = _axis_size(mesh, "model")
    heads_divisible = (cfg.attention is not None
                       and cfg.attention.num_heads % model_sz == 0)
    # deep/wide models: shard the remat'd scan carry over model (seq dim)
    big_carry = cfg.d_model * cfg.num_layers >= 80_000
    rules = MeshRules(
        batch=("pod", "data"),
        seq=None,
        seqcarry="model" if big_carry else None,
        kvseq=None if (heads_divisible or cfg.attention is None)
        else "model",
        heads="model",
        kvheads="model",
        dmodel=None,
        ffn="model",
        vocab="model",
        expert="model",
        fsdp=("data",),
    )
    if overrides:
        rules = rules.with_overrides(**overrides)
    return rules


def serve_rules(cfg: ModelConfig, mesh, overrides: dict | None = None
                ) -> MeshRules:
    from repro import models
    # >=2.5B: replicated weights crowd out the KV cache on 16 GB chips
    # (qwen2.5 decode_32k measured 34.9 GiB/dev with replicated weights;
    # stablelm's MHA cache needs the params sharded too).  Below that the
    # per-layer gather latency isn't worth the <2 GB saved.
    big = models.param_count(cfg) >= 2.5e9
    rules = MeshRules(
        batch=("pod", "data"),
        seq=None,
        seqcarry=None,
        kvseq="model",
        heads="model",
        kvheads="model",
        dmodel=None,
        ffn="model",
        vocab="model",
        expert="model",
        fsdp=("data",) if big else None,
    )
    if overrides:
        rules = rules.with_overrides(**overrides)
    return rules


def rules_for(cfg: ModelConfig, mesh, step: str,
              overrides: dict | None = None) -> MeshRules:
    if step == "train":
        return train_rules(cfg, mesh, overrides).restrict(mesh)
    return serve_rules(cfg, mesh, overrides).restrict(mesh)


def parse_rule_overrides(pairs: list[str]) -> dict:
    """['seqcarry=model', 'fsdp=pod,data', 'kvseq='] -> kwargs dict."""
    out: dict = {}
    for p in pairs:
        k, _, v = p.partition("=")
        if not v:
            out[k] = None
        elif "," in v:
            out[k] = tuple(x for x in v.split(",") if x)
        else:
            out[k] = v
    return out

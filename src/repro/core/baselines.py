"""Baseline lock implementations evaluated against the mutable lock.

Mirrors the paper's §4 adversaries:

* ``TASLock``            — naive test-and-set spin lock.
* ``TTASLock``           — test-and-test-and-set spin lock (PT-SPINLOCK proxy).
* ``MCSLock``            — Mellor-Crummey & Scott queue lock [11]: FIFO,
                           each waiter spins on its own node's flag.
* ``SleepLock``          — benaphore (atomic counter + semaphore): the
                           pthread-mutex *default* behaviour — one
                           test-and-set attempt, then sleep.
* ``AdaptiveMutex``      — glibc PTHREAD_MUTEX_ADAPTIVE_NP behaviour: spin
                           for a budget derived from recent history, then
                           sleep.  No sleep->spin transition (the limitation
                           the paper's §2 calls out).

All expose ``acquire()/release()``, context-manager protocol, and cheap
counters so lockbench can attribute CPU time to synchronization.
"""

from __future__ import annotations

import threading
import time

from .atomic import AtomicBool, AtomicU64


class TASLock:
    """Spin on the RMW itself (maximal cache-line bouncing)."""

    def __init__(self):
        self._cell = AtomicBool(False)
        self.spin_iters = 0

    def acquire(self) -> None:
        while self._cell.test_and_set():
            self.spin_iters += 1
            time.sleep(0)

    def release(self) -> None:
        self._cell.clear()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class TTASLock:
    """Read the cell until free, then attempt the RMW (PT-SPINLOCK proxy)."""

    def __init__(self):
        self._cell = AtomicBool(False)
        self.spin_iters = 0

    def acquire(self) -> None:
        while True:
            while self._cell.load():
                self.spin_iters += 1
                time.sleep(0)
            if not self._cell.test_and_set():
                return

    def release(self) -> None:
        self._cell.clear()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class _MCSNode:
    __slots__ = ("locked", "next")

    def __init__(self):
        self.locked = True
        self.next: "_MCSNode | None" = None


class MCSLock:
    """Queue lock: FIFO handoff, each waiter spins on its own node.

    The CAS-on-tail and next-pointer handoff follow the MCS paper; waiters
    spin on ``node.locked`` which only the predecessor writes.
    """

    def __init__(self):
        self._tail_mu = threading.Lock()  # linearizes swap/cas on the tail
        self._tail: _MCSNode | None = None
        self._local = threading.local()
        self.spin_iters = 0

    def _swap_tail(self, node: _MCSNode | None) -> "_MCSNode | None":
        with self._tail_mu:
            old = self._tail
            self._tail = node
            return old

    def _cas_tail(self, expected: _MCSNode, new: _MCSNode | None) -> bool:
        with self._tail_mu:
            if self._tail is expected:
                self._tail = new
                return True
            return False

    def acquire(self) -> None:
        node = _MCSNode()
        self._local.node = node
        pred = self._swap_tail(node)
        if pred is not None:
            pred.next = node
            while node.locked:          # spin on own cache line
                self.spin_iters += 1
                time.sleep(0)

    def release(self) -> None:
        node: _MCSNode = self._local.node
        if node.next is None:
            if self._cas_tail(node, None):
                return
            while node.next is None:    # successor announced but not linked
                time.sleep(0)
        node.next.locked = False

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class SleepLock:
    """Benaphore: futex-style sleep lock == pthread mutex default behaviour.

    acquire: FAD(count,+1); if the lock was contended, park on the semaphore.
    release: FAD(count,-1); if waiters remain, post one permit.
    Wake-ups are conserved by the semaphore, so no lost wake-ups.
    """

    def __init__(self):
        self._count = AtomicU64(0)
        self._sem = threading.Semaphore(0)
        self.sleeps = 0

    def acquire(self) -> None:
        if self._count.fetch_add(1) > 0:
            self.sleeps += 1
            self._sem.acquire()

    def release(self) -> None:
        if self._count.fetch_add(-1) > 1:
            self._sem.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class AdaptiveMutex:
    """glibc adaptive mutex: bounded spin first, then benaphore sleep.

    The spin budget tracks recent acquisition history exactly like glibc's
    ``mutex->__data.__spins += (cnt - spins) / 8`` running average, capped at
    ``max_spin``.  Crucially there is **no sleep->spin transition**: a thread
    that sleeps is woken straight into the acquisition race, paying the full
    wake-up latency — the gap the mutable lock closes.
    """

    MAX_SPIN = 100

    def __init__(self):
        self._cell = AtomicBool(False)
        self._waiters = AtomicU64(0)
        self._sem = threading.Semaphore(0)
        self._spins = 10  # running-average spin budget
        self.sleeps = 0
        self.spin_iters = 0

    def acquire(self) -> None:
        budget = min(self.MAX_SPIN, 2 * self._spins + 10)
        cnt = 0
        while cnt < budget:
            if not self._cell.load() and not self._cell.test_and_set():
                self._spins += (cnt - self._spins) // 8
                return
            cnt += 1
            self.spin_iters += 1
            time.sleep(0)
        self._spins += (cnt - self._spins) // 8
        # Sleep path (default-mutex behaviour).
        while True:
            self._waiters.fetch_add(1)
            if not self._cell.load() and not self._cell.test_and_set():
                self._waiters.fetch_add(-1)
                return
            self.sleeps += 1
            self._sem.acquire()
            self._waiters.fetch_add(-1)
            if not self._cell.test_and_set():
                return

    def release(self) -> None:
        self._cell.clear()
        if self._waiters.load() > 0:
            self._sem.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


#: Registry used by lockbench and the framework's lock factory.
LOCKS = {
    "tas": TASLock,
    "ttas": TTASLock,
    "mcs": MCSLock,
    "sleep": SleepLock,
    "adaptive": AdaptiveMutex,
}

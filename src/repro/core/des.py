"""Discrete-event simulation of lock algorithms under processor sharing.

Purpose (DESIGN.md §2): this container has a single hardware core, so real
threads can never exhibit the paper's *multi-core* regimes (20-core machine,
spinners genuinely parallel with the critical-section holder).  This module
simulates the six lock disciplines on a machine with a configurable number
of cores, CS/NCS length distributions, and OS wake-up latency — reproducing
Fig. 1's timelines and Fig. 3's throughput / CPU-time trends deterministically.

Model
-----
* ``cores`` CPUs, generalized processor sharing: every *runnable* task
  (executing CS, executing NCS, or spinning) advances at rate
  ``min(1, cores / n_runnable)``.
* Sleeping / waking threads are not runnable (consume no CPU).
* Waking takes ``wake_latency`` wall seconds (OS scheduling delay).
* Hardware contention: the CS holder's rate is additionally multiplied by
  ``1 / (1 + alpha * n_spinners)`` — the cache-coherency pressure the paper
  attributes to concurrent RMW/spin traffic (§2).  ``alpha`` is per-lock
  (MCS spins on local lines -> 0; TAS is worst) and overridable per run.
* Wake permits are conserved exactly like a semaphore: a wake-up issued when
  no thread is parked is banked and absorbed by the next would-be sleeper.
* Metric "CPU time in synchronization" = integral of CPU consumed by
  spinning, the paper's wasted-cycles metric.

The mutable-lock model runs the real :class:`~repro.core.oracle.EvalSWS`
oracle and the C1/C2 wake-up-count corrections of Algorithm 1 — the DES and
the threaded implementation share the oracle code, so validating one
validates the policy of the other.

Workloads: CS/NCS duration draws route through the workload rows of
:data:`repro.core.policy.WORKLOAD_ROWS` (constant, bursty ON/OFF,
heterogeneous per-thread scales, Poisson-like jittered arrivals) — this
module is the event-driven twin the batched engine's workload rows are
pinned against by randomized parity tests (tests/test_workloads.py).  The
per-thread phase/scale state is drawn from a dedicated seeded stream, so
the constant row consumes exactly the pre-workload RNG sequence.

Faults: environment interference routes through the fault rows of
:data:`repro.core.policy.FAULT_ROWS` (lock-holder preemption, CPU
oversubscription, lost wake-ups with timeout recovery, timer jitter).  The
DES realizes them as (a) a per-(thread, window) progress multiplier on
CS/NCS execution, gated by the same ``FLT_GATE_SALT`` counter stream as the
batched engine, with event intervals capped at fault-window boundaries so
multipliers stay piecewise-constant, and (b) a perturbation of the wake-up
latency at wake-scheduling time (``FLT_WAKE_SALT`` / ``FLT_MAG_SALT``
streams, indexed by a per-thread wake counter — the batched engine keys the
same draws by step index, so the two agree in distribution, not bit-for-bit;
parity is pinned by seed-averaged band tests in tests/test_faults.py).
Spin burn is deliberately NOT modulated: a preempted spinner stops making
progress anyway, while the sleeper's parked time costs nothing — the
asymmetry that lets sleep-leaning disciplines overtake spin under
preemption.  The ``none`` row takes none of these code paths, so benign
runs are bit-identical to the pre-fault DES.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from . import policy
from .oracle import EvalSWS, Oracle

# thread states (shared integer encoding: see repro.core.policy)
from .policy import CS, DONE, NCS, SPIN, STATE_NAMES, WAKING
from .policy import SLEEP_ST as SLEEP  # noqa: N811 (DES-local alias)


@dataclass
class _Task:
    tid: int
    state: int = NCS
    remaining: float = 0.0      # CPU-seconds of work left (CS/NCS/spin budget)
    wake_at: float = -1.0       # wall time the wake completes (WAKING)
    slept: bool = False         # paper's per-acquire `slept` flag
    spun: bool = False          # paper's per-acquire `spun` flag
    cs_done: int = 0
    spin_cpu: float = 0.0


@dataclass
class SimResult:
    lock: str
    threads: int
    cores: int
    completed_cs: int = 0
    t_end: float = 0.0
    spin_cpu: float = 0.0       # CPU-seconds burnt spinning (sync waste)
    wake_count: int = 0
    sws_trace: list = field(default_factory=list)
    timeline: list = field(default_factory=list)  # (t, tid, event) triples
    # -- open-loop accounting (zero / empty on closed runs) -----------------
    arrived: int = 0            # offered arrivals (admitted + shed)
    shed: int = 0               # dropped at the full queue
    slo_viol: int = 0           # departures with latency > slo
    latencies: list = field(default_factory=list)   # per-request sojourns

    @property
    def throughput(self) -> float:
        return self.completed_cs / self.t_end if self.t_end > 0 else 0.0

    @property
    def sync_cpu_per_cs(self) -> float:
        return self.spin_cpu / max(1, self.completed_cs)

    @property
    def mean_latency(self) -> float:
        return (sum(self.latencies) / len(self.latencies)
                if self.latencies else float("nan"))

    def latency_percentile(self, q: float) -> float:
        """Exact per-request latency quantile (nearest-rank)."""
        if not self.latencies:
            return float("nan")
        lat = sorted(self.latencies)
        return lat[min(len(lat) - 1,
                       max(0, math.ceil(q * len(lat)) - 1))]


# ---------------------------------------------------------------------------
# Lock discipline models
# ---------------------------------------------------------------------------
class _LockModel:
    """Reacts to arrive/release/wake events; decides spin vs sleep vs enter."""

    default_alpha = 0.0  # hardware-contention coefficient

    def __init__(self, sim: "LockSim", alpha: float | None = None):
        self.sim = sim
        self.alpha = self.default_alpha if alpha is None else alpha
        self.holder: int | None = None
        self.permits = 0  # banked semaphore permits (conserved wake-ups)

    # -- hooks --------------------------------------------------------------
    def on_arrive(self, t: _Task) -> None:
        raise NotImplementedError

    def on_release(self, t: _Task) -> None:
        raise NotImplementedError

    def on_wake_complete(self, t: _Task) -> None:
        raise NotImplementedError

    def on_spin_budget_exhausted(self, t: _Task) -> None:
        raise AssertionError("no spin budget in this discipline")

    # -- helpers --------------------------------------------------------------
    def _enter_cs(self, t: _Task) -> None:
        assert self.holder is None, "mutual exclusion violated in model"
        self.holder = t.tid
        self.sim.start_cs(t)

    def _sleep(self, t: _Task) -> None:
        """Park t, absorbing a banked permit if one exists (semaphore law)."""
        if self.permits > 0:
            self.permits -= 1
            self.sim.schedule_wake_direct(t)  # instant re-dispatch path
        else:
            t.state = SLEEP

    def _wake_some(self, k: int) -> None:
        """Issue k wake permits; park-free permits are banked."""
        for _ in range(k):
            sl = self.sleepers()
            if sl:
                self.sim.schedule_wake(self.sim.rng.choice(sl))
            else:
                self.permits += 1

    def spinners(self) -> list[_Task]:
        return [t for t in self.sim.tasks if t.state == SPIN]

    def sleepers(self) -> list[_Task]:
        return [t for t in self.sim.tasks if t.state == SLEEP]

    # -- model-internal wall-clock events (backoff polls etc.) --------------
    def next_event(self) -> float:
        """Earliest model-internal wall-clock event, or +inf.  The DES main
        loop caps its interval here so discipline-private timers (e.g. the
        ttas_backoff poll schedule) fire exactly on time."""
        return float("inf")

    def on_time_advanced(self) -> None:
        """Fire model-internal events due at ``sim.now`` (default: none)."""


class SpinModel(_LockModel):
    """TTAS-style: every waiter spins; release hands to a random spinner."""

    name = "ttas"
    default_alpha = policy.DEFAULT_ALPHA["ttas"]

    def on_arrive(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:
            t.state = SPIN
            t.spun = True

    def on_release(self, t):
        self.holder = None
        sp = self.spinners()
        if sp:
            self._enter_cs(self.sim.rng.choice(sp))

    def on_wake_complete(self, t):
        raise AssertionError("spin lock never sleeps")


class TASModel(SpinModel):
    name = "tas"
    default_alpha = policy.DEFAULT_ALPHA["tas"]


class MCSModel(_LockModel):
    """FIFO queue lock; waiters spin on private lines (alpha = 0)."""

    name = "mcs"
    default_alpha = policy.DEFAULT_ALPHA["mcs"]

    def __init__(self, sim, alpha=None):
        super().__init__(sim, alpha)
        self.queue: list[int] = []

    def on_arrive(self, t):
        if self.holder is None and not self.queue:
            self._enter_cs(t)
        else:
            t.state = SPIN
            t.spun = True
            self.queue.append(t.tid)

    def on_release(self, t):
        self.holder = None
        if self.queue:
            self._enter_cs(self.sim.tasks[self.queue.pop(0)])

    def on_wake_complete(self, t):
        raise AssertionError("mcs never sleeps")


class FIFOModel(MCSModel):
    """True-MCS ticket handoff: waiters join a numbered queue and the lock
    is granted strictly in arrival order — no barging.  The event-driven
    twin of the batched engine's ``fifo`` discipline row (which implements
    the same order with per-thread tickets); parity between the two is
    pinned by tests/test_disciplines.py."""

    name = "fifo"
    default_alpha = policy.DEFAULT_ALPHA["fifo"]


class SleepModel(_LockModel):
    """Benaphore / pthread-mutex default: always sleep when contended."""

    name = "sleep"
    default_alpha = policy.DEFAULT_ALPHA["sleep"]

    def on_arrive(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:
            t.slept = True
            self._sleep(t)

    def on_release(self, t):
        self.holder = None
        if self.sleepers() or self.sim.any_waking():
            self._wake_some(1)

    def on_wake_complete(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:  # barged by a new arrival; park again
            self._sleep(t)


class AdaptiveModel(_LockModel):
    """glibc adaptive: spin for a fixed budget, then sleep.  No sleep->spin."""

    name = "adaptive"
    default_alpha = policy.DEFAULT_ALPHA["adaptive"]

    def __init__(self, sim, spin_budget: float = 2e-6, alpha=None):
        super().__init__(sim, alpha)
        self.spin_budget = spin_budget  # CPU-seconds before giving up

    def on_arrive(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:
            t.state = SPIN
            t.spun = True
            t.remaining = self.spin_budget  # consumed at CPU rate

    def on_spin_budget_exhausted(self, t):
        t.slept = True
        self._sleep(t)

    def on_release(self, t):
        self.holder = None
        sp = self.spinners()
        if sp:
            self._enter_cs(self.sim.rng.choice(sp))
        elif self.sleepers() or self.sim.any_waking():
            self._wake_some(1)

    def on_wake_complete(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:
            self._sleep(t)


class MutableModel(_LockModel):
    """Paper Algorithm 1 on top of the DES: spinning window + sleep->spin
    transitions + EvalSWS oracle + C1/C2 wake-up-count corrections."""

    name = "mutable"
    default_alpha = policy.DEFAULT_ALPHA["mutable"]

    def __init__(self, sim, initial_sws: int = 1, max_sws: int | None = None,
                 oracle: Oracle | None = None, alpha=None):
        super().__init__(sim, alpha)
        self.sws = initial_sws
        self.max = max_sws if max_sws is not None else sim.cores
        self.thc = 0
        self.wuc = 0
        self.oracle = oracle if oracle is not None else EvalSWS(k=10)

    def on_arrive(self, t):
        thc_pre, self.thc = self.thc, self.thc + 1       # A4: FAD(+1)
        t.slept = t.spun = False
        if policy.should_sleep_on_arrival(thc_pre, self.sws):  # A7
            t.slept = True                               # A8
            self._sleep(t)                               # A9
        elif self.holder is None:                        # A11: spn_obj free
            self._acquired(t)
        else:
            t.state = SPIN                               # A11: spin phase
            t.spun = True

    def _acquired(self, t):
        """spn_obj acquired: run EvalSWS + C1/C2 bookkeeping (A12-A33)."""
        self._enter_cs(t)
        self.sim.res.sws_trace.append((self.sim.now, self.sws))
        delta = self.oracle.eval_sws(t.spun, t.slept, self.sws)  # A12
        delta = policy.clamp_delta(self.sws, delta, 1, self.max)  # A16-A17
        if delta:                                        # A18
            sws_pre, self.sws = self.sws, self.sws + delta       # A20
            # A21-A33: C1/C2 correction from the shared policy core.
            self.wuc += policy.wake_correction(delta, self.thc, sws_pre)

    def on_release(self, t):
        r_wuc, self.wuc = policy.latch_wuc(self.wuc)     # R2-R7
        thc_pre, self.thc = self.thc, self.thc - 1       # R9: FAD(-1)
        self.holder = None                               # R10: spn unlock
        sp = self.spinners()
        if sp:                                           # spn handoff
            self._acquired(self.sim.rng.choice(sp))
        # R11-R17: the handoff's _acquired may have resized the window, so
        # the R16 check reads the post-handoff sws (same order as before).
        self._wake_some(policy.release_quota(r_wuc, thc_pre, self.sws))

    def on_wake_complete(self, t):
        # The sleep->spin transition: the woken thread joins the window.
        if self.holder is None:
            # spn_obj free: acquired with no spinning -> t.spun stays False,
            # so EvalSWS sees the late wake-up and doubles the window.
            self._acquired(t)
        else:
            t.state = SPIN
            t.spun = True  # spn_obj.lock() will observe contention


class FissileModel(AdaptiveModel):
    """Fissile-style spin-then-park composition (Dice & Kogan): waiters spin
    for a *bounded* budget then park, and the budget self-tunes through the
    same oracle state as the mutable lock — ``sws`` scales the budget
    (``spin_budget * sws * park_cost``, the spin-for-about-a-park-round-trip
    rule) instead of gating arrivals.  A park doubles the window (bigger
    budget next time); clean spin-only acquisitions shrink it.  The
    event-driven twin of the engine's ``fissile`` row, which masks ``spun``
    so the oracle's *late* signal is exactly *did this acquisition park?*."""

    name = "fissile"
    default_alpha = policy.DEFAULT_ALPHA["fissile"]

    def __init__(self, sim, spin_budget: float = 2e-6, initial_sws: int = 1,
                 max_sws: int | None = None, oracle: Oracle | None = None,
                 alpha=None):
        super().__init__(sim, spin_budget, alpha)
        self.sws = max(1, min(initial_sws,
                              max_sws if max_sws is not None else sim.cores))
        self.max = max_sws if max_sws is not None else sim.cores
        self.oracle = oracle if oracle is not None else EvalSWS(k=10)

    def _budget(self) -> float:
        return self.spin_budget * self.sws * self.sim.park_cost

    def _acquired(self, t):
        """Lock acquired: resize the budget window.  ``spun`` is forced
        False so the oracle's late signal is purely *slept* (as in the
        engine's ``budget_scaled`` masking)."""
        self._enter_cs(t)
        self.sim.res.sws_trace.append((self.sim.now, self.sws))
        delta = self.oracle.eval_sws(False, t.slept, self.sws)
        delta = policy.clamp_delta(self.sws, delta, 1, self.max)
        self.sws += delta

    def on_arrive(self, t):
        t.slept = t.spun = False
        if self.holder is None:
            self._acquired(t)
        else:
            t.state = SPIN
            t.spun = True
            t.remaining = self._budget()

    def on_release(self, t):
        self.holder = None
        sp = self.spinners()
        if sp:
            self._acquired(self.sim.rng.choice(sp))
        elif self.sleepers() or self.sim.any_waking():
            self._wake_some(1)

    def on_wake_complete(self, t):
        if self.holder is None:
            self._acquired(t)
        else:  # sleep->spin: rejoin the spin phase with a re-armed budget
            t.state = SPIN
            t.remaining = self._budget()


class HapaxModel(_LockModel):
    """Hapax value-based FIFO admission (Dice & Kogan): constant-time
    arrival (tail enqueue) and unlock (head wake).  Every contended arrival
    parks with its queue position; releases wake strictly in arrival order,
    and an arrival may barge only when the lock is free AND nobody waits —
    structurally no barging.  Twin of the engine's ``hapax`` row (min-ticket
    grant among parked waiters)."""

    name = "hapax"
    default_alpha = policy.DEFAULT_ALPHA["hapax"]

    def __init__(self, sim, alpha=None):
        super().__init__(sim, alpha)
        self.queue: list[int] = []  # tids of parked/waking waiters, FIFO

    def _wake_head(self, k: int = 1) -> None:
        """Issue k wake permits to the earliest still-sleeping waiters;
        park-free permits are banked (semaphore law), exactly like
        :meth:`_LockModel._wake_some` but in queue order, never random."""
        for _ in range(k):
            sl = [tid for tid in self.queue
                  if self.sim.tasks[tid].state == SLEEP]
            if sl:
                self.sim.schedule_wake(self.sim.tasks[sl[0]])
            else:
                self.permits += 1

    def on_arrive(self, t):
        if self.holder is None and not self.queue:
            self._enter_cs(t)
        else:
            t.slept = True
            self.queue.append(t.tid)
            self._sleep(t)

    def on_release(self, t):
        self.holder = None
        if self.queue:
            self._wake_head(1)

    def on_wake_complete(self, t):
        if self.holder is None and self.queue and self.queue[0] == t.tid:
            self.queue.pop(0)
            self._enter_cs(t)
        else:
            # Not yet this waiter's turn (another head is mid-wake) or the
            # lock is held: re-park WITHOUT losing the queue position.
            self._sleep(t)


class TTASBackoffModel(_LockModel):
    """TTAS with seeded bounded-exponential backoff: contended waiters stay
    runnable (burning spin CPU) but only *poll* the lock on a schedule —
    after each failed poll the next attempt is delayed by
    ``spin_budget * 2^min(attempt, BO_CAP) * u`` with ``u`` from the
    dedicated ``BO_SALT`` counter stream.  No handoff: a release leaves the
    lock free until some spinner's next poll.  Twin of the engine's
    ``ttas_backoff`` row (lowest-tid due poller wins each instant)."""

    name = "ttas_backoff"
    default_alpha = policy.DEFAULT_ALPHA["ttas_backoff"]

    def __init__(self, sim, spin_budget: float = 2e-6, alpha=None):
        super().__init__(sim, alpha)
        self.spin_budget = spin_budget
        self.next_poll: dict[int, float] = {}
        self.attempt: dict[int, int] = {}
        self._draws: dict[int, int] = {}  # per-tid BO-stream counters

    def _bo_u(self, tid: int) -> float:
        k = self._draws.get(tid, 0)
        self._draws[tid] = k + 1
        return policy.counter_uniform_scalar(
            self.sim._flt_seed ^ policy.BO_SALT, tid, k)

    def on_arrive(self, t):
        if self.holder is None:
            self._enter_cs(t)
        else:
            t.state = SPIN
            t.spun = True
            self.attempt[t.tid] = 0
            self.next_poll[t.tid] = (self.sim.now
                                     + self.spin_budget * self._bo_u(t.tid))

    def on_release(self, t):
        self.holder = None  # no handoff: spinners acquire at their polls

    def on_wake_complete(self, t):
        raise AssertionError("ttas_backoff never sleeps")

    def next_event(self) -> float:
        due = [self.next_poll[t.tid] for t in self.spinners()]
        return min(due) if due else float("inf")

    def on_time_advanced(self) -> None:
        eps = 1e-15
        for t in self.spinners():  # tid order: lowest due poller wins
            if self.next_poll[t.tid] > self.sim.now + eps:
                continue
            if self.holder is None:
                self.next_poll.pop(t.tid)
                self.attempt.pop(t.tid)
                self._enter_cs(t)
            else:
                a = self.attempt[t.tid] = self.attempt[t.tid] + 1
                delay = (self.spin_budget
                         * 2.0 ** min(a, policy.BO_CAP) * self._bo_u(t.tid))
                self.next_poll[t.tid] = self.sim.now + delay


_MODELS = {
    "tas": TASModel,
    "ttas": SpinModel,
    "mcs": MCSModel,
    "fifo": FIFOModel,
    "sleep": SleepModel,
    "adaptive": AdaptiveModel,
    "mutable": MutableModel,
    "fissile": FissileModel,
    "hapax": HapaxModel,
    "ttas_backoff": TTASBackoffModel,
}


# ---------------------------------------------------------------------------
# The simulator core
# ---------------------------------------------------------------------------
class LockSim:
    """Generalized-processor-sharing DES of N threads hammering one lock."""

    def __init__(
        self,
        lock: str,
        threads: int,
        cores: int,
        cs: tuple[float, float],
        ncs: tuple[float, float],
        wake_latency: float,
        seed: int = 0,
        record_timeline: bool = False,
        max_cs_per_thread: int | None = None,
        lock_kwargs: dict | None = None,
        workload: str = "constant",
        wl_period: float = 1e-4,
        wl_duty: float = 0.25,
        wl_burst: float = 8.0,
        wl_spread: float = 4.0,
        arrival_phase: float = 0.0,
        arrival: str = "closed",
        arrival_rate: float = 0.0,
        queue_cap: int = policy.QUEUE_MAX,
        slo: float = 1e-3,
        fault: str = "none",
        fault_rate: float = 0.0,
        fault_scale: float = 5e-5,
        park_cost: float = 1.0,
    ):
        self.rng = random.Random(seed)
        self.cores = cores
        self.cs_lo, self.cs_hi = cs
        self.ncs_lo, self.ncs_hi = ncs
        # M:N parking axis: park_cost scales the park/unpark round trip
        # BEFORE the fault rows perturb it, same order as the engine
        # (wake_base = wake * park_cost, then fault wake_delay).
        self.park_cost = park_cost
        self.wake_latency = wake_latency * park_cost
        self.now = 0.0
        self.tasks = [_Task(tid=i) for i in range(threads)]
        self.model: _LockModel = _MODELS[lock](self, **(lock_kwargs or {}))
        self.res = SimResult(lock=lock, threads=threads, cores=cores)
        self.record_timeline = record_timeline
        self.max_cs_per_thread = max_cs_per_thread
        # -- workload rows (the event-driven twin of WORKLOAD_ROWS) --------
        self.workload = policy.WORKLOAD_IDS[workload]
        self.wl_period, self.wl_duty = wl_period, wl_duty
        self.wl_burst, self.wl_spread = wl_burst, wl_spread
        self.arrival_phase = arrival_phase
        # persistent per-thread phase/scale from the SAME salted counter
        # streams as the batched engine (identical realizations per
        # (seed, tid)), leaving the main RNG sequence untouched so the
        # constant row matches the pre-workload engine draw for draw
        u32 = seed & 0xFFFFFFFF
        self._wl_phase = [
            policy.counter_uniform_scalar(u32 ^ policy.WL_PHASE_SALT, i)
            for i in range(threads)]
        self._wl_tscale = [
            policy.workload_thread_scale(
                policy.counter_uniform_scalar(u32 ^ policy.WL_SPREAD_SALT,
                                              i), wl_spread)
            for i in range(threads)]
        # -- open-loop arrival rows (the event-driven twin of ARRIVAL_ROWS) --
        self.arrival = policy.ARRIVAL_IDS[arrival]
        self.arrival_rate = arrival_rate
        self.queue_cap = queue_cap
        self.slo = slo
        self.open_loop = self.arrival != policy.AR_CLOSED
        # burst-gate phase from the same salted counter stream as the engine
        self._ar_phase = policy.counter_uniform_scalar(
            (seed ^ policy.AR_PHASE_SALT) & 0xFFFFFFFF, 0)
        # dedicated arrival stream: the main draw sequence stays untouched,
        # so closed-loop realizations are unchanged by the open-loop fields
        self.arr_rng = random.Random((seed ^ policy.AR_SALT) & 0xFFFFFFFF)
        self.queue: list[float] = []   # FIFO of admitted arrival wall-times
        self._req_t: dict[int, float] = {}  # tid -> bound request's arrival
        self._next_arr = float("inf")
        # -- fault rows (the event-driven twin of FAULT_ROWS) ---------------
        self.fault = policy.FAULT_IDS[fault]
        self.fault_rate = fault_rate
        self.fault_scale = fault_scale
        self._fault_row = policy.FAULT_ROWS[fault]
        self._faulted = self.fault != policy.FAULT_NONE
        self._flt_seed = u32
        # per-thread wake-draw counters for the lostwake/jitter streams
        self._flt_wake_ctr = [0] * threads

    # -- fault-row machinery ------------------------------------------------
    def _wake_delay(self, tid: int) -> float:
        """Effective wake latency under the config's fault row.  The none
        row returns ``wake_latency`` without touching any counter stream."""
        if not self._faulted:
            return self.wake_latency
        k = self._flt_wake_ctr[tid]
        self._flt_wake_ctr[tid] = k + 1
        w1 = policy.counter_uniform_scalar(
            self._flt_seed ^ policy.FLT_WAKE_SALT, tid, k)
        w2 = policy.counter_uniform_scalar(
            self._flt_seed ^ policy.FLT_MAG_SALT, tid, k)
        return self._fault_row.wake_delay(self.wake_latency, w1, w2,
                                          self.fault_rate, self.fault_scale)

    def _fault_window(self) -> int:
        """Current fault-window index, nudged past a boundary the clock has
        effectively reached (guards against float-epsilon stalls)."""
        win = int(self.now / self.fault_scale)
        if (win + 1) * self.fault_scale - self.now <= self.fault_scale * 1e-9:
            win += 1
        return win

    def _fault_mult(self, t: _Task, win: int) -> float:
        """Per-(thread, window) CS/NCS progress multiplier."""
        gu = policy.counter_uniform_scalar(
            self._flt_seed ^ policy.FLT_GATE_SALT, t.tid, win)
        return self._fault_row.progress(1.0 if t.state == CS else 0.0,
                                        gu, self.fault_rate)

    # -- open-loop arrival machinery ----------------------------------------
    def arrival_rate_at(self, t: float) -> float:
        """Instantaneous offered rate: scalar twin of ARRIVAL_ROWS."""
        if self.arrival == policy.AR_BURSTY:
            gate_off = policy.workload_off_gate(t, self._ar_phase,
                                                self.wl_period, self.wl_duty)
            gate_on = 1.0 - gate_off
            return self.arrival_rate * (1.0 + gate_on * (self.wl_burst - 1.0))
        return self.arrival_rate

    def _draw_next_arrival(self, t0: float) -> float:
        """Next arrival after ``t0`` by thinning an Exp(max-rate) stream,
        exact for the time-varying bursty row."""
        rmax = self.arrival_rate * (self.wl_burst
                                    if self.arrival == policy.AR_BURSTY
                                    else 1.0)
        if rmax <= 0.0:
            return float("inf")
        t = t0
        while True:
            t += self.arr_rng.expovariate(rmax)
            if self.arr_rng.random() * rmax <= self.arrival_rate_at(t):
                return t

    def _admit_due_arrivals(self) -> None:
        while self._next_arr <= self.now + 1e-15:
            self.res.arrived += 1
            if len(self.queue) < self.queue_cap:
                self.queue.append(self._next_arr)
            else:
                self.res.shed += 1
            self._next_arr = self._draw_next_arrival(self._next_arr)

    def _bind_queued(self) -> None:
        """Bind queued requests to free (DONE) threads, lowest tid first."""
        if not self.queue:
            return
        for t in self.tasks:
            if not self.queue:
                return
            if t.state == DONE:
                self._req_t[t.tid] = self.queue.pop(0)
                t.state = NCS
                t.remaining = self.draw_ncs(t.tid)
                self._log(t.tid, "bind")

    # -- workload-row hold-time draws ---------------------------------------
    def draw_cs(self, tid: int) -> float:
        """One CS duration under the config's workload row (the scalar
        mirror of :func:`repro.kernels.ref.workload_draw`)."""
        base = self.rng.uniform(self.cs_lo, self.cs_hi)
        if self.workload == policy.WL_HETERO:
            return base * self._wl_tscale[tid]
        return base

    def draw_ncs(self, tid: int) -> float:
        """One NCS (arrival-gap) duration under the workload row."""
        u = self.rng.random()
        base = self.ncs_lo + u * (self.ncs_hi - self.ncs_lo)
        if self.workload == policy.WL_BURSTY:
            gate = policy.workload_off_gate(self.now, self._wl_phase[tid],
                                            self.wl_period, self.wl_duty)
            return base * (1.0 + gate * (self.wl_burst - 1.0))
        if self.workload == policy.WL_HETERO:
            return base * self._wl_tscale[tid]
        if self.workload == policy.WL_JITTER:
            mean = 0.5 * (self.ncs_lo + self.ncs_hi)
            return -mean * math.log1p(-u)
        return base

    # -- helpers for models -------------------------------------------------
    def any_waking(self) -> bool:
        return any(t.state == WAKING for t in self.tasks)

    def _log(self, tid: int, event: str) -> None:
        if self.record_timeline:
            self.res.timeline.append((round(self.now, 12), tid, event))

    def start_cs(self, t: _Task) -> None:
        t.state = CS
        t.remaining = self.draw_cs(t.tid)
        self._log(t.tid, "cs_start")

    def schedule_wake(self, t: _Task) -> None:
        assert t.state == SLEEP
        t.state = WAKING
        t.wake_at = self.now + self._wake_delay(t.tid)
        self.res.wake_count += 1
        self._log(t.tid, "wake_scheduled")

    def schedule_wake_direct(self, t: _Task) -> None:
        """A banked permit absorbed the sleep: still pays the park/unpark
        round-trip latency (the thread had committed to sleeping)."""
        t.state = WAKING
        t.wake_at = self.now + self._wake_delay(t.tid)
        self.res.wake_count += 1
        self._log(t.tid, "wake_banked")

    # -- main loop ------------------------------------------------------------
    def run(self, target_cs: int = 1000, horizon: float = 1e9) -> SimResult:
        ncs_mean = 0.5 * (self.ncs_lo + self.ncs_hi)
        if self.open_loop:
            # threads start free; logical requests arrive and bind to them
            for t in self.tasks:
                t.state = DONE
            self._next_arr = self._draw_next_arrival(0.0)
            self._admit_due_arrivals()
            self._bind_queued()
        else:
            for t in self.tasks:
                t.state = NCS
                # seeded per-thread arrival-order randomization: stagger
                # first arrivals by up to arrival_phase mean-NCS lengths
                t.remaining = (self.draw_ncs(t.tid)
                               + self._wl_phase[t.tid] * self.arrival_phase
                               * ncs_mean)

        while self.res.completed_cs < target_cs and self.now < horizon:
            runnable = [t for t in self.tasks if t.state in (CS, NCS, SPIN)]
            if not runnable:
                wakes = [t for t in self.tasks if t.state == WAKING]
                if not wakes:
                    if self.open_loop and self._next_arr < horizon:
                        self.now = self._next_arr
                        self._admit_due_arrivals()
                        self._bind_queued()
                        continue
                    break  # all DONE (or a model bug; tests assert progress)
                nxt = min(wakes, key=lambda t: t.wake_at)
                self.now = min(nxt.wake_at, self._next_arr)
                if self.now >= nxt.wake_at:
                    self._wake(nxt)
                if self.open_loop:
                    self._admit_due_arrivals()
                    self._bind_queued()
                continue

            rate = min(1.0, self.cores / len(runnable))
            n_spin = sum(1 for t in runnable if t.state == SPIN)
            holder_rate = rate / (1.0 + self.model.alpha * n_spin)
            has_budget = isinstance(self.model, AdaptiveModel)

            # per-(thread, window) fault multipliers; piecewise-constant
            # within a window, so intervals are capped at the boundary
            mult: dict[int, float] | None = None
            if self._faulted:
                win = self._fault_window()
                mult = {t.tid: self._fault_mult(t, win)
                        for t in runnable if t.state in (CS, NCS)}

            dt = float("inf")
            for t in runnable:
                if t.state == CS:
                    r = holder_rate * (mult[t.tid] if mult is not None
                                       else 1.0)
                    if r > 0.0:
                        dt = min(dt, t.remaining / r)
                elif t.state == NCS:
                    r = rate * (mult[t.tid] if mult is not None else 1.0)
                    if r > 0.0:
                        dt = min(dt, t.remaining / r)
                elif has_budget:  # SPIN with budget
                    dt = min(dt, t.remaining / rate)
            for t in self.tasks:
                if t.state == WAKING:
                    dt = min(dt, t.wake_at - self.now)
            ne = self.model.next_event()
            if ne < float("inf"):
                dt = min(dt, ne - self.now)
            if self.open_loop and self._next_arr < float("inf"):
                dt = min(dt, self._next_arr - self.now)
            if mult is not None:
                dt = min(dt, (win + 1) * self.fault_scale - self.now)
            dt = max(dt, 0.0)
            assert dt != float("inf")

            self.now += dt
            finished: list[_Task] = []
            for t in runnable:
                if t.state == CS:
                    m = mult[t.tid] if mult is not None else 1.0
                    t.remaining -= dt * holder_rate * m
                    if t.remaining <= 1e-15:
                        finished.append(t)
                elif t.state == NCS:
                    m = mult[t.tid] if mult is not None else 1.0
                    t.remaining -= dt * rate * m
                    if t.remaining <= 1e-15:
                        finished.append(t)
                else:  # SPIN
                    burn = dt * rate
                    t.spin_cpu += burn
                    self.res.spin_cpu += burn
                    if has_budget:
                        t.remaining -= burn
                        if t.remaining <= 1e-15:
                            self.model.on_spin_budget_exhausted(t)
            for t in self.tasks:
                if t.state == WAKING and t.wake_at <= self.now + 1e-15:
                    self._wake(t)

            for t in sorted(finished, key=lambda x: x.tid):
                if t.state == CS:
                    t.cs_done += 1
                    self.res.completed_cs += 1
                    self._log(t.tid, "cs_end")
                    self.model.on_release(t)
                    if self.open_loop:
                        # departure: record the request's sojourn, free tid
                        lat = self.now - self._req_t.pop(t.tid)
                        self.res.latencies.append(lat)
                        if lat > self.slo:
                            self.res.slo_viol += 1
                        t.state = DONE
                    elif (self.max_cs_per_thread is not None
                            and t.cs_done >= self.max_cs_per_thread):
                        t.state = DONE
                    else:
                        t.state = NCS
                        t.remaining = self.draw_ncs(t.tid)
                elif t.state == NCS:
                    self._log(t.tid, "arrive")
                    self.model.on_arrive(t)

            # model-internal timers (e.g. backoff polls) fire AFTER releases
            # at the same instant, matching the engine's stage order
            # (release/wake, then poll pickup, then arrivals).
            self.model.on_time_advanced()

            if self.open_loop:
                self._admit_due_arrivals()
                self._bind_queued()

        self.res.t_end = self.now
        return self.res

    def _wake(self, t: _Task) -> None:
        self._log(t.tid, "wake_complete")
        self.model.on_wake_complete(t)


def simulate(lock: str, threads: int, cores: int = 20,
             cs: tuple[float, float] = (0.0, 3.7e-6),
             ncs: tuple[float, float] = (0.0, 3.7e-6),
             wake_latency: float = 5e-6, target_cs: int = 2000,
             seed: int = 0, **kw) -> SimResult:
    """One lockbench cell (paper Fig. 3) under the DES."""
    return LockSim(lock, threads, cores, cs, ncs, wake_latency,
                   seed=seed, **kw).run(target_cs=target_cs)

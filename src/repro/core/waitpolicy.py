"""MutableWait — hybrid spin/sleep predicate waiting (DESIGN.md §3.3).

Cross-host waits in the runtime (barrier on checkpoint shards, heartbeat of
peer hosts, straggler watch) are classically written as either a busy poll
(lowest latency, burns a core) or a fixed ``time.sleep`` loop (free, adds up
to one period of latency).  MutableWait applies the paper's insight: spin
for a *self-tuned* budget first, then back off to sleeping polls.  The spin
budget plays the role of the spinning window; "the predicate became true
while we were sleeping" is the late-wake-up signal that grows it; K clean
waits shrink it.
"""

from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class WaitStats:
    waits: int = 0
    spin_hits: int = 0      # satisfied during the spin phase
    sleep_hits: int = 0     # satisfied during the sleep phase (late)
    timeouts: int = 0
    spin_budget_history: list = None

    def __post_init__(self):
        if self.spin_budget_history is None:
            self.spin_budget_history = []


class MutableWait:
    """wait(predicate) with a self-tuned spin budget.

    Parameters mirror the lock: ``k`` clean spins-hits shrink the budget,
    a sleep-hit doubles it (the wait was under-provisioned), clamped to
    [min_spin_s, max_spin_s].
    """

    def __init__(
        self,
        min_spin_s: float = 1e-5,
        max_spin_s: float = 5e-3,
        sleep_s: float = 1e-3,
        k: int = 10,
    ):
        self.min_spin_s = min_spin_s
        self.max_spin_s = max_spin_s
        self.sleep_s = sleep_s
        self.k = k
        self._budget = min_spin_s
        self._clean = 0
        self.stats = WaitStats()

    @property
    def spin_budget_s(self) -> float:
        return self._budget

    def wait(self, predicate, timeout_s: float | None = None) -> bool:
        """Block until ``predicate()`` is truthy.  Returns False on timeout."""
        self.stats.waits += 1
        start = time.monotonic()
        spin_deadline = start + self._budget

        # --- spin phase (hot: lowest reaction latency) --------------------
        while time.monotonic() < spin_deadline:
            if predicate():
                self._observe(late=False)
                self.stats.spin_hits += 1
                return True
            time.sleep(0)  # GIL-friendly busy wait

        # --- sleep phase (cold: poll with period sleep_s) ------------------
        while True:
            if predicate():
                self._observe(late=True)
                self.stats.sleep_hits += 1
                return True
            if timeout_s is not None and time.monotonic() - start > timeout_s:
                self.stats.timeouts += 1
                return False
            time.sleep(self.sleep_s)

    def _observe(self, late: bool) -> None:
        """EvalSWS on the spin budget: double on a late hit, decay after K
        clean hits."""
        if late:
            self._budget = min(self.max_spin_s, self._budget * 2)
            self._clean = 0
        else:
            self._clean += 1
            if self._clean >= self.k:
                self._budget = max(self.min_spin_s, self._budget / 2)
                self._clean = 0
        self.stats.spin_budget_history.append(self._budget)

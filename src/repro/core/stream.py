"""stream — memory-budgeted streaming sweeps over the xdes engine.

:func:`repro.core.xdes.simulate_batch` is one device program per call: at
10^5-10^6 configs its working set — eight ``(C, T)`` state arrays carried
through the blocked rollout, plus the full raw :class:`~repro.core.xdes.
BatchResult` on host — outgrows both accelerator memory and host RAM.
:func:`sweep_stream` runs the SAME blocked/bucketed/sharded rollout
chunk-by-chunk instead:

* **Chunk size from a memory model, not a constant.**
  :func:`bytes_per_config` prices the rollout's working set per config
  (the ``(C, T)`` state block double-buffered across a ``while_loop``
  iteration plus the per-config input/carry/output columns);
  :func:`memory_budget_bytes` resolves the budget — an explicit
  ``mem_mb``, the ``REPRO_SWEEP_MEM_MB`` env var, the accelerator's
  reported ``bytes_limit`` when it has one, else a CPU default — and
  :func:`plan_chunks` divides the two, quantized to
  ``lcm(reduce.group, n_devices) x power-of-two`` so every full chunk
  lands on one compiled executable (the traced-horizon blocked rollout
  makes the executable horizon-agnostic) and reduction groups never
  straddle a chunk boundary.
* **On-device reduction.** Chunks run ``keep_per_thread=False``: the
  ``(chunk, T)`` state reduces on device to per-config summary columns —
  completed CS, spin CPU, wake count, fairness spread (max-min over
  active thread slots), final SWS, executed steps, ``t_end`` — and only
  those ``(chunk,)`` vectors reach the host.  An optional
  :class:`CellReduce` additionally folds each chunk into a donated
  ``(n_cells, group)`` win-count accumulator on device (throughput
  argmax per consecutive ``group``-row block — the phase-diagram
  accumulation), so diagram cells update in place without a host pass.
* **Composition.** ``bucket_steps=True`` buckets the GLOBAL step plan
  (:func:`repro.core.xdes.plan_buckets`) before chunking, so per-config
  horizons match the one-shot bucketed path; ``shard=True`` routes every
  chunk through the ``shard_map`` path.  With ``early_exit=False``
  results are bit-identical to one-shot ``simulate_batch`` and invariant
  to chunk boundaries (configs are independent; padded tail rows are
  copies of the last row).  With ``early_exit=True`` the exit decision
  is per call — i.e. per (bucket, chunk) — so ``steps_run``/``t_end``
  may differ from the one-shot run (each config still reports its exact
  state at its reported horizon); single-chunk streams remain
  bit-identical.

* **Self-healing.**  Long sweeps survive their own failures
  (docs/robustness.md):

  - ``checkpoint_dir=`` checkpoints the accumulated summary columns, the
    ``CellReduce`` win counts and the chunk cursor after every committed
    chunk through :class:`repro.checkpoint.manager.CheckpointManager`'s
    atomic tmp+rename layout; ``resume=True`` restores the latest
    checkpoint (guarded by a sweep-plan fingerprint) and skips the
    already-committed chunks — the resumed result is bit-identical to an
    uninterrupted run.
  - a chunk that dies with an allocation failure (``RESOURCE_EXHAUSTED``
    / out-of-memory) is retried as two half chunks, recursively down to
    one reduction group, instead of killing the sweep.
  - non-finite summary values are quarantined: the offending configs are
    reported in ``StreamResult.failures`` (and, with ``failures_path=``,
    a structured JSON report), and sanitized copies (zero throughput)
    feed the win-count reduction so one poisoned config cannot flip a
    phase-diagram cell.

Feed it raw column arrays (:data:`repro.core.policy.RAW_CONFIG_FIELDS`,
e.g. from the ``*_columns`` generators in :mod:`repro.configs.catalog`)
to keep the whole pipeline array-native — a list of
:class:`~repro.core.policy.SimConfig` works too.  See docs/performance.md
("Scaling sweeps") for the memory model and how the 100k diagrams use
this path.
"""

from __future__ import annotations

import functools
import hashlib
import json
import math
import os
import warnings
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from . import policy as P
from . import xdes

#: Environment variable naming the sweep memory budget in MiB.
ENV_MEM_MB = "REPRO_SWEEP_MEM_MB"
#: Fallback budget (MiB) when neither an explicit ``mem_mb``, the env
#: var, nor an accelerator ``bytes_limit`` is available (CPU hosts).
DEFAULT_MEM_MB = 512.0
#: Fraction of the accelerator's reported ``bytes_limit`` the sweep may
#: claim (headroom for the runtime's own allocations).
DEVICE_MEM_FRACTION = 0.6

#: The blocked rollout's working set, per config (see bytes_per_config):
#: (C, T) state arrays carried through the while_loop...
_STATE_PT_ARRAYS = 8     # st, rem, wake_at, slept, spun, ctr, ticket, cpt
#: ...plus (C,) carries (sws..wake_count, spin_cpu),
_STATE_PC_ARRAYS = 9
#: the encoded input columns (CONFIG_FIELDS + dt),
_IN_COLS = len(P.CONFIG_FIELDS) + 1
#: and the summary output columns.
_OUT_COLS = 7

#: Per-config summary columns a streamed chunk reduces to on device.
SUMMARY_FIELDS = ("completed", "spin_cpu", "wake_count", "final_sws",
                  "t_end", "steps_run", "fairness")

#: Extra (C,) summary columns of an open-loop stream (the (C, LAT_NBINS)
#: ``lat_hist`` histogram rides along separately).
OPEN_SUMMARY_FIELDS = ("arrived", "shed", "departed", "slo_viol",
                       "lat_sum", "occ_int", "in_flight")

#: Open-loop integer summary columns (the rest are float32).
_OPEN_INT_FIELDS = ("arrived", "shed", "departed", "slo_viol", "in_flight")


def bytes_per_config(T: int, *, dtype_bytes: int = 4,
                     double_buffer: int = 2,
                     open_loop: bool = False) -> int:
    """Modelled device working set of one config at ``T`` thread slots.

    Every state/input/output element is 4 bytes (int32/float32/uint32).
    The ``(C, T)`` state block is counted ``double_buffer`` times: XLA
    holds the old and new carry of a ``while_loop`` body concurrently,
    and donation does not reliably elide the copy on every backend — the
    model prices the worst case so the budget is an upper bound.

    ``open_loop=True`` adds the 11 OPEN_STATE carry arrays: one more
    ``(C, T)`` block (``req_t``), the ``(C, QUEUE_MAX)`` ring buffer and
    ``(C, LAT_NBINS)`` histogram, and 8 more per-config counters.
    """
    pt_arrays = _STATE_PT_ARRAYS + (1 if open_loop else 0)
    per_thread = pt_arrays * dtype_bytes * int(T) * double_buffer
    per_config = dtype_bytes * (_STATE_PC_ARRAYS * double_buffer
                                + _IN_COLS + _OUT_COLS)
    if open_loop:
        per_config += dtype_bytes * (
            (P.QUEUE_MAX + P.LAT_NBINS + 8) * double_buffer
            + len(OPEN_SUMMARY_FIELDS) + P.LAT_NBINS)
    return per_thread + per_config


def memory_budget_bytes(mem_mb: float | None = None) -> int:
    """Resolve the sweep memory budget in bytes.

    Priority: explicit ``mem_mb`` argument > ``REPRO_SWEEP_MEM_MB`` env
    var > :data:`DEVICE_MEM_FRACTION` of the accelerator's reported
    ``bytes_limit`` (GPU/TPU) > :data:`DEFAULT_MEM_MB` (CPU hosts, where
    jax reports no limit).
    """
    if mem_mb is None:
        env = os.environ.get(ENV_MEM_MB)
        if env:
            mem_mb = float(env)
    if mem_mb is not None:
        return int(float(mem_mb) * 2**20)
    try:
        stats = jax.devices()[0].memory_stats() or {}
        limit = stats.get("bytes_limit")
        if limit:
            return int(DEVICE_MEM_FRACTION * limit)
    except Exception:          # backends without memory_stats()
        pass
    return int(DEFAULT_MEM_MB * 2**20)


def plan_chunks(C: int, T: int, *, mem_mb: float | None = None,
                quantum: int = 1, open_loop: bool = False) -> int:
    """Chunk size (configs per device call) for a ``C``-config sweep at
    ``T`` thread slots under the resolved memory budget.

    The chunk is the largest ``quantum x power-of-two`` count whose
    modelled working set (:func:`bytes_per_config`) fits the budget
    (:func:`memory_budget_bytes`) — quantized so every full chunk shares
    ONE compiled executable and reduction groups / device shards divide
    it evenly.  Floor: one ``quantum`` (a warning names the overshoot
    when even that exceeds the budget).  Never larger than needed for
    ``C``.
    """
    if C < 1 or T < 1 or quantum < 1:
        raise ValueError("C, T and quantum must be >= 1")
    budget = memory_budget_bytes(mem_mb)
    bpc = bytes_per_config(T, open_loop=open_loop)
    raw = budget // bpc
    if raw < quantum:
        warnings.warn(
            f"sweep memory budget {budget / 2**20:.0f} MiB is below one "
            f"reduction/shard quantum of {quantum} configs at T={T} "
            f"(~{quantum * bpc / 2**20:.1f} MiB); "
            f"streaming at the quantum floor.", stacklevel=2)
        return quantum
    chunk = quantum * (1 << int(math.log2(raw // quantum)))
    return min(chunk, quantum * xdes._pad_quantum(-(-C // quantum)))


@dataclass(frozen=True)
class CellReduce:
    """Phase-diagram accumulation spec for :func:`sweep_stream`.

    Rows are consumed in consecutive blocks of ``group`` (e.g. the V
    (discipline, oracle) variants of one scenario, row order of the
    catalog sweeps); each block's throughput argmax is its winner, and
    ``cell_ids[g]`` names the phase-diagram cell block ``g`` belongs to
    (e.g. its scenario's CS-length x subscription x wake bucket).  The
    stream folds every chunk into a donated on-device ``(n_cells,
    group)`` int32 win-count accumulator — ``StreamResult.wins``.
    """

    group: int
    cell_ids: np.ndarray
    n_cells: int

    def __post_init__(self):
        ids = np.asarray(self.cell_ids, np.int32)
        object.__setattr__(self, "cell_ids", ids)
        if self.group < 1:
            raise ValueError("group must be >= 1")
        if ids.size and (int(ids.min()) < 0
                         or int(ids.max()) >= self.n_cells):
            raise ValueError("cell_ids out of range")


@functools.partial(jax.jit, static_argnames=("group",),
                   donate_argnums=(0,))
def _cell_update(wins, completed, t_end, cell_ids, *, group: int):
    """Fold one chunk into the donated win-count accumulator: throughput
    argmax per ``group``-row block, scatter-add at ``cell_ids`` (-1 ids
    mark padded blocks and contribute nothing)."""
    thr = completed.astype(jnp.float32) / jnp.maximum(t_end, 1e-30)
    win = jnp.argmax(thr.reshape(-1, group), axis=1)
    ok = cell_ids >= 0
    return wins.at[jnp.where(ok, cell_ids, 0), win].add(
        ok.astype(jnp.int32))


@dataclass
class StreamResult:
    """Per-config summary columns of one streamed sweep (numpy, length
    C) — the same statistics as :class:`repro.core.xdes.BatchResult`
    with ``keep_per_thread=False``, without the configs list or any
    (C, T) array ever reaching the host."""

    n_configs: int
    n_steps: int               # the largest horizon any chunk ran
    backend: str
    dt: np.ndarray
    t_end: np.ndarray
    completed: np.ndarray
    spin_cpu: np.ndarray
    wake_count: np.ndarray
    final_sws: np.ndarray
    steps_run: np.ndarray
    fairness: np.ndarray
    #: Streaming-plan record: configs per device call, number of calls,
    #: resolved budget, and the bytes/config model behind the chunk size.
    chunk_size: int = 0
    n_chunks: int = 0
    budget_mb: float = 0.0
    bytes_per_config: int = 0
    #: (n_cells, group) on-device win counts when a CellReduce was given.
    wins: np.ndarray | None = None
    #: Quarantined configs: one record per config whose summary came back
    #: non-finite (see :func:`_quarantine`).  Empty on healthy sweeps.
    failures: list = field(default_factory=list)
    #: Chunks restored from a checkpoint instead of recomputed.
    resumed_chunks: int = 0
    #: Open-loop outputs (``None`` on closed sweeps): the (C, LAT_NBINS)
    #: latency histogram and the (C,) request counters / accumulators —
    #: same semantics as :class:`repro.core.xdes.BatchResult`.
    lat_hist: np.ndarray | None = None
    arrived: np.ndarray | None = None
    shed: np.ndarray | None = None
    departed: np.ndarray | None = None
    slo_viol: np.ndarray | None = None
    lat_sum: np.ndarray | None = None
    occ_int: np.ndarray | None = None
    in_flight: np.ndarray | None = None

    @property
    def throughput(self) -> np.ndarray:
        return self.completed / np.maximum(self.t_end, 1e-30)

    @property
    def sync_cpu_per_cs(self) -> np.ndarray:
        return self.spin_cpu / np.maximum(self.completed, 1)

    def fairness_spread(self, i: int) -> int:
        return int(self.fairness[i])

    def latency_quantiles(self, qs=(0.50, 0.95, 0.99)) -> np.ndarray:
        """(len(qs), C) per-request latency percentiles from the streamed
        histogram (NaN where nothing departed)."""
        if self.lat_hist is None:
            raise ValueError("closed-loop sweep: no latency histogram")
        return P.latency_percentiles(self.lat_hist, qs)

    @property
    def p50(self) -> np.ndarray:
        return self.latency_quantiles((0.50,))[0]

    @property
    def p95(self) -> np.ndarray:
        return self.latency_quantiles((0.95,))[0]

    @property
    def p99(self) -> np.ndarray:
        return self.latency_quantiles((0.99,))[0]

    @property
    def slo_frac(self) -> np.ndarray:
        if self.slo_viol is None:
            raise ValueError("closed-loop sweep: no SLO accounting")
        dep = np.asarray(self.departed, np.float64)
        return np.where(dep > 0, self.slo_viol / np.maximum(dep, 1.0),
                        np.nan)


def _run_chunk(arrs, n_steps: int, T: int, backend: str, block_steps: int,
               target_cs: int, shard: bool, open_loop: bool = False):
    """One device call on an encoded chunk — the sharded or the
    traced-horizon unsharded blocked rollout, ``keep_per_thread=False``
    (summaries reduce on device)."""
    if shard:
        return xdes._simulate_sharded(
            arrs, n_steps=int(n_steps), T=T, backend=backend,
            rollout="blocked", block_steps=block_steps,
            target_cs=target_cs, keep_per_thread=False,
            open_loop=open_loop)
    return xdes._simulate_dyn(
        arrs, np.int32(n_steps), T=T, backend=backend, rollout="blocked",
        block_steps=block_steps, target_cs=np.int32(target_cs),
        early_exit=target_cs > 0, keep_per_thread=False,
        open_loop=open_loop)


def _pad_rows(arrs, n: int):
    """Pad every column to ``n`` rows with copies of the last row (the
    bucketed path's trick: independent copies converge exactly when the
    source row does, so early exit and results are unchanged)."""
    C = arrs["policy"].shape[0]
    if n <= C:
        return arrs
    return {k: np.concatenate([v, np.repeat(v[-1:], n - C, axis=0)])
            for k, v in arrs.items()}


def _is_oom(e: BaseException) -> bool:
    """Allocation failure, by message: jax surfaces accelerator OOM as
    ``XlaRuntimeError`` with a ``RESOURCE_EXHAUSTED`` status (message
    wording varies by backend, so match the status and the common
    phrasings)."""
    s = f"{type(e).__name__}: {e}"
    return ("RESOURCE_EXHAUSTED" in s or "out of memory" in s.lower()
            or "OOM" in s)


def _run_chunk_resilient(part, n: int, horizon, T, backend, block_steps,
                         target_cs, shard, open_loop, quantum: int,
                         chunk: int, group: int, verbose: bool = False):
    """Run one chunk with halving backoff: an allocation failure splits
    the chunk into two group-aligned halves and retries each, recursively
    down to one reduction group / shard quantum.  Returns the summary
    dict trimmed to the ``n`` real rows."""
    pad_to = min(chunk, quantum * xdes._pad_quantum(-(-n // quantum)))
    try:
        res = _run_chunk(_pad_rows(part, pad_to), horizon, T, backend,
                         block_steps, target_cs, shard, open_loop)
        return {k: np.asarray(v)[:n] for k, v in res.items()}
    except Exception as e:                      # noqa: BLE001 (filtered)
        if not _is_oom(e) or n <= quantum:
            raise
        mid = group * max(1, (n // 2) // group)
        if verbose:
            print(f"  stream chunk of {n} configs hit "
                  f"{type(e).__name__}; retrying as {mid} + {n - mid}")
        warnings.warn(
            f"sweep chunk of {n} configs failed with an allocation error; "
            f"retrying with halved chunks ({mid} + {n - mid})",
            stacklevel=2)
        halves = []
        for lo, hi in ((0, mid), (mid, n)):
            sub = {k: v[lo:hi] for k, v in part.items()}
            halves.append(_run_chunk_resilient(
                sub, hi - lo, horizon, T, backend, block_steps, target_cs,
                shard, open_loop, quantum, max(quantum, pad_to // 2),
                group, verbose))
        return {k: np.concatenate([h[k] for h in halves])
                for k in halves[0]}


#: Float summary columns scanned for engine non-finites (intentional NaN
#: lives only in DERIVED statistics of empty histograms — see
#: ``StreamResult.latency_quantiles``/``slo_frac`` — never in these).
_FINITE_FIELDS = ("t_end", "spin_cpu", "lat_sum", "occ_int")


def _quarantine(res: dict, cols, sel_index: np.ndarray, failures: list):
    """Detect non-finite summary values in one chunk's results.

    Appends one structured record per offending config to ``failures``
    (global config index, the non-finite fields, and the config's raw
    column values for reproduction) and returns a per-row bad mask.  The
    caller feeds SANITIZED copies to the win-count reduction; the raw
    values stay visible in the summary columns."""
    bad = np.zeros(sel_index.shape[0], bool)
    for f in _FINITE_FIELDS:
        if f in res:
            bad |= ~np.isfinite(np.asarray(res[f], np.float64))
    if not bad.any():
        return bad
    for i in np.nonzero(bad)[0]:
        gi = int(sel_index[i])
        failures.append({
            "index": gi,
            "fields": {f: float(np.asarray(res[f], np.float64)[i])
                       for f in _FINITE_FIELDS if f in res
                       and not np.isfinite(np.asarray(res[f],
                                                      np.float64)[i])},
            "config": {k: (v[gi].item() if np.asarray(v).ndim else
                           np.asarray(v).item())
                       for k, v in cols.items()},
        })
    return bad


def _write_failures(path: str, n_configs: int, failures: list) -> None:
    """Atomically write the structured quarantine report (tmp+rename,
    same crash-safety contract as the checkpoint layout)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"n_configs": n_configs, "n_failures": len(failures),
                   "failures": failures}, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)


def _plan_fingerprint(arrs, *, chunk, T, n_steps, target_cs, backend,
                      bucket_steps, shard, group) -> np.ndarray:
    """Digest of the sweep plan + encoded inputs: a checkpoint written by
    a DIFFERENT sweep (other configs, other chunking) must never be
    resumed into this one."""
    h = hashlib.sha256()
    h.update(repr((int(chunk), int(T), int(n_steps), int(target_cs),
                   str(backend), bool(bucket_steps), bool(shard),
                   int(group))).encode())
    for k in sorted(arrs):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrs[k]).tobytes())
    return np.frombuffer(h.digest(), np.uint8).copy()


def sweep_stream(configs, *, target_cs: int = 300,
                 n_steps: int | None = None, dt=None, backend: str = "ref",
                 block_steps: int | None = None, shard: bool | None = None,
                 bucket_steps: bool = False, early_exit: bool | None = None,
                 reduce: CellReduce | None = None,
                 mem_mb: float | None = None,
                 max_threads: int | None = None,
                 chunk: int | None = None,
                 strict: bool = True,
                 checkpoint_dir: str | None = None,
                 resume: bool = False,
                 failures_path: str | None = None,
                 verbose: bool = False) -> StreamResult:
    """Run a sweep chunk-by-chunk under a memory budget; see the module
    docstring for the mechanism.

    ``configs`` is a RAW column mapping (:data:`repro.core.policy.
    RAW_CONFIG_FIELDS`) or a list of :class:`~repro.core.policy.
    SimConfig`.  Planning (``dt`` + per-config horizons, and the
    ``bucket_steps`` grouping) happens ONCE over the full sweep, so
    per-config horizons match the equivalent one-shot
    :func:`~repro.core.xdes.simulate_batch` call regardless of
    chunking.  ``chunk`` overrides the budget-derived size (tests);
    ``mem_mb`` overrides the budget (else env/device/default — see
    :func:`memory_budget_bytes`).  ``early_exit`` defaults to on iff the
    horizon is auto-planned, like ``simulate_batch`` — pass ``False``
    for chunk-invariant bit-exactness.

    Resilience (docs/robustness.md): ``strict=False`` clamps out-of-range
    sweep columns instead of raising (:func:`repro.core.policy.
    encode_columns`); ``checkpoint_dir`` + ``resume`` give chunk-granular
    crash recovery; allocation failures retry with halved chunks;
    non-finite summaries are quarantined into ``StreamResult.failures``
    (and ``failures_path`` when given) with sanitized rows feeding the
    win-count reduction.
    """
    cols = configs if isinstance(configs, dict) else \
        P.config_columns(configs)
    arrs = P.encode_columns(cols, validate=isinstance(configs, dict),
                            strict=strict)
    C = arrs["policy"].shape[0]
    open_loop = bool((np.asarray(arrs["arrival"]) != P.AR_CLOSED).any())
    if reduce is not None:
        if C % reduce.group:
            raise ValueError(f"C={C} not a multiple of reduce.group="
                             f"{reduce.group}")
        if reduce.cell_ids.shape != (C // reduce.group,):
            raise ValueError("cell_ids must have one entry per group")

    auto_dt, steps_arr = xdes.plan_schedule_columns(cols, target_cs)
    dt = auto_dt if dt is None else np.broadcast_to(
        np.asarray(dt, np.float32), (C,)).copy()
    if n_steps is None:
        if int(steps_arr.max()) > xdes.MAX_STEPS and not bucket_steps:
            over = int((steps_arr > xdes.MAX_STEPS).sum())
            warnings.warn(
                f"step cap {xdes.MAX_STEPS} truncates {over}/{C} configs "
                f"below target_cs={target_cs} (see plan_schedule); "
                f"bucket_steps=True keeps fast cells fully sampled.",
                stacklevel=2)
        n_steps = min(int(steps_arr.max()), xdes.MAX_STEPS)
        if early_exit is None:
            early_exit = True
    elif early_exit is None:
        early_exit = False
    arrs["dt"] = np.asarray(dt, np.float32)

    T = max_threads or int(arrs["threads"].max())
    if T < int(arrs["threads"].max()):
        raise ValueError("max_threads smaller than widest config")
    if shard is None:
        shard = len(jax.devices()) > 1
    n_dev = len(jax.devices()) if shard else 1
    if block_steps is None:
        block_steps = xdes.DEFAULT_BLOCK_STEPS
    tc = int(target_cs) if early_exit else 0

    group = reduce.group if reduce is not None else 1
    quantum = (group * n_dev) // math.gcd(group, n_dev)
    if chunk is None:
        chunk = plan_chunks(C, T, mem_mb=mem_mb, quantum=quantum,
                            open_loop=open_loop)
    elif chunk % quantum:
        raise ValueError(f"chunk={chunk} not a multiple of the "
                         f"group/device quantum {quantum}")
    bpc = bytes_per_config(T, open_loop=open_loop)
    budget_mb = memory_budget_bytes(mem_mb) / 2**20

    out = {f: np.empty(C, np.float32 if f in ("spin_cpu", "t_end")
                       else np.int32) for f in SUMMARY_FIELDS}
    if open_loop:
        for f in OPEN_SUMMARY_FIELDS:
            out[f] = np.empty(C, np.int32 if f in _OPEN_INT_FIELDS
                              else np.float32)
        out["lat_hist"] = np.empty((C, P.LAT_NBINS), np.int32)
    wins = (jnp.zeros((reduce.n_cells, group), jnp.int32)
            if reduce is not None else None)
    # Per-chunk on-device cell accumulation needs every group's rows in
    # one call: that holds in row order, but bucketing regroups rows by
    # horizon — there the accumulator folds once at the end instead.
    chunk_reduce = reduce is not None and not bucket_steps

    if bucket_steps:
        buckets = xdes.plan_buckets(steps_arr)
        plans = [(idx, min(int(steps_arr[idx].max()), xdes.MAX_STEPS))
                 for idx in buckets]
    else:
        plans = [(None, int(n_steps))]

    # deterministic flat chunk schedule: the unit of checkpoint/resume
    chunk_plans = []
    for idx, horizon in plans:
        rows = C if idx is None else len(idx)
        for lo in range(0, rows, chunk):
            hi = min(lo + chunk, rows)
            chunk_plans.append((idx, lo, hi, horizon))

    failures: list = []
    mgr = None
    cursor = 0                     # chunks already committed (checkpoint)
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(checkpoint_dir, keep_last=2,
                                async_save=False)
        fp = _plan_fingerprint(
            arrs, chunk=chunk, T=T, n_steps=int(n_steps),
            target_cs=tc, backend=backend, bucket_steps=bucket_steps,
            shard=shard, group=group)
        template = {"out": {k: np.zeros_like(v) for k, v in out.items()},
                    "wins": (np.zeros((reduce.n_cells, group), np.int32)
                             if reduce is not None
                             else np.zeros((1,), np.int32)),
                    "cursor": np.zeros((), np.int64),
                    "fingerprint": np.zeros_like(fp),
                    "failures_json": np.zeros((), np.uint32)}
        if resume:
            step, tree = mgr.restore(template)
            if tree is not None:
                if not np.array_equal(np.asarray(tree["fingerprint"]), fp):
                    raise ValueError(
                        f"checkpoint in {checkpoint_dir!r} was written by "
                        f"a different sweep plan; refusing to resume")
                cursor = int(tree["cursor"])
                for k in out:
                    out[k][...] = np.asarray(tree["out"][k])
                if reduce is not None:
                    wins = jnp.asarray(tree["wins"])
                nfail = int(tree["failures_json"])
                if nfail and failures_path and os.path.exists(
                        failures_path):
                    with open(failures_path) as f:
                        failures = json.load(f)["failures"][:nfail]
                if verbose:
                    print(f"  stream resume: {cursor}/{len(chunk_plans)} "
                          f"chunks restored from {checkpoint_dir}")

    n_chunks = 0
    run_steps = 0
    for ci, (idx, lo, hi, horizon) in enumerate(chunk_plans):
        n_chunks += 1
        run_steps = max(run_steps, horizon)
        if ci < cursor:
            continue               # committed before the crash: restored
        sel = slice(lo, hi) if idx is None else idx[lo:hi]
        gidx = np.arange(lo, hi) if idx is None else np.asarray(idx[lo:hi])
        part = {k: v[sel] for k, v in arrs.items()}
        n = hi - lo
        # _run_chunk_resilient pads the tail chunk onto the quantized
        # shape ladder (executable reuse) and halves on OOM
        res = _run_chunk_resilient(part, n, horizon, T, backend,
                                   int(block_steps), tc, shard, open_loop,
                                   quantum, chunk, group, verbose)
        for f in SUMMARY_FIELDS:
            out[f][sel] = res[f]
        if open_loop:
            for f in OPEN_SUMMARY_FIELDS:
                out[f][sel] = res[f]
            out["lat_hist"][sel] = res["lat_hist"]
        bad = _quarantine(res, cols, gidx, failures)
        if chunk_reduce:
            completed = np.where(bad, 0, res["completed"])
            t_end = np.where(bad, 1.0, res["t_end"]).astype(np.float32)
            cid = reduce.cell_ids[lo // group:hi // group]
            wins = _cell_update(wins, jnp.asarray(completed),
                                jnp.asarray(t_end), jnp.asarray(cid),
                                group=group)
        if verbose:
            print(f"  stream chunk {ci + 1}/{len(chunk_plans)}: {n} "
                  f"configs x {horizon} steps"
                  + (f" [{int(bad.sum())} quarantined]" if bad.any()
                     else ""))
        if mgr is not None:
            if failures and failures_path:
                _write_failures(failures_path, C, failures)
            mgr.save(ci + 1, {
                "out": out,
                "wins": (np.asarray(wins) if wins is not None
                         else np.zeros((1,), np.int32)),
                "cursor": np.int64(ci + 1),
                "fingerprint": fp,
                "failures_json": np.uint32(len(failures))})
    if reduce is not None and not chunk_reduce:
        badf = np.zeros(C, bool)
        for f in _FINITE_FIELDS:
            if f in out:
                badf |= ~np.isfinite(np.asarray(out[f], np.float64))
        wins = _cell_update(
            jnp.zeros((reduce.n_cells, group), jnp.int32),
            jnp.asarray(np.where(badf, 0, out["completed"])),
            jnp.asarray(np.where(badf, 1.0,
                                 out["t_end"]).astype(np.float32)),
            jnp.asarray(reduce.cell_ids), group=group)

    if failures and failures_path:
        _write_failures(failures_path, C, failures)
    if failures:
        warnings.warn(
            f"sweep quarantined {len(failures)}/{C} configs with "
            f"non-finite summaries"
            + (f" (report: {failures_path})" if failures_path else "")
            + "; their rows kept raw values but were excluded from the "
            f"win-count reduction", stacklevel=2)

    return StreamResult(
        n_configs=C, n_steps=run_steps, backend=backend,
        dt=np.asarray(dt, np.float32), t_end=out["t_end"],
        completed=out["completed"], spin_cpu=out["spin_cpu"],
        wake_count=out["wake_count"], final_sws=out["final_sws"],
        steps_run=out["steps_run"], fairness=out["fairness"],
        chunk_size=int(chunk), n_chunks=n_chunks,
        budget_mb=float(budget_mb), bytes_per_config=bpc,
        wins=None if wins is None else np.asarray(wins),
        failures=failures, resumed_chunks=min(cursor, len(chunk_plans)),
        lat_hist=out.get("lat_hist"), arrived=out.get("arrived"),
        shed=out.get("shed"), departed=out.get("departed"),
        slo_viol=out.get("slo_viol"), lat_sum=out.get("lat_sum"),
        occ_int=out.get("occ_int"), in_flight=out.get("in_flight"))

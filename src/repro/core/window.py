"""Generic spinning-window controller (the paper's state machine, factored
out of the OS lock).

The mutable lock's essence is a bounded *active set*: at most ``sws`` agents
are kept "hot" (consuming resources, zero admission latency) while the rest
are "cold" (free, but pay a wake-up latency when promoted).  ``sws`` is
self-tuned by the EvalSWS rule.  This module exposes that state machine for
any resource with the same trade-off; in this framework it governs the
serving engine's decode-batch occupancy (DESIGN.md §3.2) and the
data-pipeline's prefetch depth.

Mapping (lock -> generic):

    spinner            -> active slot (hot)
    sleeper            -> queued item (cold)
    critical section   -> one service round (e.g. a decode step)
    wake-up latency    -> promotion latency (e.g. prefill/KV rehydration)
    slept and not spun -> a promoted item found the service idle-starved
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .oracle import EvalSWS, Oracle
from .policy import clamp_delta, wake_correction


@dataclass
class WindowStats:
    late_wakes: int = 0
    grows: int = 0
    shrinks: int = 0
    observations: int = 0
    history: list = field(default_factory=list)


class SpinningWindow:
    """Self-tuning bounded active set.

    Single-controller variant: unlike :class:`~repro.core.mutlock.MutableLock`
    there is one scheduler thread driving it, so the C1/C2 wake-up-count
    corrections reduce to immediately reporting how many cold items to
    promote (C1) or how many hot items to let drain (C2) after a resize.
    """

    def __init__(
        self,
        max_size: int,
        initial: int = 1,
        oracle: Oracle | None = None,
        min_size: int = 1,
    ):
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        self.max = max_size
        self.min = min_size          # the lock clamps to 1 (A16); a zero
        self.sws = max(min_size, min(initial, max_size))  # standby pool is a
        # valid serving ablation
        self.oracle: Oracle = oracle if oracle is not None else EvalSWS(k=10)
        self.stats = WindowStats()

    def observe(self, late_wake: bool, occupancy: int) -> int:
        """Feed one service-round observation; returns the *correction*
        (positive: promote that many cold items now — C1; negative: allow
        that many hot items to drain — C2; zero: nothing to do).

        ``late_wake``  — the round was served by a freshly-promoted item that
                         found no hot item ready ("slept and not spun").
        ``occupancy``  — hot + queued items (the lock's ``thc``).
        """
        self.stats.observations += 1
        self.stats.late_wakes += late_wake
        delta = self.oracle.eval_sws(spun=not late_wake, slept=late_wake,
                                     sws=self.sws)
        # Clamp exactly as Algorithm 1 lines A16-A17 (low bound = min_size).
        delta = clamp_delta(self.sws, delta, self.min, self.max)
        if delta == 0:
            self.stats.history.append(self.sws)
            return 0
        sws_pre, self.sws = self.sws, self.sws + delta
        self.stats.grows += delta > 0
        self.stats.shrinks += delta < 0
        self.stats.history.append(self.sws)
        # C1/C2 corrections (A23-A33): same arithmetic as the lock's wuc,
        # applied immediately since one controller drives the window.
        return wake_correction(delta, occupancy, sws_pre)

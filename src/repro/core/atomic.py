"""Atomic primitives for the mutable-lock algorithm.

The paper (§3.2) stores the lock state as a single 64-bit word
``lstate = <sws(hi 32), thc(lo 32)>`` manipulated exclusively through atomic
Fetch&Add (FAD), so that a thread updating one field atomically observes the
other.  CPython exposes no user-level FAD; :class:`AtomicU64` emulates it with
a nano-scale internal mutex.  The *semantics* (linearizable FAD on a packed
64-bit word, two's-complement wrap) are identical to the hardware
instruction; only the constant factor differs, which is documented in
DESIGN.md §3 as a changed assumption.

Packing convention (paper §3.2)::

    lstate = (sws << 32) | thc          # both unsigned 32-bit fields
    FAD(lstate, +1)        -> thc += 1
    FAD(lstate, -1)        -> thc -= 1
    FAD(lstate, delta<<32) -> sws += delta   (no carry into/out of thc by
                                              construction: thc>0 on -1, etc.)
"""

from __future__ import annotations

import threading

_MASK64 = (1 << 64) - 1
_MASK32 = (1 << 32) - 1


def pack_lstate(sws: int, thc: int) -> int:
    """Pack ``(sws, thc)`` into the 64-bit lstate word."""
    return ((sws & _MASK32) << 32) | (thc & _MASK32)


def unpack_lstate(word: int) -> tuple[int, int]:
    """Unpack the 64-bit lstate word into ``(sws, thc)``."""
    return (word >> 32) & _MASK32, word & _MASK32


def sws_delta(delta: int) -> int:
    """Encode a signed sws variation as a FAD operand (two's complement)."""
    return (delta << 32) & _MASK64


class AtomicU64:
    """A 64-bit word supporting linearizable fetch_add / load / cas.

    Emulates the x86 ``lock xadd`` / ``lock cmpxchg`` used by the paper's C
    implementation.  All mutation goes through one internal lock, so every
    operation is a single linearization point exactly like the hardware
    instruction.
    """

    __slots__ = ("_value", "_mu")

    def __init__(self, value: int = 0):
        self._value = value & _MASK64
        self._mu = threading.Lock()

    def fetch_add(self, delta: int) -> int:
        """Atomic FAD: returns the value *before* the addition (``x^-``)."""
        with self._mu:
            old = self._value
            self._value = (old + delta) & _MASK64
            return old

    def load(self) -> int:
        # A 64-bit aligned load is atomic on the target hardware; the lock
        # here only guards against torn reads of the Python int swap.
        return self._value

    def store(self, value: int) -> None:
        with self._mu:
            self._value = value & _MASK64

    def compare_and_swap(self, expected: int, new: int) -> bool:
        with self._mu:
            if self._value == expected:
                self._value = new & _MASK64
                return True
            return False


class AtomicBool:
    """Test-and-set cell for TAS/TTAS spin locks."""

    __slots__ = ("_value", "_mu")

    def __init__(self, value: bool = False):
        self._value = value
        self._mu = threading.Lock()

    def test_and_set(self) -> bool:
        """Atomically set True; return the *previous* value."""
        with self._mu:
            old = self._value
            self._value = True
            return old

    def load(self) -> bool:
        return self._value

    def clear(self) -> None:
        with self._mu:
            self._value = False

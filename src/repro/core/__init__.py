"""repro.core — the paper's contribution: Mutable Locks (Marotta et al., 2019).

Public API:

* :class:`MutableLock`     — Algorithm 1, drop-in for ``threading.Lock``.
* :class:`SpinningWindow`  — the window state machine, reusable for any
                             bounded-active-set resource (serving scheduler).
* :class:`MutableWait`     — self-tuned hybrid spin/sleep predicate wait.
* :mod:`baselines`         — TAS/TTAS/MCS/sleep/adaptive adversaries.
* :mod:`des`               — deterministic discrete-event validation of the
                             paper's multi-core claims.
"""

from . import policy
from .atomic import AtomicBool, AtomicU64, pack_lstate, sws_delta, unpack_lstate
from .baselines import LOCKS, AdaptiveMutex, MCSLock, SleepLock, TASLock, TTASLock
from .mutlock import MutableLock, MutLockStats, SemSleep, TTASSpin
from .oracle import (AIMDOracle, EvalSWS, FixedBudgetOracle, FixedOracle,
                     HistoryOracle, Oracle, make_oracle)
from .policy import (DEFAULT_ALPHA, ORACLE_IDS, POLICY_IDS, SimConfig,
                     clamp_delta, encode_configs, eval_sws_delta, latch_wuc,
                     oracle_update, release_quota, should_sleep_on_arrival,
                     wake_correction)
from .waitpolicy import MutableWait
from .window import SpinningWindow

#: Factory registry: every lock the framework can be configured with.
ALL_LOCKS = dict(LOCKS, mutable=MutableLock)


def make_lock(kind: str = "mutable", **kwargs):
    """Instantiate a lock by name (``mutable|tas|ttas|mcs|sleep|adaptive``)."""
    try:
        cls = ALL_LOCKS[kind]
    except KeyError as e:
        raise ValueError(f"unknown lock kind {kind!r}; "
                         f"options: {sorted(ALL_LOCKS)}") from e
    return cls(**kwargs)


__all__ = [
    "AtomicBool", "AtomicU64", "pack_lstate", "unpack_lstate", "sws_delta",
    "MutableLock", "MutLockStats", "SemSleep", "TTASSpin",
    "EvalSWS", "FixedOracle", "AIMDOracle", "FixedBudgetOracle",
    "HistoryOracle", "Oracle", "make_oracle",
    "SpinningWindow", "MutableWait",
    "TASLock", "TTASLock", "MCSLock", "SleepLock", "AdaptiveMutex",
    "LOCKS", "ALL_LOCKS", "make_lock",
    "policy", "SimConfig", "encode_configs",
    "POLICY_IDS", "DEFAULT_ALPHA", "ORACLE_IDS",
    "eval_sws_delta", "oracle_update", "clamp_delta", "wake_correction",
    "latch_wuc", "release_quota", "should_sleep_on_arrival",
]

"""The Mutable Lock (paper §3.2, Algorithm 1) — faithful implementation.

A mutable lock is a spin lock (``spn_obj``) plus five variables:

* ``sws``  — current spinning-window size            (hi 32 bits of lstate)
* ``thc``  — thread count: waiters + holder          (lo 32 bits of lstate)
* ``wuc``  — wake-up count for SWS-change correction (C1/C2 countermeasures)
* ``slp_obj`` — blocking object wrapping the OS sleep/wake API (semaphore)
* ``max``  — maximum SWS (defaults to the core count)

State machine (paper §3.1): a thread arriving at index ``i`` (holder at 0)

    i == 0            -> grabs the lock
    i in [1, SWS]     -> spins
    i in (SWS, +inf)  -> sleeps

On release, one spinner wins the lock and one sleeper is woken *into the
spinning window* (the sleep->spin transition) so that wake-up latency is
masked by the next critical section.

Line-number comments (A*, R*, E*) refer to Algorithm 1 in the paper.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from . import policy
from .atomic import AtomicBool, AtomicU64, pack_lstate, sws_delta, unpack_lstate
from .oracle import EvalSWS, Oracle


# --------------------------------------------------------------------------
# spn_obj: test-and-test-and-set spin lock (paper §4 uses "a classical
# test-and-test-and-set spin lock as spn_obj").
# --------------------------------------------------------------------------
class TTASSpin:
    """TTAS spin lock whose ``lock()`` reports whether the caller spun.

    ``lock() -> spun`` must be True iff at least one acquisition attempt
    failed — EvalSWS uses ``slept and not spun`` as the "late wake-up"
    predicate (the woken thread found the lock immediately free, i.e. no
    spinner was hot when the critical section ended).
    """

    def __init__(self, yield_while_spinning: bool = True):
        self._cell = AtomicBool(False)
        # On CPython a pure busy-loop holds the GIL for a full switch
        # interval; yielding keeps the emulation honest on few-core hosts.
        self._yield = yield_while_spinning

    def lock(self) -> bool:
        spun = False
        while True:
            # test ... (cache-local read, no RMW)
            while self._cell.load():
                spun = True
                if self._yield:
                    time.sleep(0)
            # ... and test-and-set
            if not self._cell.test_and_set():
                return spun
            spun = True

    def try_lock(self) -> bool:
        if self._cell.load():
            return False
        return not self._cell.test_and_set()

    def unlock(self) -> None:
        self._cell.clear()


# --------------------------------------------------------------------------
# slp_obj: semaphore-based sleep object (paper §4 uses "a semaphore as
# sleeping object").  Wake-ups are conserved: a wake_up() issued before the
# sleeper parks is absorbed by the semaphore permit, so no lost wake-ups.
# --------------------------------------------------------------------------
class SemSleep:
    def __init__(self):
        self._sem = threading.Semaphore(0)
        self.sleeps = 0
        self.wakes = 0

    def sleep(self) -> None:
        self.sleeps += 1
        self._sem.acquire()

    def wake_up(self, n: int) -> int:
        """Wake ``n`` sleepers; returns the number of wake permits issued."""
        if n <= 0:
            return 0
        self._sem.release(n)
        self.wakes += n
        return n


@dataclass
class MutLockStats:
    """Observability counters (not part of the algorithm)."""

    acquisitions: int = 0
    sleeps: int = 0
    spins: int = 0            # acquisitions that observed contention
    late_wakeups: int = 0     # slept and not spun
    sws_samples: list = field(default_factory=list)


class MutableLock:
    """Paper Algorithm 1.  API mirrors ``threading.Lock`` plus stats.

    ``wuc`` and the oracle state are only touched while holding ``spn_obj``
    (ACQUIRE lines A12-A33 run after A11; RELEASE lines R2-R8 run before
    R10), exactly as in the paper — so they are plain fields.
    """

    def __init__(
        self,
        max_sws: int | None = None,
        initial_sws: int = 1,
        oracle: Oracle | None = None,
        record_stats: bool = False,
    ):
        import os

        self.max = max_sws if max_sws is not None else (os.cpu_count() or 1)
        if not (1 <= initial_sws <= self.max):
            initial_sws = max(1, min(initial_sws, self.max))
        self.lstate = AtomicU64(pack_lstate(initial_sws, 0))
        self.wuc = 0
        self.spn_obj = TTASSpin()
        self.slp_obj = SemSleep()
        self.oracle: Oracle = oracle if oracle is not None else EvalSWS(k=10)
        self.stats = MutLockStats() if record_stats else None
        self._holder: int | None = None  # debug: thread ident of the holder

    # -- introspection ----------------------------------------------------
    @property
    def sws(self) -> int:
        return unpack_lstate(self.lstate.load())[0]

    @property
    def thc(self) -> int:
        return unpack_lstate(self.lstate.load())[1]

    # -- Algorithm 1: ACQUIRE ---------------------------------------------
    def acquire(self) -> None:
        slept = False                                    # A3
        lstate_pre = self.lstate.fetch_add(1)            # A4: thc += 1
        sws, thc_pre = unpack_lstate(lstate_pre)         # A5-A6
        if thc_pre >= sws:                               # A7: no room in SW
            slept = True                                 # A8
            self.slp_obj.sleep()                         # A9: park
        spun = self.spn_obj.lock()                       # A11: spin phase
        self._holder = threading.get_ident()

        if self.stats is not None:
            self.stats.acquisitions += 1
            self.stats.sleeps += slept
            self.stats.spins += spun
            self.stats.late_wakeups += slept and not spun
            self.stats.sws_samples.append(sws)

        delta = self.oracle.eval_sws(spun, slept, sws)   # A12
        if sws != unpack_lstate(self.lstate.load())[0]:  # A13: sws changed
            return                                       # A14: concurrently
        delta = policy.clamp_delta(sws, delta, 1, self.max)  # A16-A17
        if delta != 0:                                   # A18
            lstate_pre = self.lstate.fetch_add(sws_delta(delta))  # A19-A20
            sws_pre, thc = unpack_lstate(lstate_pre)     # A21-A22
            # A23-A33: C1/C2 correction from the shared policy core.
            self.wuc += policy.wake_correction(delta, thc, sws_pre)

    # -- Algorithm 1: RELEASE ---------------------------------------------
    def release(self) -> None:
        if self._holder != threading.get_ident():
            raise RuntimeError("release() by non-holder thread")
        self._holder = None
        r_wuc, self.wuc = policy.latch_wuc(self.wuc)     # R2-R7
        lstate_pre = self.lstate.fetch_add(-1)           # R9: thc -= 1
        self.spn_obj.unlock()                            # R10
        sws, thc_pre = unpack_lstate(lstate_pre)         # R14-R15
        r_wuc = policy.release_quota(r_wuc, thc_pre, sws)  # R11-R17
        while r_wuc > 0:                                 # R19
            cnt = self.slp_obj.wake_up(r_wuc)            # R20
            r_wuc -= cnt                                 # R21

    # -- context-manager / drop-in threading.Lock API ----------------------
    def __enter__(self) -> "MutableLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._holder is not None

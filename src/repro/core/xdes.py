"""xdes — batched, fixed-timestep simulation of lock disciplines on JAX.

The event-driven DES (:mod:`repro.core.des`) is exact but interpreter-bound:
one Python loop per ``(lock, threads, cores, cs, ncs)`` cell, so the Fig. 3
grid (5 locks x 8 thread counts x 4 regimes x seeds) runs sequentially for
minutes.  This module simulates *thousands of configurations in one device
program*: a generalized-processor-sharing step on a fixed timestep, rolled
out with ``lax.scan`` and batched over configurations with ``vmap``.  The
hot per-step update (runnable counts, GPS rate, the paper §2 cache-
contention slowdown ``1/(1 + alpha*n_spinners)``, work advance, spin-CPU
burn) is a swappable backend: the pure-XLA reference
(:func:`repro.kernels.ref.lock_sim_step_ref`) or the fused Pallas kernel
(:func:`repro.kernels.lock_sim.lock_sim_step`).

Model fidelity: same state machine, same policy decisions (shared pure
functions in :mod:`repro.core.policy` — A7 arrival rule, the four SWS
adaptation oracle families (paper EvalSWS / AIMD / fixed-budget / history,
dispatched per config by the ``oracle`` column, see ``docs/oracles.md``),
A16-A17 clamps, C1/C2 corrections, R2-R21 release quotas, banked wake
permits), same metrics (throughput, spin-CPU per CS, wake count).  The differences
are (a) time is quantized to ``dt`` instead of exact event times, and
(b) simultaneous events inside one step resolve in thread-id order instead
of RNG order.  Equivalence tests pin xdes against the Python DES on the
paper's four regimes (qualitative claims C2-C4).

Threads are array slots: state ``(configs, max_threads)`` int32 plus small
per-config integers (sws, cnt, wuc, permits) — exactly the array-encodable
policy state :mod:`repro.core.policy` defines.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from . import policy as P

#: Residual work (CPU-seconds) under which a CS/NCS counts as finished.
REM_EPS = 1e-9
#: Hard cap on scan length (compile + runtime guard).
MAX_STEPS = 200_000
_INF = np.float32(np.inf)


# --------------------------------------------------------------------------
# Counter-based RNG: durations are drawn per (config, thread, event) from a
# splitmix-style avalanche, so the whole rollout is deterministic and
# needs no threaded PRNG state through scan.
# --------------------------------------------------------------------------
def _uniform(seed, tid, ctr):
    x = seed ^ (tid.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)) \
        ^ ((ctr + jnp.uint32(1)) * jnp.uint32(0x85EBCA6B))
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x.astype(jnp.float32) * jnp.float32(2.0 ** -32)


# --------------------------------------------------------------------------
# Per-config transition logic (vmapped over configs).  Shapes: (T,) arrays
# and scalars; every branch is a `where` so the whole step is one fused
# device program.
# --------------------------------------------------------------------------
def _transitions(st, rem, wake_at, slept, spun, ctr,
                 sws, cnt, ewma, wuc, permits, completed, wake_count,
                 now2, prm):
    T = st.shape[0]
    tid = jnp.arange(T, dtype=jnp.int32)
    active = tid < prm["threads"]
    p = prm["policy"]
    is_mut = p == P.MUTABLE
    is_slp = p == P.SLEEP
    is_adp = p == P.ADAPTIVE
    teps = prm["dt"] * jnp.float32(1e-3)

    def first_oh(mask):
        """One-hot of the lowest-tid True (all-False rows stay all-False)."""
        return (tid == jnp.argmax(mask)) & mask.any()

    def thc_of(s):
        """Algorithm 1's thc: holder + every waiter (CS/SPIN/SLEEP/WAKING)."""
        return jnp.sum((active & (s >= P.CS) & (s <= P.WAKING))
                       .astype(jnp.int32))

    def draw_into(mask, lo, hi, c):
        val = lo + _uniform(prm["seed"], tid, c) * (hi - lo)
        return val, jnp.where(mask, c + jnp.uint32(1), c)

    def park(mask, st, wake_at, permits, wake_count, slept, rem):
        """DES ``_sleep``: park, absorbing banked permits (semaphore law —
        an absorbed permit still pays the park/unpark round trip)."""
        rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
        grant = mask & (rank < permits)
        n_grant = jnp.sum(grant.astype(jnp.int32))
        st = jnp.where(grant, P.WAKING,
                       jnp.where(mask, P.SLEEP_ST, st))
        wake_at = jnp.where(grant, now2 + prm["wake"], wake_at)
        return (st, wake_at, permits - n_grant, wake_count + n_grant,
                slept | mask, jnp.where(mask, _INF, rem))

    def oracle_acquire(happened, winner_oh, thc, sws, cnt, ewma, wuc):
        """A12-A33 at an acquisition: oracle family dispatch (EvalSWS /
        AIMD / fixed-budget / history, selected by the per-config
        ``oracle`` id), clamp, C1/C2 correction — the array form of the
        scalar functions in repro.core.policy."""
        do = happened & is_mut
        spun_w = (spun & winner_oh).any()
        slept_w = (slept & winner_oh).any()
        delta, cnt2, ewma2 = P.oracle_update(                 # E2-E11
            prm["oracle"], spun_w, slept_w, sws, cnt, ewma, prm["k"])
        delta = jnp.clip(delta, 1 - sws, prm["sws_max"] - sws)  # A16-A17
        sws2 = sws + delta                                    # A20
        tmp = jnp.where((delta < 0) & (thc > sws2), thc - sws2,       # C2
                        jnp.where((delta > 0) & (thc > sws), thc - sws,
                                  0))                                 # C1
        corr = jnp.sign(delta) * jnp.minimum(jnp.abs(delta), tmp)  # A32
        return (jnp.where(do, sws2, sws), jnp.where(do, cnt2, cnt),
                jnp.where(do, ewma2, ewma), jnp.where(do, wuc + corr, wuc))

    # -- adaptive spin-budget exhaustion -> sleep (DES stage order) --------
    exhausted = (st == P.SPIN) & is_adp & (rem <= REM_EPS)
    st, wake_at, permits, wake_count, slept, rem = park(
        exhausted, st, wake_at, permits, wake_count, slept, rem)

    # -- wake completions --------------------------------------------------
    due = (st == P.WAKING) & (wake_at <= now2 + teps)
    holder_free = ~(st == P.CS).any()
    winA = first_oh(due) & holder_free
    cs_val, ctr = draw_into(winA, prm["cs_lo"], prm["cs_hi"], ctr)
    rem = jnp.where(winA, cs_val, rem)
    st = jnp.where(winA, P.CS, st)
    # the sleep->spin transition's payoff: a woken thread that finds the
    # lock free acquired "slept and not spun" -> EvalSWS doubles the window
    sws, cnt, ewma, wuc = oracle_acquire(winA.any(), winA, thc_of(st),
                                         sws, cnt, ewma, wuc)
    losers = due & ~winA
    to_spin = losers & is_mut          # woken into the spinning window
    st = jnp.where(to_spin, P.SPIN, st)
    spun = spun | to_spin
    rem = jnp.where(to_spin, _INF, rem)
    to_park = losers & (is_slp | is_adp)   # barged: park again
    st, wake_at, permits, wake_count, slept, rem = park(
        to_park, st, wake_at, permits, wake_count, slept, rem)

    # -- CS completion / release ------------------------------------------
    holder_done = (st == P.CS) & (rem <= REM_EPS)
    rel = holder_done.any()
    completed = completed + rel.astype(jnp.int32)
    thc_pre = thc_of(st)                                   # R14 (pre-FAD)
    do_latch = rel & is_mut
    r_wuc = jnp.where(do_latch & (wuc >= 0), wuc, -1)      # R2-R6
    wuc = jnp.where(do_latch, jnp.where(wuc >= 0, 0, wuc + 1), wuc)  # R4/R7
    ncs_val, ctr = draw_into(holder_done, prm["ncs_lo"], prm["ncs_hi"], ctr)
    rem = jnp.where(holder_done, ncs_val, rem)
    st = jnp.where(holder_done, P.NCS, st)                 # R9-R10
    # spn handoff: lowest-tid spinner wins (DES picks at random)
    spinners = st == P.SPIN
    can_handoff = rel & ~is_slp & spinners.any()
    winB = first_oh(spinners) & can_handoff
    cs_valB, ctr = draw_into(winB, prm["cs_lo"], prm["cs_hi"], ctr)
    rem = jnp.where(winB, cs_valB, rem)
    st = jnp.where(winB, P.CS, st)
    sws, cnt, ewma, wuc = oracle_acquire(can_handoff, winB, thc_pre - 1,
                                         sws, cnt, ewma, wuc)
    # wake quota: mutable R11-R21; sleep/adaptive wake one when anyone is
    # parked (DES `sleepers() or any_waking()`), adaptive only if no
    # spinner took the handoff
    n_parked = jnp.sum(((st == P.SLEEP_ST) | (st == P.WAKING))
                       .astype(jnp.int32))
    quota_mut = jnp.where(r_wuc < 0, 0,
                          r_wuc + (thc_pre > sws).astype(jnp.int32))
    quota_one = (n_parked > 0).astype(jnp.int32)
    quota = jnp.where(is_mut, quota_mut,
                      jnp.where(is_slp | (is_adp & ~can_handoff),
                                quota_one, 0))
    quota = jnp.where(rel, quota, 0)
    sleepers = st == P.SLEEP_ST
    rank_s = jnp.cumsum(sleepers.astype(jnp.int32)) - 1
    sel = sleepers & (rank_s < quota)
    n_sel = jnp.sum(sel.astype(jnp.int32))
    st = jnp.where(sel, P.WAKING, st)
    wake_at = jnp.where(sel, now2 + prm["wake"], wake_at)
    wake_count = wake_count + n_sel
    permits = permits + (quota - n_sel)    # park-free permits are banked

    # -- arrivals (NCS finished) ------------------------------------------
    arr = (st == P.NCS) & (rem <= REM_EPS) & active
    thc_base = thc_of(st)
    rank_a = jnp.cumsum(arr.astype(jnp.int32)) - 1
    thc_pre_i = thc_base + rank_a                          # A4 per arrival
    slept = jnp.where(arr, False, slept)                   # A3
    spun = jnp.where(arr, False, spun)
    holder_free2 = ~(st == P.CS).any()
    # A7 for window disciplines; the pure sleep lock barges when free
    sleeps = arr & jnp.where(is_slp, ~((rank_a == 0) & holder_free2),
                             thc_pre_i >= sws)
    nonsleep = arr & ~sleeps
    winC = first_oh(nonsleep) & holder_free2
    cs_valC, ctr = draw_into(winC, prm["cs_lo"], prm["cs_hi"], ctr)
    rem = jnp.where(winC, cs_valC, rem)
    st = jnp.where(winC, P.CS, st)
    sws, cnt, ewma, wuc = oracle_acquire(winC.any(), winC, thc_base + 1,
                                         sws, cnt, ewma, wuc)
    to_spinC = nonsleep & ~winC
    st = jnp.where(to_spinC, P.SPIN, st)
    spun = spun | to_spinC
    rem = jnp.where(to_spinC,
                    jnp.where(is_adp, prm["spin_budget"], _INF), rem)
    st, wake_at, permits, wake_count, slept, rem = park(
        sleeps, st, wake_at, permits, wake_count, slept, rem)

    return (st, rem, wake_at, slept, spun, ctr,
            sws, cnt, ewma, wuc, permits, completed, wake_count)


_vtransitions = jax.vmap(
    _transitions,
    in_axes=((0,) * 13) + (0, {k: 0 for k in (
        "policy", "threads", "dt", "wake", "cs_lo", "cs_hi", "ncs_lo",
        "ncs_hi", "k", "sws_max", "spin_budget", "seed", "oracle")},))


# --------------------------------------------------------------------------
# The rollout: lax.scan over steps, vmap over configs
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_steps", "T", "backend"))
def _simulate(arrs, n_steps: int, T: int, backend: str = "ref"):
    C = arrs["policy"].shape[0]
    tid = jnp.arange(T, dtype=jnp.int32)[None, :]
    active = tid < arrs["threads"][:, None]
    has_budget = arrs["policy"] == P.ADAPTIVE
    prm = {k: arrs[k] for k in (
        "policy", "threads", "dt", "wake", "cs_lo", "cs_hi", "ncs_lo",
        "ncs_hi", "k", "sws_max", "spin_budget", "seed", "oracle")}

    if backend == "ref":
        from repro.kernels.ref import lock_sim_step_ref as step1
        advance = lambda st, rem: step1(st, rem, arrs["alpha"],
                                        arrs["cores"], arrs["dt"],
                                        has_budget)
    elif backend == "pallas":
        from repro.kernels.lock_sim import lock_sim_step
        advance = lambda st, rem: lock_sim_step(st, rem, arrs["alpha"],
                                                arrs["cores"], arrs["dt"],
                                                has_budget)
    else:
        raise ValueError(f"unknown backend {backend!r} (ref|pallas)")

    # initial state: every thread in NCS with a fresh draw
    ctr0 = jnp.zeros((C, T), jnp.uint32)
    u0 = _uniform(arrs["seed"][:, None], jnp.broadcast_to(tid, (C, T)), ctr0)
    rem0 = arrs["ncs_lo"][:, None] + u0 * (arrs["ncs_hi"]
                                           - arrs["ncs_lo"])[:, None]
    state0 = (
        jnp.where(active, P.NCS, P.DONE).astype(jnp.int32),   # st
        jnp.where(active, rem0, _INF),                        # rem
        jnp.full((C, T), _INF),                               # wake_at
        jnp.zeros((C, T), bool),                              # slept
        jnp.zeros((C, T), bool),                              # spun
        ctr0 + 1,                                             # ctr
        arrs["sws_init"].astype(jnp.int32),                   # sws
        jnp.zeros((C,), jnp.int32),                           # cnt
        jnp.zeros((C,), jnp.int32),                           # ewma
        jnp.zeros((C,), jnp.int32),                           # wuc
        jnp.zeros((C,), jnp.int32),                           # permits
        jnp.zeros((C,), jnp.int32),                           # completed
        jnp.zeros((C,), jnp.int32),                           # wake_count
        jnp.zeros((C,), jnp.float32),                         # spin_cpu
    )

    def body(carry, i):
        (st, rem, wake_at, slept, spun, ctr, sws, cnt, ewma, wuc, permits,
         completed, wake_count, spin_cpu) = carry
        now2 = (i.astype(jnp.float32) + 1.0) * arrs["dt"]
        rem, burn = advance(st, rem)
        spin_cpu = spin_cpu + burn
        (st, rem, wake_at, slept, spun, ctr, sws, cnt, ewma, wuc, permits,
         completed, wake_count) = _vtransitions(
            st, rem, wake_at, slept, spun, ctr, sws, cnt, ewma, wuc,
            permits, completed, wake_count, now2, prm)
        return (st, rem, wake_at, slept, spun, ctr, sws, cnt, ewma, wuc,
                permits, completed, wake_count, spin_cpu), None

    final, _ = jax.lax.scan(body, state0, jnp.arange(n_steps))
    (st, rem, wake_at, slept, spun, ctr, sws, cnt, ewma, wuc, permits,
     completed, wake_count, spin_cpu) = final
    return {
        "completed": completed,
        "spin_cpu": spin_cpu,
        "wake_count": wake_count,
        "final_sws": sws,
        "t_end": n_steps * arrs["dt"],
    }


# --------------------------------------------------------------------------
# Scheduling heuristics + public API
# --------------------------------------------------------------------------
def plan_schedule(configs, target_cs: int = 300):
    """Pick per-config ``dt`` and a shared step count.

    ``dt`` resolves the fastest load-bearing timescale (CS length and wake
    latency — NCS shorter than the CS only shifts arrivals within a step);
    the step count covers ~``target_cs`` critical sections for the slowest
    configuration, so every cell completes at least that many.  The count
    is unclamped — :func:`simulate_batch` caps it at :data:`MAX_STEPS`
    (with a warning, since capped cells under-sample ``target_cs``).
    """
    dts, steps = [], []
    for c in configs:
        cs_m = (c.cs[0] + c.cs[1]) / 2.0
        ncs_m = (c.ncs[0] + c.ncs[1]) / 2.0
        dt = min(max(cs_m, 1e-8), max(c.wake_latency, 1e-8)) / 6.0
        per_cs = (max(cs_m, (cs_m + ncs_m) / min(c.threads, c.cores)) * 1.35
                  + 0.25 * c.wake_latency + 2.0 * dt)
        dts.append(dt)
        steps.append(int(np.ceil(target_cs * per_cs / dt)))
    return np.asarray(dts, np.float32), max(steps)


@dataclass
class BatchResult:
    """Struct-of-arrays results for one batched run (numpy, length C)."""

    configs: list
    n_steps: int
    backend: str
    dt: np.ndarray
    t_end: np.ndarray
    completed: np.ndarray
    spin_cpu: np.ndarray
    wake_count: np.ndarray
    final_sws: np.ndarray

    @property
    def throughput(self) -> np.ndarray:
        return self.completed / np.maximum(self.t_end, 1e-30)

    @property
    def sync_cpu_per_cs(self) -> np.ndarray:
        return self.spin_cpu / np.maximum(self.completed, 1)

    def row(self, i: int) -> dict:
        return {
            "config": self.configs[i],
            "completed_cs": int(self.completed[i]),
            "throughput": float(self.throughput[i]),
            "sync_cpu_per_cs": float(self.sync_cpu_per_cs[i]),
            "wake_count": int(self.wake_count[i]),
            "final_sws": int(self.final_sws[i]),
            "t_end": float(self.t_end[i]),
        }


def simulate_batch(configs, *, target_cs: int = 300, n_steps: int | None = None,
                   dt=None, backend: str = "ref",
                   max_threads: int | None = None) -> BatchResult:
    """Simulate every :class:`repro.core.policy.SimConfig` in ``configs``
    in ONE jit-compiled device call.

    All configurations share the scan length; each carries its own ``dt``,
    so heterogeneous regimes (µs spin cells next to 100µs-CS cells) batch
    together without resolution loss.  ``backend="pallas"`` routes the
    per-step GPS update through :mod:`repro.kernels.lock_sim`.
    """
    configs = list(configs)
    arrs = P.encode_configs(configs)
    auto_dt, auto_steps = plan_schedule(configs, target_cs)
    if dt is None:
        dt = auto_dt
    else:
        dt = np.broadcast_to(np.asarray(dt, np.float32),
                             arrs["policy"].shape).copy()
    if n_steps is None:
        if auto_steps > MAX_STEPS:
            import warnings

            warnings.warn(
                f"auto step count {auto_steps} capped at {MAX_STEPS}: the "
                f"slowest configs will complete fewer than target_cs="
                f"{target_cs} critical sections", stacklevel=2)
        n_steps = min(auto_steps, MAX_STEPS)
    if n_steps > MAX_STEPS:
        raise ValueError(f"n_steps={n_steps} exceeds MAX_STEPS={MAX_STEPS}")
    arrs["dt"] = dt
    T = max_threads or int(arrs["threads"].max())
    if T < int(arrs["threads"].max()):
        raise ValueError("max_threads smaller than widest config")
    out = _simulate(arrs, n_steps=int(n_steps), T=int(T), backend=backend)
    out = {k: np.asarray(v) for k, v in out.items()}
    return BatchResult(configs=configs, n_steps=int(n_steps), backend=backend,
                       dt=dt, t_end=out["t_end"], completed=out["completed"],
                       spin_cpu=out["spin_cpu"],
                       wake_count=out["wake_count"],
                       final_sws=out["final_sws"])

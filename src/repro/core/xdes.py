"""xdes — batched, fixed-timestep simulation of lock disciplines on JAX.

The event-driven DES (:mod:`repro.core.des`) is exact but interpreter-bound:
one Python loop per ``(lock, threads, cores, cs, ncs)`` cell, so the Fig. 3
grid (5 locks x 8 thread counts x 4 regimes x seeds) runs sequentially for
minutes.  This module simulates *thousands of configurations in one device
program*: a generalized-processor-sharing step on a fixed timestep.

The rollout is **time-blocked** (``rollout="blocked"``, the default): a
chunked ``lax.while_loop`` whose body is ONE fused kernel dispatch per
``block_steps`` timesteps — GPS advance + oracle update + transitions
iterated with the whole (C, T) state block resident in VMEM/registers
(:func:`repro.kernels.ref.lock_sim_block_ref` on the XLA backend, the
bit-identical Pallas twin :func:`repro.kernels.lock_sim.lock_sim_block` on
``backend="pallas"``), so the outer loop shrinks from ``n_steps``
dispatches to ``n_steps / block_steps``.  The loop carries a per-config
``done = completed >= target_cs`` mask and **exits early** as soon as
every config has converged (``early_exit``, on by default for
auto-planned horizons; the executed step count is reported as
``BatchResult.steps_run``).  ``rollout="scan"`` keeps the legacy
two-dispatches-per-step ``lax.scan`` — the parity reference the blocked
path is pinned bit-identical against.  Both per-step stages remain
swappable kernel backends in the scan path:

* GPS advance — :func:`repro.kernels.ref.lock_sim_step_ref` (XLA) or the
  fused Pallas kernel :func:`repro.kernels.lock_sim.lock_sim_step`;
* transitions — :func:`repro.kernels.ref.lock_transitions_ref` (XLA) or
  :func:`repro.kernels.lock_sim.lock_transitions_step` (Pallas grid over
  config blocks).

Model fidelity: same state machine, same policy decisions — every waiting
discipline is a row in :data:`repro.core.policy.DISCIPLINE_ROWS` (spin,
sleep, adaptive, mutable, FIFO/MCS ticket handoff), every SWS oracle a
row in ``ORACLE_ROWS``, and every hold-time model a row in
``WORKLOAD_ROWS`` (constant, bursty ON/OFF, heterogeneous per-thread
scales, Poisson-like jittered arrivals — docs/workloads.md), all
dispatched per config by integer columns, so one batch mixes disciplines,
oracle families and workloads freely.  The row-registry contract: a new
row is pure elementwise arithmetic in :mod:`repro.core.policy`, lands in
the kernels once via :mod:`repro.kernels.ref` (the Pallas twin applies
the same body per block — ref/Pallas bit-identity is by construction and
by test), gets an event-driven twin in :mod:`repro.core.des` pinned by
randomized parity tests, and must preserve the blocked-rollout
invariants (``now2 = (step0+s+1)*dt`` in int32 index arithmetic,
``spin_cpu`` accumulated in-loop) so blocked == per-step stays exact.
The differences from the DES are (a) time is quantized to ``dt`` instead
of exact event times, and (b) simultaneous events inside one step resolve
in thread-id order instead of RNG order — reducible via the seeded
per-thread arrival-phase randomization (``SimConfig.arrival_phase``).
The quantization-error band is measured by the dt-convergence study
(``benchmarks/fidelity_study.py``; docs/performance.md "Fidelity").
Equivalence tests pin xdes against the Python DES on the paper's four
regimes (qualitative claims C2-C4) and per-row.

Threads are array slots: state ``(configs, max_threads)`` int32 plus small
per-config integers (sws, cnt, wuc, permits, next-ticket) — exactly the
array-encodable policy state :mod:`repro.core.policy` defines.

Scale: :func:`simulate_batch` shards the batch over every visible device
with ``shard_map`` (config axis, manual mapping; the only collective is a
one-int ``psum`` per block agreeing on early exit) when more than one
device is attached — 10-100k-config sweeps split across a host's
accelerators with no change to the calling code.  ``bucket_steps=True``
additionally groups heterogeneous configs by planned step count
(power-of-two buckets of :func:`plan_schedule`'s per-config estimate), so
a 100µs-CS cell no longer pins a µs-spin cell to its scan length.  See
docs/performance.md for the block-size/early-exit/bucketing trade-offs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ref import (NO_TICKET, REM_EPS,  # noqa: F401
                               counter_uniform, fault_rewind,
                               workload_init_rem)

from . import policy as P

#: Hard cap on scan length (compile + runtime guard).
MAX_STEPS = 200_000
#: Default timesteps fused into one kernel dispatch by the blocked rollout.
DEFAULT_BLOCK_STEPS = 32
_INF = np.float32(np.inf)

#: Context columns threaded to the transition kernels each step
#: (TRANSITION_CONTEXT minus the per-step ``now2``/``stepi``, same order).
_PRM_FIELDS = ("policy", "threads", "dt", "wake", "cs_lo", "cs_hi",
               "ncs_lo", "ncs_hi", "k", "sws_max", "spin_budget", "seed",
               "oracle", "workload", "wl_period", "wl_duty", "wl_burst",
               "wl_spread", "arrival", "arr_rate", "q_cap", "slo", "tb",
               "fault", "flt_rate", "flt_scale", "park_cost")


# --------------------------------------------------------------------------
# The rollout.  Default: a chunked lax.while_loop whose body is ONE fused
# kernel dispatch per block of timesteps (with target_cs early exit);
# legacy: lax.scan over steps, two kernel dispatches per step.  Both sit
# behind the swappable ref/pallas kernel boundary and are bit-identical.
# --------------------------------------------------------------------------
def _step_backends(backend: str):
    if backend == "ref":
        from repro.kernels.ref import lock_sim_step_ref, lock_transitions_ref
        return lock_sim_step_ref, lock_transitions_ref
    if backend == "pallas":
        from repro.kernels.lock_sim import lock_sim_step, lock_transitions_step
        return lock_sim_step, lock_transitions_step
    raise ValueError(f"unknown backend {backend!r} (ref|pallas)")


def _block_backend(backend: str):
    if backend == "ref":
        from repro.kernels.ref import lock_sim_block_ref
        return lock_sim_block_ref
    if backend == "pallas":
        from repro.kernels.lock_sim import lock_sim_block
        return lock_sim_block
    raise ValueError(f"unknown backend {backend!r} (ref|pallas)")


def _init_state(arrs, T: int, open_loop: bool = False):
    """The 17-array carry (16 transition-state arrays + spin_cpu): every
    thread starts in NCS with a fresh workload-row duration draw plus the
    seeded arrival-order phase offset (:func:`repro.kernels.ref.
    workload_init_rem`).

    With ``open_loop=True`` the 11 OPEN_STATE arrays are appended and
    threads of open-arrival configs (``arrival != closed``) start DONE
    with no request bound (``req_t = -1``) — the population is empty
    until requests arrive.  Closed configs in the same batch are
    untouched (their threads circulate from step 0 exactly as in the
    closed-loop engine)."""
    C = arrs["policy"].shape[0]
    tid = jnp.arange(T, dtype=jnp.int32)[None, :]
    active = tid < arrs["threads"][:, None]
    ctr0 = jnp.zeros((C, T), jnp.uint32)
    col = lambda k: arrs[k][:, None]
    rem0 = workload_init_rem(
        col("seed"), jnp.broadcast_to(tid, (C, T)), ctr0,
        col("ncs_lo"), col("ncs_hi"), col("workload"), col("wl_period"),
        col("wl_duty"), col("wl_burst"), col("wl_spread"),
        col("arrival_phase"))
    circulate = active
    if open_loop:
        circulate = active & (col("arrival") == P.AR_CLOSED)
    state = (
        jnp.where(circulate, P.NCS, P.DONE).astype(jnp.int32),  # st
        jnp.where(circulate, rem0, _INF),                     # rem
        jnp.full((C, T), _INF),                               # wake_at
        jnp.zeros((C, T), jnp.int32),                         # slept
        jnp.zeros((C, T), jnp.int32),                         # spun
        ctr0 + 1,                                             # ctr
        jnp.full((C, T), NO_TICKET, jnp.int32),               # ticket
        jnp.zeros((C, T), jnp.int32),                         # completed_pt
        arrs["sws_init"].astype(jnp.int32),                   # sws
        jnp.zeros((C,), jnp.int32),                           # cnt
        jnp.zeros((C,), jnp.int32),                           # ewma
        jnp.zeros((C,), jnp.int32),                           # wuc
        jnp.zeros((C,), jnp.int32),                           # permits
        jnp.zeros((C,), jnp.int32),                           # nticket
        jnp.zeros((C,), jnp.int32),                           # completed
        jnp.zeros((C,), jnp.int32),                           # wake_count
        jnp.zeros((C,), jnp.float32),                         # spin_cpu
    )
    if not open_loop:
        return state
    return state + (
        jnp.full((C, T), -1.0, jnp.float32),                  # req_t
        jnp.zeros((C, P.QUEUE_MAX), jnp.float32),             # qbuf
        jnp.zeros((C, P.LAT_NBINS), jnp.int32),               # hist
        jnp.zeros((C,), jnp.int32),                           # qhead
        jnp.zeros((C,), jnp.int32),                           # qlen
        jnp.zeros((C,), jnp.int32),                           # arrived
        jnp.zeros((C,), jnp.int32),                           # shed
        jnp.zeros((C,), jnp.int32),                           # departed
        jnp.zeros((C,), jnp.int32),                           # slo_viol
        jnp.zeros((C,), jnp.float32),                         # lat_sum
        jnp.zeros((C,), jnp.float32),                         # occ_int
    )


def _out_dict(state, executed, arrs, keep_per_thread: bool = True):
    (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt,
     sws, cnt, ewma, wuc, permits, nticket, completed, wake_count,
     spin_cpu) = state[:17]
    executed = jnp.asarray(executed, jnp.int32)
    out = {
        "completed": completed,
        "spin_cpu": spin_cpu,
        "wake_count": wake_count,
        "final_sws": sws,
        "t_end": executed.astype(jnp.float32) * arrs["dt"],
        "steps_run": jnp.broadcast_to(executed, completed.shape),
    }
    if len(state) > 17:          # open-loop run: the 11 OPEN_STATE arrays
        (req_t, qbuf, hist, qhead, qlen, arrived, shed, departed,
         slo_viol, lat_sum, occ_int) = state[17:]
        T = req_t.shape[1]
        tid = jnp.arange(T, dtype=jnp.int32)[None, :]
        act = tid < arrs["threads"][:, None]
        busy = jnp.sum((act & (req_t >= 0.0)).astype(jnp.int32), axis=-1)
        out.update(lat_hist=hist, arrived=arrived, shed=shed,
                   departed=departed, slo_viol=slo_viol, lat_sum=lat_sum,
                   occ_int=occ_int, in_flight=qlen + busy)
    if keep_per_thread:
        out["completed_per_thread"] = completed_pt
    else:
        # fairness on device: max-min completed-CS spread over the active
        # thread slots — the (C, T) array never reaches the host.
        T = completed_pt.shape[1]
        tid = jnp.arange(T, dtype=jnp.int32)[None, :]
        act = tid < arrs["threads"][:, None]
        big = jnp.int32(2**31 - 1)
        mx = jnp.max(jnp.where(act, completed_pt, -big), axis=-1)
        mn = jnp.min(jnp.where(act, completed_pt, big), axis=-1)
        out["fairness"] = mx - mn
    return out


def _simulate_core(arrs, n_steps, T: int, backend: str = "ref",
                   rollout: str = "blocked",
                   block_steps: int = DEFAULT_BLOCK_STEPS,
                   target_cs=0, shard_axis: str | None = None,
                   early_exit: bool | None = None,
                   keep_per_thread: bool = True,
                   open_loop: bool = False):
    """One device program simulating ``n_steps`` timesteps of every config.

    ``rollout="blocked"``: chunked ``lax.while_loop``, one fused kernel
    dispatch (:func:`_block_backend`) per ``block_steps`` timesteps.  Both
    ``n_steps`` and ``target_cs`` may be traced int32 scalars here: the
    loop runs ``ceil(n_steps / block_steps)`` blocks with the kernels'
    step-``limit`` mask turning the tail block's overshoot sub-steps into
    exact passthroughs, so one compiled executable serves every horizon
    at a given padded shape.  When early exit is on the loop stops at the
    first block boundary where every config has completed ``target_cs``
    critical sections (under ``shard_axis`` the decision is agreed across
    devices with a one-int ``psum``, keeping sharded results
    bit-identical).  ``early_exit=None`` infers the flag from a static
    ``target_cs`` (on iff > 0); pass it explicitly when ``target_cs`` is
    traced.  ``rollout="scan"``: the legacy per-step ``lax.scan`` (two
    kernel dispatches per step, static ``n_steps``, no early exit) — the
    parity reference.
    """
    C = arrs["policy"].shape[0]
    budget_f = P.discipline_flags(arrs["policy"])[2]
    has_budget = budget_f > 0
    state0 = _init_state(arrs, T, open_loop)
    prm = tuple(arrs[f] for f in _PRM_FIELDS)
    if early_exit is None:
        early_exit = isinstance(target_cs, int) and target_cs > 0

    if rollout == "scan":
        advance, transitions = _step_backends(backend)

        def body(carry, i):
            state, spin_cpu = carry[:16], carry[16]
            ostate = carry[17:] if open_loop else None
            st, rem = state[0], state[1]
            now2 = (i.astype(jnp.float32) + 1.0) * arrs["dt"]
            rem, burn = advance(st, rem, arrs["alpha"], arrs["cores"],
                                arrs["dt"], has_budget)
            rem = fault_rewind(st, rem, arrs["alpha"], arrs["cores"],
                               arrs["dt"], i.astype(jnp.float32) * arrs["dt"],
                               arrs["seed"], arrs["fault"],
                               arrs["flt_rate"], arrs["flt_scale"])
            out = transitions(st, rem, *state[2:], now2, i, *prm,
                              open_state=ostate)
            new, onew = out[:16], out[16:]
            return (*new, spin_cpu + burn, *onew), None

        final, _ = jax.lax.scan(body, state0, jnp.arange(int(n_steps)))
        return _out_dict(final, int(n_steps), arrs, keep_per_thread)

    if rollout != "blocked":
        raise ValueError(f"unknown rollout {rollout!r} (blocked|scan)")

    block = _block_backend(backend)
    B = max(1, int(block_steps))
    limit = jnp.asarray(n_steps, jnp.int32)
    n_blocks = (limit + (B - 1)) // B
    tc = jnp.asarray(target_cs, jnp.int32)

    def run_block(state, step0):
        ostate = tuple(state[17:]) if open_loop else None
        return block(*state[:17], jnp.asarray(step0, jnp.int32),
                     arrs["alpha"], arrs["cores"], has_budget, *prm,
                     n_sub_steps=B, limit=limit, open_state=ostate)

    def all_done(completed):
        if not early_exit:
            return jnp.bool_(False)
        done = jnp.all(completed >= tc)
        if shard_axis is not None:    # agree across shards: exit globally
            done = (jax.lax.psum(done.astype(jnp.int32), shard_axis)
                    == jax.lax.psum(1, shard_axis))
        return done

    def cond(c):
        return (c[-2] < n_blocks) & jnp.logical_not(c[-1])

    def body(c):
        s = run_block(c[:-2], c[-2] * B)
        return (*s, c[-2] + 1, all_done(s[14]))

    *state, nblk, done = jax.lax.while_loop(
        cond, body, (*state0, jnp.int32(0), jnp.bool_(False)))
    executed = jnp.minimum(nblk * B, limit)
    return _out_dict(tuple(state), executed, arrs, keep_per_thread)


#: Fully-static jit entry (legacy + scan path): one executable per
#: (n_steps, target_cs, shapes) combination.
_simulate = functools.partial(jax.jit, static_argnames=(
    "n_steps", "T", "backend", "rollout", "block_steps", "target_cs",
    "shard_axis", "early_exit", "keep_per_thread",
    "open_loop"))(_simulate_core)

#: Dynamic-horizon jit entry for the blocked rollout: ``n_steps`` and
#: ``target_cs`` are traced int32 scalars, so ONE executable per padded
#: (C, T) shape serves every step-count bucket and stream chunk.
_simulate_dyn = functools.partial(jax.jit, static_argnames=(
    "T", "backend", "rollout", "block_steps", "shard_axis", "early_exit",
    "keep_per_thread", "open_loop"))(_simulate_core)


@functools.lru_cache(maxsize=None)
def _sharded_fn(n_steps: int | None, T: int, backend: str, n_dev: int,
                rollout: str, block_steps: int, target_cs: int | None,
                early_exit: bool = False, keep_per_thread: bool = True,
                open_loop: bool = False):
    """jit(shard_map(core)) over a 1-d ``configs`` device mesh — every
    config is independent, so the mapping is manual (the single collective
    is the one-int early-exit psum per block, which agrees on the exit
    step) and results are bit-identical to the unsharded call.

    With ``n_steps=None`` (blocked rollout only) the returned callable
    takes ``(arrs, n_steps, target_cs)`` with the two scalars traced and
    replicated across the mesh — the sharded twin of :data:`_simulate_dyn`.
    """
    from jax.sharding import Mesh, PartitionSpec

    from repro.sharding.compat import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("configs",))
    spec = PartitionSpec("configs")
    rep = PartitionSpec()

    # check_vma=False: the pinned JAX has no replication rule for `while`
    # (the blocked rollout's chunk loop); replication checking adds no
    # safety here — every output is config-partitioned, never replicated.
    if n_steps is None:
        def run_dyn(arrs, ns, tc):
            return _simulate_core(arrs, ns, T=T, backend=backend,
                                  rollout=rollout, block_steps=block_steps,
                                  target_cs=tc, shard_axis="configs",
                                  early_exit=early_exit,
                                  keep_per_thread=keep_per_thread,
                                  open_loop=open_loop)

        return jax.jit(shard_map(run_dyn, mesh=mesh,
                                 in_specs=(spec, rep, rep),
                                 out_specs=spec, check_vma=False))

    def run(arrs):
        return _simulate_core(arrs, n_steps=n_steps, T=T, backend=backend,
                              rollout=rollout, block_steps=block_steps,
                              target_cs=target_cs, shard_axis="configs",
                              keep_per_thread=keep_per_thread,
                              open_loop=open_loop)

    return jax.jit(shard_map(run, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_vma=False))


def _simulate_sharded(arrs, n_steps: int, T: int, backend: str,
                      rollout: str = "blocked",
                      block_steps: int = DEFAULT_BLOCK_STEPS,
                      target_cs: int = 0, keep_per_thread: bool = True,
                      open_loop: bool = False):
    n_dev = len(jax.devices())
    C = arrs["policy"].shape[0]
    pad = (-C) % n_dev
    if pad:            # pad with copies of the last row, sliced off below
        arrs = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k, v in arrs.items()}
    if rollout == "blocked":
        fn = _sharded_fn(None, T, backend, n_dev, rollout, block_steps,
                         None, target_cs > 0, keep_per_thread, open_loop)
        out = fn(arrs, np.int32(n_steps), np.int32(target_cs))
    else:
        out = _sharded_fn(n_steps, T, backend, n_dev, rollout, block_steps,
                          target_cs, False, keep_per_thread,
                          open_loop)(arrs)
    return {k: v[:C] for k, v in out.items()}


# --------------------------------------------------------------------------
# Scheduling heuristics + public API
# --------------------------------------------------------------------------
def plan_schedule(configs, target_cs: int = 300):
    """Pick per-config ``dt`` and per-config planned step counts.

    ``dt`` resolves the fastest load-bearing timescale (the *base* CS
    length and wake latency — NCS shorter than the CS only shifts
    arrivals within a step); each config's step count covers
    ~``target_cs`` critical sections for that cell, with the mean CS/NCS
    durations corrected for the config's workload row
    (:func:`repro.core.policy.workload_mean_scale` — a bursty row's
    effective arrival gap is ``duty + (1-duty)·burst`` times the base, so
    an uncorrected horizon would under-sample it severalfold).  Returns
    ``(dt, steps)``: (C,) float32 timesteps and (C,) int64 planned
    counts.  Counts are unclamped — :func:`simulate_batch` runs
    ``steps.max()`` for the whole batch (or per bucket with
    ``bucket_steps=True``), capped at :data:`MAX_STEPS` with a diagnostic
    naming the cells the cap under-samples.
    """
    return plan_schedule_columns(P.config_columns(configs), target_cs)


def plan_schedule_columns(cols, target_cs: int = 300):
    """:func:`plan_schedule` over RAW struct-of-arrays columns
    (:data:`repro.core.policy.RAW_CONFIG_FIELDS`) — the array-native
    planner the streaming sweep uses.  All arithmetic is float64 and
    elementwise-identical to the per-object path (``plan_schedule`` is
    now this function applied to :func:`repro.core.policy.
    config_columns`), so plans never depend on which form fed them."""
    cs_lo = np.asarray(cols["cs_lo"], np.float64)
    cs_hi = np.asarray(cols["cs_hi"], np.float64)
    ncs_lo = np.asarray(cols["ncs_lo"], np.float64)
    ncs_hi = np.asarray(cols["ncs_hi"], np.float64)
    wake = (np.asarray(cols["wake_latency"], np.float64)
            * np.asarray(cols.get("park_cost", 1.0), np.float64))
    threads = np.asarray(cols["threads"], np.int64)
    cores = np.asarray(cols["cores"], np.int64)
    cs_scale, ncs_scale = P.workload_mean_scale_columns(
        cols["workload"], cols["wl_duty"], cols["wl_burst"],
        cols["wl_spread"])
    cs_b = (cs_lo + cs_hi) / 2.0
    cs_m = cs_b * cs_scale
    ncs_m = (ncs_lo + ncs_hi) / 2.0 * ncs_scale
    dt = np.minimum(np.maximum(cs_b, 1e-8), np.maximum(wake, 1e-8)) / 6.0
    per_cs = (np.maximum(cs_m, (cs_m + ncs_m) / np.minimum(threads, cores))
              * 1.35 + 0.25 * wake + 2.0 * dt)
    steps = np.ceil(target_cs * per_cs / dt).astype(np.int64)
    return dt.astype(np.float32), steps


def plan_buckets(steps) -> list[np.ndarray]:
    """Group config indices into power-of-two buckets of planned step
    count (``ceil(log2(steps))``), ascending.

    Within a bucket the shared scan length (the bucket max) is at most 2x
    any member's own plan, so a 100µs-CS cell no longer pins a µs-spin
    cell to its horizon — versus the single global ``steps.max()``, which
    can overshoot fast cells by orders of magnitude on log-uniform
    workload sweeps.
    """
    ids = np.ceil(np.log2(np.maximum(np.asarray(steps), 1))).astype(int)
    return [np.nonzero(ids == b)[0] for b in np.unique(ids)]


def _warn_undersampled(configs, steps, cap: int, target_cs: int,
                       bucketed: bool = False) -> None:
    """Step-cap diagnostic: name which cells under-sample ``target_cs``
    (count + worst offender) instead of one generic warning."""
    import warnings

    steps = np.asarray(steps)
    over = np.nonzero(steps > cap)[0]
    worst = int(steps.argmax())
    c = configs[worst]
    expect = int(target_cs * cap / steps[worst])
    advice = ("the truncated cells need a shorter horizon (smaller "
              "target_cs) or a split sweep"
              if bucketed else
              "bucket_steps=True keeps fast cells fully sampled; the "
              "truncated cells need a shorter horizon (smaller "
              "target_cs) or a split sweep")
    warnings.warn(
        f"step cap {cap} truncates {len(over)}/{len(configs)} configs "
        f"below target_cs={target_cs}; worst offender is config {worst} "
        f"({c.lock}, threads={c.threads}, cores={c.cores}, "
        f"cs<={c.cs[1]:.3g}s, ncs<={c.ncs[1]:.3g}s, "
        f"wake={c.wake_latency:.3g}s): planned {int(steps[worst])} steps, "
        f"expect ~{expect} completed CS.  {advice}.", stacklevel=3)


@dataclass
class BatchResult:
    """Struct-of-arrays results for one batched run (numpy, length C)."""

    configs: list
    n_steps: int
    backend: str
    dt: np.ndarray
    t_end: np.ndarray
    completed: np.ndarray
    spin_cpu: np.ndarray
    wake_count: np.ndarray
    final_sws: np.ndarray
    #: (C, T) per-slot CS counts; ``None`` when the run was made with
    #: ``keep_per_thread=False`` (the (C, T) array then never reaches the
    #: host and ``fairness`` carries the on-device spread instead).
    completed_per_thread: np.ndarray | None = None
    #: (C,) timesteps actually executed per config — less than ``n_steps``
    #: when early exit fired, and per-bucket under ``bucket_steps=True``.
    steps_run: np.ndarray | None = None
    #: (C,) max-min completed-CS spread over active threads, computed on
    #: device when ``keep_per_thread=False`` (else derivable from
    #: ``completed_per_thread``).
    fairness: np.ndarray | None = None
    #: Open-loop outputs, ``None`` on closed-loop runs: (C, LAT_NBINS)
    #: per-request latency histogram (log-spaced bins,
    #: :func:`repro.core.policy.latency_bin_edges`) plus (C,) request
    #: counters — arrivals offered, shed at the full queue, departed,
    #: SLO violations among departures — and the exact latency /
    #: occupancy-integral accumulators behind Little's law
    #: (``occ_int = ∫L dt``, ``lat_sum = Σ latency``; see
    #: docs/open_loop.md).  ``in_flight`` is the end-of-run system
    #: occupancy (queued + bound to a thread).
    lat_hist: np.ndarray | None = None
    arrived: np.ndarray | None = None
    shed: np.ndarray | None = None
    departed: np.ndarray | None = None
    slo_viol: np.ndarray | None = None
    lat_sum: np.ndarray | None = None
    occ_int: np.ndarray | None = None
    in_flight: np.ndarray | None = None

    @property
    def throughput(self) -> np.ndarray:
        return self.completed / np.maximum(self.t_end, 1e-30)

    @property
    def sync_cpu_per_cs(self) -> np.ndarray:
        return self.spin_cpu / np.maximum(self.completed, 1)

    def latency_quantiles(self, qs=(0.50, 0.95, 0.99)) -> np.ndarray:
        """(len(qs), C) per-request latency percentiles from the on-device
        histogram (geometric bin midpoints; NaN where nothing departed)."""
        if self.lat_hist is None:
            raise ValueError("closed-loop run: no latency histogram")
        return P.latency_percentiles(self.lat_hist, qs)

    @property
    def p50(self) -> np.ndarray:
        return self.latency_quantiles((0.50,))[0]

    @property
    def p95(self) -> np.ndarray:
        return self.latency_quantiles((0.95,))[0]

    @property
    def p99(self) -> np.ndarray:
        return self.latency_quantiles((0.99,))[0]

    @property
    def slo_frac(self) -> np.ndarray:
        """Fraction of departed requests whose latency exceeded the
        config's SLO (NaN where nothing departed)."""
        if self.slo_viol is None:
            raise ValueError("closed-loop run: no SLO accounting")
        dep = np.asarray(self.departed, np.float64)
        return np.where(dep > 0, self.slo_viol / np.maximum(dep, 1.0),
                        np.nan)

    @property
    def mean_latency(self) -> np.ndarray:
        """Exact mean departed-request latency (NaN where none departed)."""
        if self.lat_sum is None:
            raise ValueError("closed-loop run: no latency accounting")
        dep = np.asarray(self.departed, np.float64)
        return np.where(dep > 0, self.lat_sum / np.maximum(dep, 1.0),
                        np.nan)

    def validate(self, where: str = "batch") -> "BatchResult":
        """Fail loudly on engine non-finites, naming the offending config.

        Distinguishes *intentional* NaN from poison: latency quantiles,
        ``mean_latency`` and ``slo_frac`` are NaN by design for configs
        where no request departed (the empty-histogram readout), so those
        are only flagged when ``departed > 0``.  Everything else —
        throughput, spin CPU, wake counts, the open-loop accumulators —
        must be finite for every config; a violation raises
        :class:`ValueError` with the config index and its parameters, so
        a poisoned sweep cell surfaces at the diagram CLI instead of
        silently propagating NaN into the phase-diagram reduction.
        Returns ``self`` so call sites can chain it.
        """
        checks = [("t_end", self.t_end), ("completed", self.completed),
                  ("spin_cpu", self.spin_cpu),
                  ("wake_count", self.wake_count),
                  ("final_sws", self.final_sws),
                  ("throughput", self.throughput),
                  ("sync_cpu_per_cs", self.sync_cpu_per_cs)]
        if self.lat_hist is not None:
            dep = np.asarray(self.departed, np.int64)
            checks += [("lat_sum", self.lat_sum),
                       ("occ_int", self.occ_int),
                       ("mean_latency",
                        np.where(dep > 0, self.mean_latency, 0.0)),
                       ("slo_frac",
                        np.where(dep > 0, self.slo_frac, 0.0)),
                       ("p50", np.where(dep > 0, self.p50, 0.0))]
        for name, arr in checks:
            a = np.asarray(arr, np.float64)
            badm = ~np.isfinite(a)
            if badm.any():
                i = int(np.nonzero(badm)[0][0])
                cfg = (self.configs[i] if i < len(self.configs)
                       else "<padded row>")
                raise ValueError(
                    f"non-finite {name}={a[i]!r} at config {i} in "
                    f"{where}: {cfg!r}")
        return self

    def fairness_spread(self, i: int) -> int:
        """Max-min completed-CS spread across config ``i``'s threads —
        ~0/1 under FIFO ticket grants, unbounded under barging locks."""
        if self.completed_per_thread is None:
            return int(self.fairness[i])
        per = self.completed_per_thread[i, :self.configs[i].threads]
        return int(per.max() - per.min())

    def row(self, i: int) -> dict:
        return {
            "config": self.configs[i],
            "completed_cs": int(self.completed[i]),
            "throughput": float(self.throughput[i]),
            "sync_cpu_per_cs": float(self.sync_cpu_per_cs[i]),
            "wake_count": int(self.wake_count[i]),
            "final_sws": int(self.final_sws[i]),
            "t_end": float(self.t_end[i]),
        }


def _pad_quantum(n: int) -> int:
    """Next power of two — the shared config-axis padding quantum of the
    bucketed path, so buckets of nearby sizes land on the SAME padded
    (C, T) shape and (with the traced-horizon blocked rollout) reuse one
    compiled executable instead of compiling per bucket."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _simulate_bucketed(configs, buckets, steps, *, target_cs, dt, backend,
                       max_threads, shard, rollout, block_steps,
                       early_exit, keep_per_thread=True,
                       open_loop=False) -> BatchResult:
    """Run each step-count bucket as its own batched call and stitch the
    per-config results back into the caller's row order.  ``dt`` and
    ``steps`` are the (C,) planned arrays — passed down sliced, so the
    per-bucket calls skip re-planning.  Each bucket's config axis is
    padded to the next power of two (copies of its last row, sliced off
    again), so buckets share padded shapes and — the horizon being traced
    in the blocked rollout — compiled executables.  ``open_loop`` is
    resolved once here and forced on every bucket, so a mixed batch
    whose open configs all land in one bucket still returns open-loop
    outputs for every row."""
    C = len(configs)
    T = max_threads or max(c.threads for c in configs)
    parts = []
    for idx in buckets:
        parts.append(simulate_batch(
            [configs[i] for i in idx], target_cs=target_cs,
            dt=np.asarray(dt)[idx],
            n_steps=min(int(steps[idx].max()), MAX_STEPS),
            backend=backend, max_threads=T, shard=shard, rollout=rollout,
            block_steps=block_steps, early_exit=early_exit,
            bucket_steps=False, keep_per_thread=keep_per_thread,
            open_loop=open_loop,
            pad_configs=_pad_quantum(len(idx)) if rollout == "blocked"
            else None))
    res = BatchResult(
        configs=configs, n_steps=max(p.n_steps for p in parts),
        backend=backend,
        dt=np.empty(C, np.float32), t_end=np.empty(C, np.float32),
        completed=np.empty(C, np.int32), spin_cpu=np.empty(C, np.float32),
        wake_count=np.empty(C, np.int32), final_sws=np.empty(C, np.int32),
        completed_per_thread=(np.empty((C, T), np.int32)
                              if keep_per_thread else None),
        steps_run=np.empty(C, np.int32),
        fairness=None if keep_per_thread else np.empty(C, np.int32),
        lat_hist=(np.empty((C, P.LAT_NBINS), np.int32)
                  if open_loop else None),
        arrived=np.empty(C, np.int32) if open_loop else None,
        shed=np.empty(C, np.int32) if open_loop else None,
        departed=np.empty(C, np.int32) if open_loop else None,
        slo_viol=np.empty(C, np.int32) if open_loop else None,
        lat_sum=np.empty(C, np.float32) if open_loop else None,
        occ_int=np.empty(C, np.float32) if open_loop else None,
        in_flight=np.empty(C, np.int32) if open_loop else None)
    fields = ["dt", "t_end", "completed", "spin_cpu", "wake_count",
              "final_sws", "steps_run"]
    fields.append("completed_per_thread" if keep_per_thread else "fairness")
    if open_loop:
        fields += ["lat_hist", "arrived", "shed", "departed", "slo_viol",
                   "lat_sum", "occ_int", "in_flight"]
    for idx, p in zip(buckets, parts):
        for f in fields:
            getattr(res, f)[idx] = getattr(p, f)
    return res


def simulate_batch(configs, *, target_cs: int = 300, n_steps: int | None = None,
                   dt=None, backend: str = "ref",
                   max_threads: int | None = None,
                   shard: bool | None = None, rollout: str = "blocked",
                   block_steps: int | None = None,
                   early_exit: bool | None = None,
                   bucket_steps: bool = False,
                   keep_per_thread: bool = True,
                   pad_configs: int | None = None,
                   open_loop: bool | None = None) -> BatchResult:
    """Simulate every :class:`repro.core.policy.SimConfig` in ``configs``
    in ONE jit-compiled device call (or one per step-count bucket).

    All configurations in a call share the scan length; each carries its
    own ``dt``, so heterogeneous regimes (µs spin cells next to 100µs-CS
    cells) batch together without resolution loss.  ``backend="pallas"``
    routes the rollout through :mod:`repro.kernels.lock_sim`.

    Rollout and horizon controls (see docs/performance.md):

    * ``rollout="blocked"`` (default) fuses ``block_steps`` timesteps
      (default :data:`DEFAULT_BLOCK_STEPS`) into one kernel dispatch per
      loop iteration — bit-identical to ``rollout="scan"``, the legacy
      two-dispatches-per-step path kept as the parity reference.
    * ``early_exit`` (default: on iff ``n_steps`` is auto-planned) stops
      the blocked rollout at the first block boundary where every config
      has completed ``target_cs`` critical sections;
      ``BatchResult.steps_run`` records the executed count.  Ignored
      under ``rollout="scan"``.
    * ``bucket_steps=True`` groups configs into power-of-two buckets of
      planned step count (:func:`plan_buckets`) and runs one call per
      bucket, so slow cells no longer pin fast cells to their horizon.
      Results per config are identical to a direct call on its bucket.

    ``shard=None`` (auto) splits the config axis across all visible
    devices via ``shard_map`` whenever more than one is attached;
    ``shard=True`` forces the sharded path (a 1-device mesh on
    single-device hosts), ``shard=False`` disables it.  Sharded and
    unsharded results are bit-identical (configs are independent; the
    early-exit decision is agreed across devices).

    ``keep_per_thread=False`` drops the (C, T) ``completed_per_thread``
    output (the fairness spread is reduced on device into
    ``BatchResult.fairness`` instead) — the memory-lean mode the
    streaming sweep (:mod:`repro.core.stream`) runs in.  ``pad_configs``
    pads the batch with copies of the last config up to the given count
    (results sliced back), stabilizing compiled shapes across calls;
    results are bit-identical because configs are independent and the
    padded copies converge exactly when their source row does.

    ``open_loop=None`` (auto) switches on the open-loop arrival engine iff
    any config has a non-closed arrival row; closed batches compile the
    exact legacy graph (the flag is static, so the 11 OPEN_STATE carry
    arrays simply don't exist).  Forcing ``open_loop=True`` on an
    all-closed batch is valid — the open machinery runs but stays inert
    (rate 0 admits nothing), which the bit-identity tests exploit.
    """
    configs = list(configs)
    if open_loop is None:
        open_loop = any(c.open_loop for c in configs)
    if dt is None or n_steps is None:
        auto_dt, steps_arr = plan_schedule(configs, target_cs)
    if bucket_steps and n_steps is None and len(configs) > 1:
        buckets = plan_buckets(steps_arr)
        if len(buckets) > 1:
            if int(steps_arr.max()) > MAX_STEPS:
                _warn_undersampled(configs, steps_arr, MAX_STEPS,
                                   target_cs, bucketed=True)
            if dt is None:
                dt = auto_dt
            else:
                dt = np.broadcast_to(np.asarray(dt, np.float32),
                                     (len(configs),)).copy()
            return _simulate_bucketed(
                configs, buckets, steps_arr, target_cs=target_cs, dt=dt,
                backend=backend, max_threads=max_threads, shard=shard,
                rollout=rollout, block_steps=block_steps,
                # a bucketed horizon is auto-planned: exit by default
                early_exit=True if early_exit is None else early_exit,
                keep_per_thread=keep_per_thread, open_loop=open_loop)
    arrs = P.encode_configs(configs)
    if dt is None:
        dt = auto_dt
    else:
        dt = np.broadcast_to(np.asarray(dt, np.float32),
                             arrs["policy"].shape).copy()
    if n_steps is None:
        auto_steps = int(steps_arr.max())
        if auto_steps > MAX_STEPS:
            _warn_undersampled(configs, steps_arr, MAX_STEPS, target_cs,
                               bucketed=bucket_steps)
        n_steps = min(auto_steps, MAX_STEPS)
        if early_exit is None:
            early_exit = True
    elif early_exit is None:
        early_exit = False       # a pinned horizon means: run exactly it
    if n_steps > MAX_STEPS:
        raise ValueError(f"n_steps={n_steps} exceeds MAX_STEPS={MAX_STEPS}")
    arrs["dt"] = np.asarray(dt, np.float32)
    C = len(configs)
    if pad_configs is not None and pad_configs > C:
        pad = pad_configs - C
        arrs = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k, v in arrs.items()}
    T = max_threads or int(arrs["threads"].max())
    if T < int(arrs["threads"].max()):
        raise ValueError("max_threads smaller than widest config")
    if shard is None:
        shard = len(jax.devices()) > 1
    if block_steps is None:
        block_steps = DEFAULT_BLOCK_STEPS
    tc = int(target_cs) if (early_exit and rollout == "blocked") else 0
    if shard:
        out = _simulate_sharded(arrs, n_steps=int(n_steps), T=int(T),
                                backend=backend, rollout=rollout,
                                block_steps=int(block_steps), target_cs=tc,
                                keep_per_thread=keep_per_thread,
                                open_loop=open_loop)
    elif rollout == "blocked":
        # traced horizon/target: one executable per padded (C, T) shape
        out = _simulate_dyn(arrs, np.int32(n_steps), T=int(T),
                            backend=backend, rollout=rollout,
                            block_steps=int(block_steps),
                            target_cs=np.int32(tc), early_exit=tc > 0,
                            keep_per_thread=keep_per_thread,
                            open_loop=open_loop)
    else:
        out = _simulate(arrs, n_steps=int(n_steps), T=int(T),
                        backend=backend, rollout=rollout,
                        block_steps=int(block_steps), target_cs=tc,
                        keep_per_thread=keep_per_thread,
                        open_loop=open_loop)
    out = {k: np.asarray(v)[:C] for k, v in out.items()}
    return BatchResult(configs=configs, n_steps=int(n_steps), backend=backend,
                       dt=np.asarray(dt, np.float32)[:C],
                       t_end=out["t_end"], completed=out["completed"],
                       spin_cpu=out["spin_cpu"],
                       wake_count=out["wake_count"],
                       final_sws=out["final_sws"],
                       completed_per_thread=out.get("completed_per_thread"),
                       steps_run=out["steps_run"],
                       fairness=out.get("fairness"),
                       lat_hist=out.get("lat_hist"),
                       arrived=out.get("arrived"), shed=out.get("shed"),
                       departed=out.get("departed"),
                       slo_viol=out.get("slo_viol"),
                       lat_sum=out.get("lat_sum"),
                       occ_int=out.get("occ_int"),
                       in_flight=out.get("in_flight"))

"""xdes — batched, fixed-timestep simulation of lock disciplines on JAX.

The event-driven DES (:mod:`repro.core.des`) is exact but interpreter-bound:
one Python loop per ``(lock, threads, cores, cs, ncs)`` cell, so the Fig. 3
grid (5 locks x 8 thread counts x 4 regimes x seeds) runs sequentially for
minutes.  This module simulates *thousands of configurations in one device
program*: a generalized-processor-sharing step on a fixed timestep, rolled
out with ``lax.scan`` over (C, T) state blocks.  BOTH stages of the step
are swappable kernel backends, pinned bit-identical by tests:

* GPS advance — :func:`repro.kernels.ref.lock_sim_step_ref` (XLA) or the
  fused Pallas kernel :func:`repro.kernels.lock_sim.lock_sim_step`;
* transitions — :func:`repro.kernels.ref.lock_transitions_ref` (XLA) or
  :func:`repro.kernels.lock_sim.lock_transitions_step` (Pallas grid over
  config blocks).

Model fidelity: same state machine, same policy decisions — every waiting
discipline is a row in :data:`repro.core.policy.DISCIPLINE_ROWS` (spin,
sleep, adaptive, mutable, FIFO/MCS ticket handoff) and every SWS oracle a
row in ``ORACLE_ROWS``, both dispatched per config by integer columns, so
one batch mixes disciplines and oracle families freely.  The differences
from the DES are (a) time is quantized to ``dt`` instead of exact event
times, and (b) simultaneous events inside one step resolve in thread-id
order instead of RNG order.  Equivalence tests pin xdes against the Python
DES on the paper's four regimes (qualitative claims C2-C4) and per-row.

Threads are array slots: state ``(configs, max_threads)`` int32 plus small
per-config integers (sws, cnt, wuc, permits, next-ticket) — exactly the
array-encodable policy state :mod:`repro.core.policy` defines.

Scale: :func:`simulate_batch` shards the batch over every visible device
with ``shard_map`` (config axis, fully manual) when more than one device
is attached — 10-100k-config sweeps split across a host's accelerators
with no change to the calling code.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ref import NO_TICKET, REM_EPS, counter_uniform  # noqa: F401

from . import policy as P

#: Hard cap on scan length (compile + runtime guard).
MAX_STEPS = 200_000
_INF = np.float32(np.inf)

#: Context columns threaded to the transition kernels each step.
_PRM_FIELDS = ("policy", "threads", "dt", "wake", "cs_lo", "cs_hi",
               "ncs_lo", "ncs_hi", "k", "sws_max", "spin_budget", "seed",
               "oracle")


# --------------------------------------------------------------------------
# The rollout: lax.scan over steps; each step = GPS advance + transitions,
# both behind the swappable kernel boundary.
# --------------------------------------------------------------------------
def _step_backends(backend: str):
    if backend == "ref":
        from repro.kernels.ref import lock_sim_step_ref, lock_transitions_ref
        return lock_sim_step_ref, lock_transitions_ref
    if backend == "pallas":
        from repro.kernels.lock_sim import lock_sim_step, lock_transitions_step
        return lock_sim_step, lock_transitions_step
    raise ValueError(f"unknown backend {backend!r} (ref|pallas)")


def _simulate_core(arrs, n_steps: int, T: int, backend: str = "ref"):
    C = arrs["policy"].shape[0]
    tid = jnp.arange(T, dtype=jnp.int32)[None, :]
    active = tid < arrs["threads"][:, None]
    _, _, budget_f, _, _, _ = P.discipline_flags(arrs["policy"])
    has_budget = budget_f > 0
    advance, transitions = _step_backends(backend)

    # initial state: every thread in NCS with a fresh draw
    ctr0 = jnp.zeros((C, T), jnp.uint32)
    u0 = counter_uniform(arrs["seed"][:, None],
                         jnp.broadcast_to(tid, (C, T)), ctr0)
    rem0 = arrs["ncs_lo"][:, None] + u0 * (arrs["ncs_hi"]
                                           - arrs["ncs_lo"])[:, None]
    state0 = (
        jnp.where(active, P.NCS, P.DONE).astype(jnp.int32),   # st
        jnp.where(active, rem0, _INF),                        # rem
        jnp.full((C, T), _INF),                               # wake_at
        jnp.zeros((C, T), jnp.int32),                         # slept
        jnp.zeros((C, T), jnp.int32),                         # spun
        ctr0 + 1,                                             # ctr
        jnp.full((C, T), NO_TICKET, jnp.int32),               # ticket
        jnp.zeros((C, T), jnp.int32),                         # completed_pt
        arrs["sws_init"].astype(jnp.int32),                   # sws
        jnp.zeros((C,), jnp.int32),                           # cnt
        jnp.zeros((C,), jnp.int32),                           # ewma
        jnp.zeros((C,), jnp.int32),                           # wuc
        jnp.zeros((C,), jnp.int32),                           # permits
        jnp.zeros((C,), jnp.int32),                           # nticket
        jnp.zeros((C,), jnp.int32),                           # completed
        jnp.zeros((C,), jnp.int32),                           # wake_count
        jnp.zeros((C,), jnp.float32),                         # spin_cpu
    )
    prm = tuple(arrs[f] for f in _PRM_FIELDS)

    def body(carry, i):
        state, spin_cpu = carry[:-1], carry[-1]
        st, rem = state[0], state[1]
        now2 = (i.astype(jnp.float32) + 1.0) * arrs["dt"]
        rem, burn = advance(st, rem, arrs["alpha"], arrs["cores"],
                            arrs["dt"], has_budget)
        state = transitions(st, rem, *state[2:], now2, *prm)
        return (*state, spin_cpu + burn), None

    final, _ = jax.lax.scan(body, state0, jnp.arange(n_steps))
    (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt,
     sws, cnt, ewma, wuc, permits, nticket, completed, wake_count,
     spin_cpu) = final
    return {
        "completed": completed,
        "completed_per_thread": completed_pt,
        "spin_cpu": spin_cpu,
        "wake_count": wake_count,
        "final_sws": sws,
        "t_end": n_steps * arrs["dt"],
    }


_simulate = functools.partial(jax.jit, static_argnames=("n_steps", "T",
                                                        "backend"))(
    _simulate_core)


@functools.lru_cache(maxsize=None)
def _sharded_fn(n_steps: int, T: int, backend: str, n_dev: int):
    """jit(shard_map(core)) over a 1-d ``configs`` device mesh — every
    config is independent, so the mapping is fully manual (no collectives)
    and results are bit-identical to the unsharded call."""
    from jax.sharding import Mesh, PartitionSpec

    from repro.sharding.compat import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("configs",))
    spec = PartitionSpec("configs")

    def run(arrs):
        return _simulate_core(arrs, n_steps=n_steps, T=T, backend=backend)

    return jax.jit(shard_map(run, mesh=mesh, in_specs=(spec,),
                             out_specs=spec))


def _simulate_sharded(arrs, n_steps: int, T: int, backend: str):
    n_dev = len(jax.devices())
    C = arrs["policy"].shape[0]
    pad = (-C) % n_dev
    if pad:            # pad with copies of the last row, sliced off below
        arrs = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                for k, v in arrs.items()}
    out = _sharded_fn(n_steps, T, backend, n_dev)(arrs)
    return {k: v[:C] for k, v in out.items()}


# --------------------------------------------------------------------------
# Scheduling heuristics + public API
# --------------------------------------------------------------------------
def plan_schedule(configs, target_cs: int = 300):
    """Pick per-config ``dt`` and a shared step count.

    ``dt`` resolves the fastest load-bearing timescale (CS length and wake
    latency — NCS shorter than the CS only shifts arrivals within a step);
    the step count covers ~``target_cs`` critical sections for the slowest
    configuration, so every cell completes at least that many.  The count
    is unclamped — :func:`simulate_batch` caps it at :data:`MAX_STEPS`
    (with a warning, since capped cells under-sample ``target_cs``).
    """
    dts, steps = [], []
    for c in configs:
        cs_m = (c.cs[0] + c.cs[1]) / 2.0
        ncs_m = (c.ncs[0] + c.ncs[1]) / 2.0
        dt = min(max(cs_m, 1e-8), max(c.wake_latency, 1e-8)) / 6.0
        per_cs = (max(cs_m, (cs_m + ncs_m) / min(c.threads, c.cores)) * 1.35
                  + 0.25 * c.wake_latency + 2.0 * dt)
        dts.append(dt)
        steps.append(int(np.ceil(target_cs * per_cs / dt)))
    return np.asarray(dts, np.float32), max(steps)


@dataclass
class BatchResult:
    """Struct-of-arrays results for one batched run (numpy, length C)."""

    configs: list
    n_steps: int
    backend: str
    dt: np.ndarray
    t_end: np.ndarray
    completed: np.ndarray
    spin_cpu: np.ndarray
    wake_count: np.ndarray
    final_sws: np.ndarray
    completed_per_thread: np.ndarray    # (C, T) per-slot CS counts

    @property
    def throughput(self) -> np.ndarray:
        return self.completed / np.maximum(self.t_end, 1e-30)

    @property
    def sync_cpu_per_cs(self) -> np.ndarray:
        return self.spin_cpu / np.maximum(self.completed, 1)

    def fairness_spread(self, i: int) -> int:
        """Max-min completed-CS spread across config ``i``'s threads —
        ~0/1 under FIFO ticket grants, unbounded under barging locks."""
        per = self.completed_per_thread[i, :self.configs[i].threads]
        return int(per.max() - per.min())

    def row(self, i: int) -> dict:
        return {
            "config": self.configs[i],
            "completed_cs": int(self.completed[i]),
            "throughput": float(self.throughput[i]),
            "sync_cpu_per_cs": float(self.sync_cpu_per_cs[i]),
            "wake_count": int(self.wake_count[i]),
            "final_sws": int(self.final_sws[i]),
            "t_end": float(self.t_end[i]),
        }


def simulate_batch(configs, *, target_cs: int = 300, n_steps: int | None = None,
                   dt=None, backend: str = "ref",
                   max_threads: int | None = None,
                   shard: bool | None = None) -> BatchResult:
    """Simulate every :class:`repro.core.policy.SimConfig` in ``configs``
    in ONE jit-compiled device call.

    All configurations share the scan length; each carries its own ``dt``,
    so heterogeneous regimes (µs spin cells next to 100µs-CS cells) batch
    together without resolution loss.  ``backend="pallas"`` routes both
    per-step stages through :mod:`repro.kernels.lock_sim`.

    ``shard=None`` (auto) splits the config axis across all visible
    devices via ``shard_map`` whenever more than one is attached;
    ``shard=True`` forces the sharded path (a 1-device mesh on
    single-device hosts), ``shard=False`` disables it.  Sharded and
    unsharded results are bit-identical (configs are independent; the
    mapping is fully manual).
    """
    configs = list(configs)
    arrs = P.encode_configs(configs)
    auto_dt, auto_steps = plan_schedule(configs, target_cs)
    if dt is None:
        dt = auto_dt
    else:
        dt = np.broadcast_to(np.asarray(dt, np.float32),
                             arrs["policy"].shape).copy()
    if n_steps is None:
        if auto_steps > MAX_STEPS:
            import warnings

            warnings.warn(
                f"auto step count {auto_steps} capped at {MAX_STEPS}: the "
                f"slowest configs will complete fewer than target_cs="
                f"{target_cs} critical sections", stacklevel=2)
        n_steps = min(auto_steps, MAX_STEPS)
    if n_steps > MAX_STEPS:
        raise ValueError(f"n_steps={n_steps} exceeds MAX_STEPS={MAX_STEPS}")
    arrs["dt"] = dt
    T = max_threads or int(arrs["threads"].max())
    if T < int(arrs["threads"].max()):
        raise ValueError("max_threads smaller than widest config")
    if shard is None:
        shard = len(jax.devices()) > 1
    run = _simulate_sharded if shard else _simulate
    out = run(arrs, n_steps=int(n_steps), T=int(T), backend=backend)
    out = {k: np.asarray(v) for k, v in out.items()}
    return BatchResult(configs=configs, n_steps=int(n_steps), backend=backend,
                       dt=dt, t_end=out["t_end"], completed=out["completed"],
                       spin_cpu=out["spin_cpu"],
                       wake_count=out["wake_count"],
                       final_sws=out["final_sws"],
                       completed_per_thread=out["completed_per_thread"])

"""SWS adaptation oracles — four families, one shared policy core.

The mutable-lock algorithm is independent of the oracle that resizes the
spinning window (paper §3.1: "the mutable lock algorithm is independent of
the actually selected SWS adaptation oracle").  We keep the oracle pluggable
so the same state machine drives the OS-thread lock, the event-driven DES,
the serving scheduler's active-window controller — and, elementwise, the
batched simulator (:mod:`repro.core.xdes`), which sweeps every family over
thousands of configurations in one device program.

Four families are implemented, each as a branch-free integer-state pure
function ("row") in :mod:`repro.core.policy` that the classes below wrap
with per-lock state (update rules, provenance and tuning guidance are in
``docs/oracles.md``; the sweep is ``benchmarks/oracle_ablation.py``):

* ``paper`` (:class:`EvalSWS`) — the paper's EvalSWS, Algorithm 1 lines
  E1-E12: a thread that **slept and then acquired the spin lock without
  spinning** (``slept and not spun``) proves the window failed to mask
  wake-up latency -> grow ``delta = +sws`` (doubling); no such event for
  ``K`` consecutive acquisitions -> shrink ``delta = -1``.  ``K = 10`` in
  the paper's evaluation: late-wake probability is kept below ~1/(K+1).
* ``aimd`` (:class:`AIMDOracle`) — additive increase (+1 on late wake),
  multiplicative decrease (halve after K clean rounds); the backoff-
  splitting bias of Fissile locks (Dice & Kogan 2020).
* ``fixed`` (:class:`FixedBudgetOracle`) — no adaptation: the window is a
  constant retrial budget, the glibc ``spin_count`` cap / Oracle RDBMS
  ``_spin_count`` design (Nikolaev 2012).
* ``history`` (:class:`HistoryOracle`) — an EWMA of the late-wake rate
  (glibc's adaptive-mutex smoothing applied to the paper's signal): grow
  when the smoothed rate exceeds 2x the 1/(K+1) target, shrink below half.

Every class delegates its update rule to the SAME row the batched backend
evaluates, so threaded and vectorized trajectories are bit-identical
(pinned by ``tests/test_oracles.py``).
"""

from __future__ import annotations

from typing import Protocol

from . import policy
from .policy import ORACLE_IDS, ORACLE_ROWS


class Oracle(Protocol):
    """Signed window variation computed at lock-acquire time."""

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        """Return the signed variation ``delta`` to apply to ``sws``."""
        ...


class _RowOracle:
    """Stateful wrapper around one vectorized policy row: holds the
    ``(cnt, ewma)`` integer state and feeds it through
    :data:`repro.core.policy.ORACLE_ROWS` — the exact code the batched
    simulator runs elementwise."""

    oracle_id: int

    def __init__(self, k: int = 10):
        if k < 1:
            raise ValueError("K must be >= 1")
        self.k = k
        self.cnt = 0
        self.ewma = 0

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        delta, self.cnt, self.ewma = ORACLE_ROWS[self.oracle_id](
            int(spun), int(slept), sws, self.cnt, self.ewma, self.k)
        return int(delta)


class EvalSWS(_RowOracle):
    """The paper's oracle, faithful to Algorithm 1 lines E1-E12.

    State ``cnt`` counts consecutive critical-section executions without a
    late wake-up.  It is only read/written while holding ``spn_obj`` (the
    call sits between spn_obj.lock() and the end of ACQUIRE), so it needs no
    extra synchronization — mirroring the paper's placement of ``m.cnt``.
    """

    oracle_id = policy.ORACLE_EVALSWS

    def __init__(self, k: int = 10):
        super().__init__(k)
        # Observability counters (not part of the algorithm).
        self.grow_events = 0
        self.shrink_events = 0

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        delta = super().eval_sws(spun, slept, sws)
        self.grow_events += delta > 0
        self.shrink_events += delta < 0
        return delta


class AIMDOracle(_RowOracle):
    """Additive-increase / multiplicative-decrease: grow by +1 on late
    wake-up, halve after K clean rounds.

    The paper doubles on a late wake and shrinks by 1; AIMD is the opposite
    bias (favors small windows / CPU savings over latency), the same split
    Fissile locks apply to their backoff budget.
    """

    oracle_id = policy.ORACLE_AIMD


class FixedBudgetOracle(_RowOracle):
    """Fixed retrial budget (glibc ``spin_count`` cap / Oracle RDBMS
    ``_spin_count``): pins the window at ``k`` slots — the classic
    spin-then-park mutex with a constant spin allowance.  Generalizes
    :class:`FixedOracle` (budget = initial window)."""

    oracle_id = policy.ORACLE_FIXED


class HistoryOracle(_RowOracle):
    """EWMA of the late-wake rate in Q8 fixed point (state ``ewma``):
    reacts slower than EvalSWS but is robust to one-off latency spikes."""

    oracle_id = policy.ORACLE_HISTORY


class FixedOracle:
    """Never resizes — degenerates the mutable lock into a static
    spin(window)/sleep hybrid.  Useful as an ablation baseline when the
    static window should stay at ``initial_sws`` (for a specific budget
    use :class:`FixedBudgetOracle`)."""

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        return 0


#: Family name -> threaded class, aligned with policy.ORACLE_IDS.
ORACLE_CLASSES = {
    "paper": EvalSWS,
    "aimd": AIMDOracle,
    "fixed": FixedBudgetOracle,
    "history": HistoryOracle,
}


def make_oracle(name: str, k: int = 10) -> Oracle:
    """Instantiate the threaded oracle for a family name (the DES-side
    counterpart of a :class:`repro.core.policy.SimConfig` ``oracle`` row)."""
    if name not in ORACLE_CLASSES:
        raise ValueError(f"unknown oracle {name!r}; "
                         f"options: {sorted(ORACLE_IDS)}")
    return ORACLE_CLASSES[name](k=k)

"""SWS adaptation oracles (paper §3.2, routine EvalSWS).

The mutable-lock algorithm is independent of the oracle that resizes the
spinning window (paper §3.1: "the mutable lock algorithm is independent of
the actually selected SWS adaptation oracle").  We keep the oracle pluggable
so the same state machine drives both the OS-thread lock and the serving
scheduler's active-window controller.

The paper's oracle (EvalSWS, Algorithm 1 lines E1-E12):

* a thread that **slept and then acquired the spin lock without spinning**
  (``slept and not spun``) proves the window failed to mask wake-up latency
  -> grow: ``delta = +sws`` (doubling);
* if that event does not occur for ``K`` consecutive acquisitions
  -> shrink: ``delta = -1``.

``K = 10`` in the paper's evaluation: late wake-up probability is kept below
~1/(K+1) ~= 10%.
"""

from __future__ import annotations

from typing import Protocol

from .policy import eval_sws_delta


class Oracle(Protocol):
    """Signed window variation computed at lock-acquire time."""

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        """Return the signed variation ``delta`` to apply to ``sws``."""
        ...


class EvalSWS:
    """The paper's oracle, faithful to Algorithm 1 lines E1-E12.

    State ``cnt`` counts consecutive critical-section executions without a
    late wake-up.  It is only read/written while holding ``spn_obj`` (the
    call sits between spn_obj.lock() and the end of ACQUIRE), so it needs no
    extra synchronization — mirroring the paper's placement of ``m.cnt``.
    """

    def __init__(self, k: int = 10):
        if k < 1:
            raise ValueError("K must be >= 1")
        self.k = k
        self.cnt = 0
        # Observability counters (not part of the algorithm).
        self.grow_events = 0
        self.shrink_events = 0

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        # E2-E11 live in the shared policy core (repro.core.policy), where
        # the batched backend applies the same rule elementwise.
        delta, self.cnt = eval_sws_delta(spun, slept, sws, self.cnt, self.k)
        self.grow_events += delta > 0
        self.shrink_events += delta < 0
        return delta


class FixedOracle:
    """Never resizes — degenerates the mutable lock into a static
    spin(window)/sleep hybrid.  Useful as an ablation baseline."""

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        return 0


class AIMDOracle:
    """Additive-increase / multiplicative-decrease variant (beyond-paper
    ablation): grow by +1 on late wake-up, halve after K clean rounds.

    The paper doubles on a late wake and shrinks by 1; AIMD is the opposite
    bias (favors small windows / CPU savings over latency).  Exposed so the
    benchmarks can compare oracle families, per the paper's future-work note.
    """

    def __init__(self, k: int = 10):
        self.k = k
        self.cnt = 0

    def eval_sws(self, spun: bool, slept: bool, sws: int) -> int:
        self.cnt += 1
        if slept and not spun:
            self.cnt = 0
            return 1
        if self.cnt >= self.k:
            self.cnt = 0
            return -(sws // 2)
        return 0

"""Shared lock-policy core — the paper's Algorithm 1 as pure functions.

Before this module, the window state machine lived in four places: the
event-driven DES (:mod:`repro.core.des`), the threaded lock
(:mod:`repro.core.mutlock`), the single-controller window
(:mod:`repro.core.window` / :mod:`repro.serve.scheduler`), and — implicitly
— any batched backend.  This module extracts the policy *decisions* as pure
functions of small integer state so one implementation drives all of them,
including the array-programming backend (:mod:`repro.core.xdes`), where the
same functions are applied elementwise over thousands of configurations.

Every function here is branch-light, allocation-free, and valid on plain
Python ints **and** on numpy/jax integer arrays (the callers pick the
``where`` combinator; the scalar forms below use ``if`` for readability and
are the reference semantics).

The row-registry contract
-------------------------
Three registries make the engine data-driven: :data:`ORACLE_ROWS` (SWS
adaptation families), :data:`DISCIPLINE_ROWS` (waiting disciplines) and
:data:`WORKLOAD_ROWS` (hold-time models).  A row is (metadata +) pure
elementwise functions using only arithmetic and comparisons — no ``if``,
no transcendentals (callers precompute deviates) — so the SAME function
body runs on Python scalars, numpy arrays, and traced jax values, and the
batched engine dispatches rows per config with masked arithmetic selects
(:func:`oracle_update`, :func:`_dispatch_rows`, :func:`workload_hold`).

To add a row: define its functions here, register it (id + registry
entry), give it an event-driven twin in :mod:`repro.core.des` for parity
testing, and — if its decisions need state the kernels don't carry — add
the state column once in :func:`repro.kernels.ref.lock_transitions_ref`;
the Pallas backend inherits it automatically because the Pallas kernels
apply the *same body* per config block (the ref/Pallas bit-identity
requirement is by construction, and pinned by tests).

Line-number comments (A*, R*, E*) refer to Algorithm 1 in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass

# --------------------------------------------------------------------------
# Thread states — shared by the event-driven DES, the batched simulator and
# the Pallas step kernel (one integer encoding everywhere).
# --------------------------------------------------------------------------
NCS, CS, SPIN, SLEEP_ST, WAKING, DONE = range(6)
STATE_NAMES = ("NCS", "CS", "SPIN", "SLEEP", "WAKING", "DONE")

# --------------------------------------------------------------------------
# Discipline ids — shared by the DES model registry, the batched simulator's
# integer encoding, and the Pallas kernel.  ``fifo`` is the true-MCS
# handoff discipline: waiters take numbered tickets and the lock is granted
# strictly in ticket (arrival) order — no barging.
# --------------------------------------------------------------------------
TAS, TTAS, MCS, SLEEP, ADAPTIVE, MUTABLE, FIFO = range(7)
# Related-work rows (PAPERS.md): Fissile-style spin-then-park with an
# oracle-tuned budget, Hapax value-based strict-FIFO admission, and
# TTAS with seeded bounded-exponential backoff.
FISSILE, HAPAX, TTAS_BACKOFF = 7, 8, 9

POLICY_IDS = {
    "tas": TAS,
    "ttas": TTAS,
    "mcs": MCS,
    "sleep": SLEEP,
    "adaptive": ADAPTIVE,
    "mutable": MUTABLE,
    "fifo": FIFO,
    "fissile": FISSILE,
    "hapax": HAPAX,
    "ttas_backoff": TTAS_BACKOFF,
}
POLICY_NAMES = {v: k for k, v in POLICY_IDS.items()}

#: Hardware-contention coefficient per discipline (paper §2): the CS
#: holder's progress rate is divided by ``1 + alpha * n_spinners``.  MCS
#: spins on private cache lines (no coherency pressure); TAS hammers the
#: lock word with RMWs (worst); TTAS/adaptive/mutable read-spin (mild);
#: FIFO inherits MCS's private-line spinning.
DEFAULT_ALPHA = {
    "tas": 0.05,
    "ttas": 0.02,
    "mcs": 0.0,
    "sleep": 0.0,
    "adaptive": 0.02,
    "mutable": 0.02,
    "fifo": 0.0,
    "fissile": 0.02,        # read-spins during its bounded window
    "hapax": 0.0,           # never spins: every waiter parks in FIFO order
    "ttas_backoff": 0.01,   # backoff thins the coherency traffic vs ttas
}

#: glibc-style default spin budget (CPU-seconds) for the adaptive mutex.
DEFAULT_SPIN_BUDGET = 2e-6

#: Seed salt for the ttas_backoff per-(thread, step) backoff-delay
#: uniforms — disjoint from every WL/AR/TB/FLT salt so backoff never
#: perturbs workload, arrival, tie-break or fault draws.
BO_SALT = 0x165667B1

#: Bounded-exponential cap: a backoff delay never exceeds
#: ``spin_budget * 2**BO_CAP`` seconds (the classic truncated-binary
#: exponential backoff rule).
BO_CAP = 6


# --------------------------------------------------------------------------
# Oracle family ids — shared by the threaded oracles (repro.core.oracle),
# the batched simulator's integer encoding, and the standalone oracle
# kernels (repro.kernels.lock_sim / repro.kernels.ref).  See docs/oracles.md
# for the update rules and provenance of each family.
# --------------------------------------------------------------------------
ORACLE_EVALSWS, ORACLE_AIMD, ORACLE_FIXED, ORACLE_HISTORY = range(4)

ORACLE_IDS = {
    "paper": ORACLE_EVALSWS,       # EvalSWS E1-E12: double / -1
    "aimd": ORACLE_AIMD,           # +1 on late wake, halve after K clean
    "fixed": ORACLE_FIXED,         # glibc/Oracle-RDBMS fixed retrial budget
    "history": ORACLE_HISTORY,     # EWMA of the late-wake rate
}
ORACLE_NAMES = {v: k for k, v in ORACLE_IDS.items()}

#: Q8.8-style fixed point for the history oracle's EWMA state: ``ewma`` is
#: the late-wake rate scaled by EWMA_ONE, smoothed with weight 1/2**EWMA_SHIFT
#: per acquisition (glibc's adaptive mutex smooths its spin count the same
#: way: ``__spins += (cnt - __spins) / 8``).
EWMA_ONE = 256
EWMA_SHIFT = 3


# --------------------------------------------------------------------------
# EvalSWS — the paper's oracle (E1-E12) as a pure function
# --------------------------------------------------------------------------
def eval_sws_delta(spun: bool, slept: bool, sws: int, cnt: int,
                   k: int) -> tuple[int, int]:
    """One EvalSWS observation.  Returns ``(delta, cnt')``.

    ``cnt`` counts consecutive acquisitions without a late wake-up; a late
    wake-up (``slept and not spun``) doubles the window (E4-E6), ``k`` clean
    acquisitions shrink it by one (E7-E9).
    """
    cnt = cnt + 1                      # E2
    if slept and not spun:             # E4: late wake-up detected
        return sws, 0                  # E5-E6: double, reset counter
    if cnt >= k:                       # E7 (>= guards lost updates)
        return -1, 0                   # E8-E9
    return 0, cnt                      # E3/E11


def clamp_delta(sws: int, delta: int, lo: int, hi: int) -> int:
    """A16-A17: clamp so that ``lo <= sws + delta <= hi``."""
    if sws + delta < lo:
        delta = lo - sws
    if sws + delta > hi:
        delta = hi - sws
    return delta


# --------------------------------------------------------------------------
# Oracle family rows — branch-free, integer-state pure functions.
#
# Every row has the same shape: ``(spun, slept, sws, cnt, ewma, k)`` in,
# ``(delta, cnt', ewma')`` out, where ``delta`` is the *unclamped* window
# variation (the caller applies A16-A17 via :func:`clamp_delta` /
# ``jnp.clip``), ``cnt`` is the clean-acquisition counter and ``ewma`` the
# history oracle's fixed-point late-wake rate (unused state passes through
# unchanged).  Selection is arithmetic (``flag * a + (1-flag) * b``), never
# ``if``, so the SAME code runs on plain Python ints (threaded oracles in
# :mod:`repro.core.oracle`), numpy arrays, and traced jax values inside the
# batched simulator's scan step — one implementation, bit-identical
# everywhere.  ``spun``/``slept`` must arrive as 0/1 integers (or boolean
# arrays); :func:`oracle_update` normalizes them.
# --------------------------------------------------------------------------
def oracle_evalsws_row(spun, slept, sws, cnt, ewma, k):
    """Paper EvalSWS (E1-E12): double on a late wake-up, -1 after ``k``
    clean acquisitions.  Branch-free form of :func:`eval_sws_delta`."""
    cnt1 = cnt + 1                                    # E2
    late = slept * (1 - spun)                         # E4
    hitk = (cnt1 >= k) * (1 - late)                   # E7 (late wins)
    delta = late * sws + hitk * (-1)                  # E5 / E8
    cnt1 = (1 - late) * (1 - hitk) * cnt1             # E6 / E9 / E11
    return delta, cnt1, ewma


def oracle_aimd_row(spun, slept, sws, cnt, ewma, k):
    """Additive-increase / multiplicative-decrease (Fissile-style backoff
    splitting): +1 on a late wake-up, halve after ``k`` clean rounds — the
    opposite bias to the paper (favors small windows / CPU savings)."""
    cnt1 = cnt + 1
    late = slept * (1 - spun)
    hitk = (cnt1 >= k) * (1 - late)
    delta = late * 1 + hitk * (-(sws // 2))
    cnt1 = (1 - late) * (1 - hitk) * cnt1
    return delta, cnt1, ewma


def oracle_fixed_row(spun, slept, sws, cnt, ewma, k):
    """Fixed-budget retrial (glibc ``spin_count`` cap / Oracle RDBMS
    ``_spin_count``, Nikolaev 2012): the window is pinned at the budget
    ``k`` — no adaptation, spin slots are a constant retrial allowance.
    ``delta`` drives ``sws`` to ``k`` (the A16-A17 clamp caps it at
    ``sws_max``)."""
    return k - sws, cnt * 0, ewma


def oracle_history_row(spun, slept, sws, cnt, ewma, k):
    """History-based: an EWMA of the late-wake indicator (fixed point,
    :data:`EWMA_ONE` = rate 1.0, smoothing 1/2**:data:`EWMA_SHIFT` — the
    glibc adaptive-mutex smoothing rule applied to the paper's late-wake
    signal).  Grow (double) when the smoothed rate exceeds twice the
    paper's target rate 1/(k+1); shrink by one when it falls below half
    the target.  Reacts slower than EvalSWS but is robust to one-off
    wake-latency spikes."""
    late = slept * (1 - spun)
    ewma1 = ewma + ((late * EWMA_ONE - ewma) >> EWMA_SHIFT)
    target = EWMA_ONE // (k + 1)
    grow = (ewma1 > 2 * target) * 1
    shrink = (2 * ewma1 < target) * (1 - grow)
    delta = grow * sws + shrink * (-1)
    return delta, cnt * 0, ewma1


#: Row functions indexed by oracle id (the dispatch order of oracle_update).
ORACLE_ROWS = (oracle_evalsws_row, oracle_aimd_row, oracle_fixed_row,
               oracle_history_row)


def oracle_update(oracle_id, spun, slept, sws, cnt, ewma, k):
    """Dispatch one oracle observation by ``oracle_id``.

    Arithmetic select over :data:`ORACLE_ROWS`, so it is valid on scalars
    and arrays alike; inside the batched simulator ``oracle_id`` is a
    per-config int32 column and every row is evaluated elementwise with the
    winner chosen by mask — branch-free, one fused program.  Returns
    ``(delta, cnt', ewma')`` with ``delta`` unclamped (apply A16-A17).
    """
    spun = spun * 1
    slept = slept * 1
    delta = cnt1 = ewma1 = 0
    for oid, row in enumerate(ORACLE_ROWS):
        sel = (oracle_id == oid) * 1
        d, c, e = row(spun, slept, sws, cnt, ewma, k)
        delta = delta + sel * d
        cnt1 = cnt1 + sel * c
        ewma1 = ewma1 + sel * e
    return delta, cnt1, ewma1


# --------------------------------------------------------------------------
# Arrival / release decisions (A7, R2-R21)
# --------------------------------------------------------------------------
def should_sleep_on_arrival(thc_pre: int, sws: int) -> bool:
    """A7: a thread arriving at index ``thc_pre`` (holder at 0) sleeps iff
    it lands outside the spinning window."""
    return thc_pre >= sws


def wake_correction(delta: int, thc: int, sws_pre: int) -> int:
    """C1/C2 wake-up-count correction (A23-A33), the signed increment to
    ``wuc`` after a resize ``sws_pre -> sws_pre + delta``.

    C1 (grow with sleepers, A27-A28): threads that went to sleep because
    the window was full would now fit — wake up to ``delta`` of them.
    C2 (shrink with excess spinners, A25-A26): more threads are inside the
    window than it now holds — suppress up to ``-delta`` future wake-ups.

    The same arithmetic serves the single-controller window
    (:meth:`repro.core.window.SpinningWindow.observe`), where the return
    value is the number of cold items to promote (>0) or hot items to let
    drain (<0).
    """
    sws_post = sws_pre + delta
    if delta < 0 and thc > sws_post:             # A25: C2
        tmp = thc - sws_post                     # A26
    elif delta > 0 and thc > sws_pre:            # A27: C1
        tmp = thc - sws_pre                      # A28
    else:
        tmp = 0                                  # A30
    sign = 1 if delta > 0 else -1                # A24
    return sign * min(abs(delta), tmp)           # A32


def latch_wuc(wuc: int) -> tuple[int, int]:
    """RELEASE lines R2-R7: latch the wake-up count at release time.

    Returns ``(r_wuc, wuc')``.  ``r_wuc < 0`` means this release is
    suppressed by a pending C2 correction (R6-R7, R11-R12) and must issue
    no wake-up at all.  Latching happens *before* the lock is handed off /
    unlocked, so corrections appended by the next acquirer belong to the
    next release.
    """
    if wuc >= 0:                                 # R2
        return wuc, 0                            # R3-R4
    return -1, wuc + 1                           # R6-R7: C2 suppression


def release_quota(r_wuc: int, thc_pre: int, sws: int) -> int:
    """RELEASE lines R11-R17: permits actually issued by this release.

    ``r_wuc`` is the latched value from :func:`latch_wuc`; ``thc_pre`` the
    thread count before the releaser's decrement (R9/R14); ``sws`` the
    window at R16 (post-handoff).  Adds the +1 sleep->spin promotion when
    sleepers exist (R16-R17); a suppressed release issues nothing.
    """
    if r_wuc < 0:                                # R11-R12
        return 0
    if thc_pre > sws:                            # R16: sleepers exist
        r_wuc += 1                               # R17: sleep->spin
    return r_wuc                                 # R19


# --------------------------------------------------------------------------
# Discipline rows — the waiting discipline as data, mirroring ORACLE_ROWS.
#
# A row describes ONE waiting discipline as (a) four 0/1 capability flags
# and (b) two elementwise decision functions.  Flags and functions are
# branch-free integer arithmetic, valid on plain Python ints, numpy arrays
# and traced jax values alike — exactly the contract of the oracle rows —
# so the SAME row drives the event-driven DES models, the batched
# transition engine (repro.kernels.ref.lock_transitions_ref) and its
# Pallas twin.  Adding a discipline is ~20 lines: one row here, one DES
# model for parity testing, one POLICY_IDS entry.
#
#   handoff       release grants the lock to a waiting spinner
#   fifo_grant    grant order is the arrival ticket, not the thread id
#   budget_spin   spinners consume a finite CPU budget, then park (glibc)
#   wake_to_spin  a woken thread that finds the lock taken joins the
#                 spinners (the mutable lock's sleep->spin transition)
#   repark        a woken thread that finds the lock taken parks again
#                 (the sleep/adaptive barging rule); disciplines that
#                 never park set both wake_to_spin and repark to 0
#   windowed      the discipline runs the SWS oracle + C1/C2 corrections
#   budget_scaled the spin budget is priced competitively: effective
#                 budget = spin_budget * sws * park_cost (Fissile's
#                 spin-roughly-the-park-cost rule, with the oracle's
#                 window as the adaptive multiplier)
#   backoff       spinners poll under seeded bounded-exponential backoff
#                 (BO_SALT stream) instead of being handed the lock
#
#   arrival_sleeps(rank, thc_pre, sws, holder_free) -> 0/1
#       whether the rank-th simultaneous arrival parks (A7 for the
#       mutable window; the sleep lock barges only when rank==0 finds
#       the lock free; spin disciplines never park on arrival).
#   quota(r_wuc, thc_pre, sws, n_parked, handoff_taken) -> int >= 0
#       wake permits issued by a release (R11-R17 for the mutable lock;
#       wake-one for sleep/adaptive; none for pure spin/FIFO).
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class DisciplineRow:
    name: str
    policy_ids: tuple
    handoff: int
    fifo_grant: int
    budget_spin: int
    wake_to_spin: int
    repark: int
    windowed: int
    arrival_sleeps: object     # callable, elementwise (see module comment)
    quota: object              # callable, elementwise
    budget_scaled: int = 0
    backoff: int = 0


def _arrive_never(rank, thc_pre, sws, holder_free):
    return rank * 0


def _arrive_sleep_lock(rank, thc_pre, sws, holder_free):
    # Barge iff this is the first arrival of the step and the lock is free.
    return 1 - (rank == 0) * holder_free


def _arrive_window(rank, thc_pre, sws, holder_free):
    # A7: arriving at index thc_pre (holder at 0) outside the window parks.
    return (thc_pre >= sws) * 1


def _arrive_fifo_park(rank, thc_pre, sws, holder_free):
    # Hapax admission: acquire only when the lock is free AND nobody is
    # ahead (thc_pre counts holder + waiters); otherwise join the FIFO
    # parking queue — structurally no barging.
    return 1 - (thc_pre == 0) * holder_free


def _quota_zero(r_wuc, thc_pre, sws, n_parked, handoff_taken):
    return r_wuc * 0


def _quota_wake_one(r_wuc, thc_pre, sws, n_parked, handoff_taken):
    return (n_parked > 0) * 1


def _quota_wake_one_no_handoff(r_wuc, thc_pre, sws, n_parked, handoff_taken):
    return (n_parked > 0) * (1 - handoff_taken)


def _quota_mutable(r_wuc, thc_pre, sws, n_parked, handoff_taken):
    # R11-R17: a suppressed release (r_wuc < 0) issues nothing; otherwise
    # the latched count plus the sleep->spin promotion when sleepers exist.
    return (r_wuc >= 0) * (r_wuc + (thc_pre > sws))


DISCIPLINE_ROWS = {
    "spin": DisciplineRow(
        name="spin", policy_ids=(TAS, TTAS, MCS),
        handoff=1, fifo_grant=0, budget_spin=0, wake_to_spin=0, repark=0,
        windowed=0, arrival_sleeps=_arrive_never, quota=_quota_zero),
    "sleep": DisciplineRow(
        name="sleep", policy_ids=(SLEEP,),
        handoff=0, fifo_grant=0, budget_spin=0, wake_to_spin=0, repark=1,
        windowed=0, arrival_sleeps=_arrive_sleep_lock, quota=_quota_wake_one),
    "adaptive": DisciplineRow(
        name="adaptive", policy_ids=(ADAPTIVE,),
        handoff=1, fifo_grant=0, budget_spin=1, wake_to_spin=0, repark=1,
        windowed=0, arrival_sleeps=_arrive_never,
        quota=_quota_wake_one_no_handoff),
    "mutable": DisciplineRow(
        name="mutable", policy_ids=(MUTABLE,),
        handoff=1, fifo_grant=0, budget_spin=0, wake_to_spin=1, repark=0,
        windowed=1, arrival_sleeps=_arrive_window, quota=_quota_mutable),
    "fifo": DisciplineRow(
        name="fifo", policy_ids=(FIFO,),
        handoff=1, fifo_grant=1, budget_spin=0, wake_to_spin=0, repark=0,
        windowed=0, arrival_sleeps=_arrive_never, quota=_quota_zero),
    # Fissile-style spin-then-park: every arrival spins for a bounded
    # budget priced at the park round-trip (budget_scaled), parks when it
    # runs out, and a woken thread re-joins the spinners with a fresh
    # budget.  The SWS oracle tunes the budget multiplier: an acquisition
    # that had to park reads as a late wake (windowed=1 + the
    # budget_scaled spun-mask in oracle_acquire), doubling the window.
    "fissile": DisciplineRow(
        name="fissile", policy_ids=(FISSILE,),
        handoff=1, fifo_grant=0, budget_spin=1, wake_to_spin=1, repark=0,
        windowed=1, arrival_sleeps=_arrive_never,
        quota=_quota_wake_one_no_handoff, budget_scaled=1),
    # Hapax value-based FIFO admission: constant-time arrival (tail
    # enqueue) and unlock (head wake); every contended arrival parks with
    # a ticket and releases wake strictly in ticket order — no barging.
    "hapax": DisciplineRow(
        name="hapax", policy_ids=(HAPAX,),
        handoff=0, fifo_grant=1, budget_spin=0, wake_to_spin=0, repark=0,
        windowed=0, arrival_sleeps=_arrive_fifo_park,
        quota=_quota_wake_one),
    # TTAS with truncated-binary exponential backoff: spinners poll on a
    # seeded schedule (BO_SALT) and pick up a free lock when a poll lands;
    # releases grant nothing (handoff=0) — the poll IS the acquire path.
    "ttas_backoff": DisciplineRow(
        name="ttas_backoff", policy_ids=(TTAS_BACKOFF,),
        handoff=0, fifo_grant=0, budget_spin=0, wake_to_spin=0, repark=0,
        windowed=0, arrival_sleeps=_arrive_never, quota=_quota_zero,
        backoff=1),
}

#: policy id -> row (every POLICY_IDS entry must be claimed by one row).
POLICY_ROW = {pid: row for row in DISCIPLINE_ROWS.values()
              for pid in row.policy_ids}
assert sorted(POLICY_ROW) == sorted(POLICY_IDS.values()), \
    "every policy id must map to exactly one discipline row"

#: Derived views over the rows: which disciplines hand the lock to a
#: spinner on release, and which ever park a thread.  A new row updates
#: these automatically.
HANDOFF_POLICIES = frozenset(pid for pid, row in POLICY_ROW.items()
                             if row.handoff)
SLEEPING_POLICIES = frozenset(
    pid for pid, row in POLICY_ROW.items()
    if row.repark or row.windowed or row.budget_spin
    or row.arrival_sleeps is not _arrive_never)


def _dispatch_rows(policy_id, fn):
    """Masked arithmetic select of ``fn(row)`` over DISCIPLINE_ROWS —
    the discipline twin of :func:`oracle_update`'s dispatch loop."""
    out = 0
    for row in DISCIPLINE_ROWS.values():
        sel = 0
        for pid in row.policy_ids:
            sel = sel + (policy_id == pid) * 1
        out = out + sel * fn(row)
    return out


#: Attribute order of :func:`discipline_flags` — unpack sites must match.
DISCIPLINE_FLAG_ATTRS = ("handoff", "fifo_grant", "budget_spin",
                         "wake_to_spin", "repark", "windowed",
                         "budget_scaled", "backoff")


def discipline_flags(policy_id):
    """Per-config capability flags ``(handoff, fifo_grant, budget_spin,
    wake_to_spin, repark, windowed, budget_scaled, backoff)`` as 0/1
    values, dispatched by policy id.  Valid on scalars and integer arrays
    (arithmetic select, no ``if``)."""
    return tuple(_dispatch_rows(policy_id, lambda r, a=attr: getattr(r, a))
                 for attr in DISCIPLINE_FLAG_ATTRS)


def discipline_arrival_sleeps(policy_id, rank, thc_pre, sws, holder_free):
    """0/1: does the ``rank``-th simultaneous arrival park?  Elementwise
    over threads; ``holder_free`` is 0/1."""
    return _dispatch_rows(
        policy_id, lambda r: r.arrival_sleeps(rank, thc_pre, sws,
                                              holder_free))


def discipline_release_quota(policy_id, r_wuc, thc_pre, sws, n_parked,
                             handoff_taken):
    """Wake permits issued by a release under each discipline's rule
    (the array form of :func:`release_quota` plus the sleep/adaptive
    wake-one rules).  ``handoff_taken`` is 0/1."""
    return _dispatch_rows(
        policy_id, lambda r: r.quota(r_wuc, thc_pre, sws, n_parked,
                                     handoff_taken))


# --------------------------------------------------------------------------
# Workload rows — the hold-time model as data, mirroring ORACLE_ROWS and
# DISCIPLINE_ROWS.
#
# The paper evaluates fixed CS/NCS draws; its robustness pitch ("scarce or
# none knowledge about the actual workload") only shows up under
# non-stationary workloads.  Every workload is therefore a row: a named,
# branch-free transformation of the base uniform CS/NCS draw, dispatched
# per config by an integer id exactly like the oracle and discipline rows.
#
# A row's ``hold`` function is pure arithmetic on caller-precomputed
# inputs, so ONE implementation runs on plain Python floats (the DES twin
# checks against it), numpy arrays, and traced jax values inside the
# kernels:
#
#   hold(is_ncs, base, expd, gate_off, tscale, burst) -> duration
#     is_ncs    0/1 static flag: is this an NCS (arrival-gap) draw?
#     base      the uniform draw  lo + u * (hi - lo)
#     expd      the exponential deviate  mean_ncs * -log1p(-u)  (same u)
#     gate_off  0/1: thread is in the OFF phase of its duty cycle
#     tscale    persistent per-thread scale from the seeded spread
#     burst     the OFF-phase NCS stretch factor
#
# ``gate_off`` and ``tscale`` derive from two persistent per-(config,
# thread) uniforms drawn from the counter RNG under dedicated salts
# (WL_PHASE_SALT / WL_SPREAD_SALT), so they are deterministic, replayable,
# and independent of the event-draw stream.  The dispatch is an arithmetic
# select; the constant row returns ``base`` untouched, so constant-workload
# configs are bit-identical to the pre-registry engine.
# --------------------------------------------------------------------------
WL_CONSTANT, WL_BURSTY, WL_HETERO, WL_JITTER = range(4)

WORKLOAD_IDS = {
    "constant": WL_CONSTANT,   # the paper's fixed uniform draws
    "bursty": WL_BURSTY,       # ON/OFF duty cycle: time-varying NCS
    "hetero": WL_HETERO,       # per-thread CS/NCS scale from a seeded spread
    "jitter": WL_JITTER,       # Poisson-like arrivals: exponential NCS
}
WORKLOAD_NAMES = {v: k for k, v in WORKLOAD_IDS.items()}

#: Seed salts for the persistent per-thread workload uniforms (XOR-ed into
#: the config seed so the streams never collide with event draws).
WL_PHASE_SALT = 0x7F4A7C15     # duty-cycle phase + arrival-order offset
WL_SPREAD_SALT = 0x6C62272E    # heterogeneous per-thread scale


def counter_uniform_scalar(seed: int, tid: int, ctr: int = 0) -> float:
    """Pure-Python mirror of :func:`repro.kernels.ref.counter_uniform`
    (same splitmix-style avalanche, mod-2**32 arithmetic), so the DES twin
    realizes the SAME persistent per-thread workload state — duty-cycle
    phases, heterogeneity scales, arrival offsets — as the batched engine
    for a given (seed, tid)."""
    m = 0xFFFFFFFF
    x = (seed ^ (tid * 0x9E3779B9) ^ ((ctr + 1) * 0x85EBCA6B)) & m
    x ^= x >> 16
    x = (x * 0x7FEB352D) & m
    x ^= x >> 15
    x = (x * 0x846CA68B) & m
    x ^= x >> 16
    return x * 2.0 ** -32


@dataclass(frozen=True)
class WorkloadRow:
    name: str
    wid: int
    time_varying: int          # 1 iff the row reads the current time
    hold: object               # callable, elementwise (see module comment)


def _hold_constant(is_ncs, base, expd, gate_off, tscale, burst):
    return base


def _hold_bursty(is_ncs, base, expd, gate_off, tscale, burst):
    # ON/OFF duty cycle as time-varying NCS (Fissile-style contention
    # burstiness): an OFF-phase thread's arrival gap stretches by `burst`;
    # CS lengths are untouched.
    return base * (1 + is_ncs * gate_off * (burst - 1))


def _hold_hetero(is_ncs, base, expd, gate_off, tscale, burst):
    # Heterogeneous threads (mixed decode lengths): every draw scaled by
    # the thread's persistent log-uniform factor in [1/spread, spread].
    return base * tscale


def _hold_jitter(is_ncs, base, expd, gate_off, tscale, burst):
    # Poisson-like arrivals: NCS becomes an exponential deviate with the
    # uniform row's mean, so arrival gaps are memoryless; CS stays uniform.
    return is_ncs * expd + (1 - is_ncs) * base


WORKLOAD_ROWS = {
    "constant": WorkloadRow("constant", WL_CONSTANT, 0, _hold_constant),
    "bursty": WorkloadRow("bursty", WL_BURSTY, 1, _hold_bursty),
    "hetero": WorkloadRow("hetero", WL_HETERO, 0, _hold_hetero),
    "jitter": WorkloadRow("jitter", WL_JITTER, 0, _hold_jitter),
}
assert sorted(r.wid for r in WORKLOAD_ROWS.values()) \
    == sorted(WORKLOAD_IDS.values())


def workload_hold(workload_id, is_ncs, base, expd, gate_off, tscale, burst):
    """Dispatch one hold-time draw by ``workload_id`` — the workload twin
    of :func:`oracle_update`'s masked select.  All candidate rows are
    finite and non-negative, so the arithmetic select is exact: a constant
    row's output is bit-identical to ``base``."""
    out = 0.0
    for row in WORKLOAD_ROWS.values():
        sel = (workload_id == row.wid) * 1.0
        out = out + sel * row.hold(is_ncs, base, expd, gate_off, tscale,
                                   burst)
    return out


def workload_thread_scale(spread_u, spread):
    """Persistent per-thread multiplier, log-uniform in
    ``[1/spread, spread]`` from the thread's spread uniform."""
    return spread ** (2.0 * spread_u - 1.0)


def workload_off_gate(now, phase_u, period, duty):
    """0/1: is a thread with duty-cycle phase ``phase_u`` in the OFF part
    of its ON/OFF cycle at time ``now``?  The cycle has length ``period``
    seconds with the first ``duty`` fraction ON; ``phase_u`` staggers the
    threads so a config's bursts overlap only partially."""
    pos = (now / period + phase_u) % 1.0
    return (pos >= duty) * 1.0


def workload_mean_scale(cfg) -> tuple[float, float]:
    """Expected ``(cs, ncs)`` mean-duration multipliers of a config's
    workload row — the horizon planner's correction
    (:func:`repro.core.xdes.plan_schedule`): a bursty row stretches the
    mean arrival gap to ``duty + (1-duty)·burst`` of the base, a hetero
    row stretches both draws by ``E[s^(2u-1)] = (s - 1/s)/(2 ln s)``;
    constant and jitter leave the means unchanged.  Exactly 1.0 for the
    constant row, so constant-workload plans are bit-identical."""
    import math

    wid = WORKLOAD_IDS[cfg.workload]
    if wid == WL_BURSTY:
        return 1.0, cfg.wl_duty + (1.0 - cfg.wl_duty) * cfg.wl_burst
    if wid == WL_HETERO:
        s = cfg.wl_spread
        m = 1.0 if s <= 1.0 else (s - 1.0 / s) / (2.0 * math.log(s))
        return m, m
    return 1.0, 1.0


# --------------------------------------------------------------------------
# Arrival rows — the OPEN-LOOP arrival process as data, mirroring
# WORKLOAD_ROWS.
#
# Everything before these rows is closed-loop: a fixed thread population
# circulates forever.  An arrival row turns a config open-loop: logical
# requests arrive at a (possibly time-varying) rate, wait in a bounded
# request queue, bind to a free simulated thread, contend under the
# config's DISCIPLINE_ROWS row, complete one critical section and depart
# — per-request latency is accumulated into on-device histogram columns
# (see docs/open_loop.md).
#
# A row's ``rate`` function maps the config's base rate to the
# instantaneous arrival rate; it is pure arithmetic on caller-precomputed
# inputs (the burst gate derives from the counter RNG under
# AR_PHASE_SALT, exactly like the workload rows' duty-cycle gate), so ONE
# implementation runs on Python floats (the DES twin), numpy arrays and
# traced jax values inside the kernels:
#
#   rate(base, gate_on, burst) -> requests/second
#     base     the config's ``arrival_rate``
#     gate_on  0/1: the config is inside the ON part of its burst cycle
#     burst    the ON-phase rate multiplier (reuses ``wl_burst``)
#
# Per step the engine admits ``floor(rate*dt)`` requests plus a Bernoulli
# trial on the fractional part (uniform from the counter RNG under
# AR_SALT), so the expected count is EXACTLY ``rate*dt`` at any dt.  The
# closed row has rate 0 and is bit-identical to the pre-open-loop engine
# (the masked select is exact and the open-loop state is only
# materialized when a batch contains an open config).
# --------------------------------------------------------------------------
AR_CLOSED, AR_POISSON, AR_BURSTY = range(3)

ARRIVAL_IDS = {
    "closed": AR_CLOSED,      # no external arrivals: the closed-loop engine
    "poisson": AR_POISSON,    # constant-rate memoryless arrivals
    "bursty": AR_BURSTY,      # ON/OFF rate modulation (wl_period/duty/burst)
}
ARRIVAL_NAMES = {v: k for k, v in ARRIVAL_IDS.items()}

#: Seed salts for the open-loop arrival streams (XOR-ed into the config
#: seed; disjoint from WL_PHASE_SALT/WL_SPREAD_SALT so the arrival
#: process never perturbs the workload draws).
AR_SALT = 0x94D049BB          # per-step Bernoulli-rounding uniforms
AR_PHASE_SALT = 0xBF58476D    # per-config burst-phase offset

#: Seed salt for the randomized same-step tie-break stream
#: (``SimConfig.tie_break="random"``).
TB_SALT = 0xD6E8FEB8

#: Same-step tie-break among equally-eligible spinners at handoff:
#: ``id`` keeps the historical deterministic thread-id order; ``random``
#: draws a fresh seeded key per (thread, step) — the DES resolves such
#: ties by RNG, so ``random`` closes that fidelity gap.
TIE_BREAK_IDS = {"id": 0, "random": 1}
TIE_BREAK_NAMES = {v: k for k, v in TIE_BREAK_IDS.items()}

#: Capacity of the on-device request ring buffer — ``queue_cap`` may not
#: exceed it (it is one Pallas lane: :data:`repro.kernels.lock_sim.LANE`).
QUEUE_MAX = 128


# --------------------------------------------------------------------------
# Fault rows — environment interference as data, mirroring WORKLOAD_ROWS
# and ARRIVAL_ROWS.
#
# The paper's whole case for hybrid waiting is adverse, *unknown*
# environments, yet the benign simulator never preempts a lock holder,
# never oversubscribes a core and never loses a wake-up.  A fault row is a
# named, seeded interference model dispatched per config by an integer id
# exactly like the other registries, so a single batched call can sweep a
# fault × discipline grid.
#
# Two elementwise hooks cover every row; both are pure arithmetic on
# caller-precomputed uniforms, so ONE implementation runs on Python floats
# (the DES twin), numpy arrays and traced jax values inside the kernels:
#
#   progress(is_holder, gate_u, rate) -> multiplier in [0, 1]
#     scales a running (CS/NCS) thread's progress inside the current
#     fault window.  ``is_holder`` is 0/1; ``gate_u`` is the persistent
#     per-(thread, window) uniform drawn under FLT_GATE_SALT.
#   wake_delay(wake, w1, w2, rate, scale) -> seconds
#     replaces the config's nominal wake latency for one wake-up.
#     ``w1``/``w2`` are per-(thread, step) uniforms under
#     FLT_WAKE_SALT / FLT_MAG_SALT.
#
# Rows (``fault_rate`` = intensity in [0, 1], ``fault_scale`` = the row's
# characteristic time in seconds):
#
#   none      no interference — bit-identical to the pre-fault engine
#             (the dispatch is an exact masked select and the engine
#             applies the progress hook through a ``where`` that is a
#             structural no-op when the give-back is zero).
#   preempt   lock-holder preemption: time is sliced into windows of
#             ``fault_scale`` seconds; with probability ``fault_rate``
#             per (thread, window) the thread is off-CPU for the whole
#             window — a descheduled *holder* stalls every waiter while
#             spinners keep burning CPU (the Fissile/Solaris regime).
#   oversub   CPU oversubscription: an interfering background load
#             steals a seeded fraction (up to ``fault_rate``) of every
#             running thread's cycles per window — uniform time-stealing
#             rather than whole-window blackouts.
#   lostwake  lost wake-ups: with probability ``fault_rate`` a wake-up
#             is dropped and the sleeper only recovers at its timeout,
#             ``fault_scale`` seconds (futex-miss / missed-signal model).
#   jitter    timer jitter: each wake-up is stretched by a uniform extra
#             delay in [0, ``fault_scale``) with probability
#             ``fault_rate`` (tickless-kernel / VM-scheduling noise).
#
# Spinning threads' CPU burn and the adaptive spin budget are deliberately
# NOT modulated: interference steals *progress*, while a spinner occupying
# a core keeps paying for it — which is exactly why sleep-leaning
# disciplines overtake pure spin under heavy preemption.
# --------------------------------------------------------------------------
FAULT_NONE, FAULT_PREEMPT, FAULT_OVERSUB, FAULT_LOSTWAKE, FAULT_JITTER = \
    range(5)

FAULT_IDS = {
    "none": FAULT_NONE,          # benign machine (the pre-fault engine)
    "preempt": FAULT_PREEMPT,    # lock-holder preemption windows
    "oversub": FAULT_OVERSUB,    # background load steals cycles
    "lostwake": FAULT_LOSTWAKE,  # dropped wake-ups + timeout recovery
    "jitter": FAULT_JITTER,      # wake-latency jitter
}
FAULT_NAMES = {v: k for k, v in FAULT_IDS.items()}

#: Seed salts for the fault streams (XOR-ed into the config seed;
#: disjoint from WL_PHASE_SALT/WL_SPREAD_SALT/AR_SALT/AR_PHASE_SALT/
#: TB_SALT so interference never perturbs workload, arrival or tie-break
#: draws).
FLT_GATE_SALT = 0xA3C59AC3    # per-(thread, fault-window) off-CPU gate
FLT_WAKE_SALT = 0xC2B2AE35    # per-(thread, step) wake-fault gate
FLT_MAG_SALT = 0x27220A95     # per-(thread, step) wake-jitter magnitude


@dataclass(frozen=True)
class FaultRow:
    name: str
    fid: int
    progress: object           # callable, elementwise (see module comment)
    wake_delay: object         # callable, elementwise


def _fault_progress_one(is_holder, gate_u, rate):
    return 1.0 + 0.0 * gate_u


def _fault_progress_preempt(is_holder, gate_u, rate):
    # The whole fault window is lost when the per-(thread, window) gate
    # fires — holders and waiters alike go off-CPU for the window.
    return 1.0 - (gate_u < rate) * 1.0


def _fault_progress_oversub(is_holder, gate_u, rate):
    # A background load steals a seeded fraction of the window's cycles.
    return 1.0 - rate * gate_u


def _fault_wake_nominal(wake, w1, w2, rate, scale):
    return wake + 0.0 * w1


def _fault_wake_lost(wake, w1, w2, rate, scale):
    # A dropped wake-up is recovered by the sleeper's timeout at `scale`.
    return wake + (w1 < rate) * (scale - wake)


def _fault_wake_jitter(wake, w1, w2, rate, scale):
    # With probability `rate` the wake-up lands up to `scale` late.
    return wake + (w1 < rate) * scale * w2


FAULT_ROWS = {
    "none": FaultRow("none", FAULT_NONE,
                     _fault_progress_one, _fault_wake_nominal),
    "preempt": FaultRow("preempt", FAULT_PREEMPT,
                        _fault_progress_preempt, _fault_wake_nominal),
    "oversub": FaultRow("oversub", FAULT_OVERSUB,
                        _fault_progress_oversub, _fault_wake_nominal),
    "lostwake": FaultRow("lostwake", FAULT_LOSTWAKE,
                         _fault_progress_one, _fault_wake_lost),
    "jitter": FaultRow("jitter", FAULT_JITTER,
                       _fault_progress_one, _fault_wake_jitter),
}
assert sorted(r.fid for r in FAULT_ROWS.values()) \
    == sorted(FAULT_IDS.values())


def fault_progress_scale(fault_id, is_holder, gate_u, rate):
    """Dispatch the per-window progress multiplier by ``fault_id`` — the
    fault twin of :func:`workload_hold`'s masked select.  Exactly 1.0 for
    the none row (every candidate is finite, the select is exact)."""
    out = 0.0
    for row in FAULT_ROWS.values():
        sel = (fault_id == row.fid) * 1.0
        out = out + sel * row.progress(is_holder, gate_u, rate)
    return out


def fault_wake_delay(fault_id, wake, w1, w2, rate, scale):
    """Dispatch the effective wake latency by ``fault_id``.  Bit-identical
    to ``wake`` for rows that do not perturb wake-ups."""
    out = 0.0
    for row in FAULT_ROWS.values():
        sel = (fault_id == row.fid) * 1.0
        out = out + sel * row.wake_delay(wake, w1, w2, rate, scale)
    return out

#: On-device latency histogram: ``LAT_NBINS`` log-spaced bins,
#: ``LAT_BINS_PER_OCTAVE`` per factor of two, starting at ``LAT_BIN0``
#: seconds — 64 bins at 2/octave span 1e-7 s .. ~4.6e2 s, wide enough for
#: µs spin cells and saturated 100µs-CS queues alike.
LAT_NBINS = 64
LAT_BIN0 = 1e-7
LAT_BINS_PER_OCTAVE = 2


@dataclass(frozen=True)
class ArrivalRow:
    name: str
    aid: int
    time_varying: int          # 1 iff the rate reads the current time
    rate: object               # callable, elementwise (see module comment)


def _rate_closed(base, gate_on, burst):
    return base * 0.0


def _rate_poisson(base, gate_on, burst):
    return base * 1.0


def _rate_bursty(base, gate_on, burst):
    # ON/OFF rate modulation: `burst` times the base rate inside the ON
    # window (the first `wl_duty` fraction of each `wl_period` cycle,
    # phase-staggered per config under AR_PHASE_SALT).
    return base * (1.0 + gate_on * (burst - 1.0))


ARRIVAL_ROWS = {
    "closed": ArrivalRow("closed", AR_CLOSED, 0, _rate_closed),
    "poisson": ArrivalRow("poisson", AR_POISSON, 0, _rate_poisson),
    "bursty": ArrivalRow("bursty", AR_BURSTY, 1, _rate_bursty),
}
assert sorted(r.aid for r in ARRIVAL_ROWS.values()) \
    == sorted(ARRIVAL_IDS.values())


def arrival_rate_at(arrival_id, base, gate_on, burst):
    """Dispatch the instantaneous arrival rate by ``arrival_id`` — the
    arrival twin of :func:`workload_hold`'s masked select.  Exact for the
    closed row (rate 0 regardless of base)."""
    out = 0.0
    for row in ARRIVAL_ROWS.values():
        sel = (arrival_id == row.aid) * 1.0
        out = out + sel * row.rate(base, gate_on, burst)
    return out


def arrival_mean_scale(arrival_id, duty, burst):
    """Time-averaged multiplier of the base rate for a row: 0 for closed,
    1 for poisson, ``1 + duty*(burst-1)`` for bursty.  Elementwise — the
    DES twin and saturation math (catalog) share it."""
    closed = (arrival_id == AR_CLOSED) * 1.0
    bursty = (arrival_id == AR_BURSTY) * 1.0
    return (1.0 - closed) * (1.0 + bursty * duty * (burst - 1.0))


def latency_bin_edges():
    """The ``LAT_NBINS + 1`` histogram bin edges in seconds (float64).
    Bin ``i`` covers ``[edges[i], edges[i+1])``; the first and last bins
    additionally absorb underflow/overflow (the kernel clips)."""
    import numpy as np

    return LAT_BIN0 * 2.0 ** (np.arange(LAT_NBINS + 1, dtype=np.float64)
                              / LAT_BINS_PER_OCTAVE)


def latency_percentiles(hist, qs=(0.50, 0.95, 0.99)):
    """Per-config latency percentiles from ``(..., LAT_NBINS)`` histogram
    counts: the geometric midpoint of the bin containing each quantile
    (the histogram is the exact on-device record; within-bin position is
    unknowable, so the midpoint is the canonical readout — bins are a
    factor sqrt(2) wide).  Returns one array per ``q``; NaN where no
    request departed."""
    import numpy as np

    hist = np.asarray(hist, np.int64)
    edges = latency_bin_edges()
    mids = np.sqrt(edges[:-1] * edges[1:])
    tot = hist.sum(axis=-1)
    cum = np.cumsum(hist, axis=-1)
    out = []
    for q in qs:
        target = np.ceil(q * np.maximum(tot, 1)).astype(np.int64)[..., None]
        idx = np.argmax(cum >= target, axis=-1)
        out.append(np.where(tot > 0, mids[idx], np.nan))
    return out


# --------------------------------------------------------------------------
# Scenario description — the unit of the batched sweep
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class SimConfig:
    """One ``(lock, threads, cores, cs, ncs, wake_latency, alpha)`` cell.

    The event-driven DES consumes these through :func:`repro.core.des.
    simulate`; the batched backend encodes a list of them into
    struct-of-arrays form (:func:`encode_configs`) and simulates all of
    them in one device program.
    """

    lock: str
    threads: int
    cores: int
    cs: tuple[float, float]
    ncs: tuple[float, float]
    wake_latency: float = 8e-6
    alpha: float | None = None          # None -> DEFAULT_ALPHA[lock]
    sws_init: int = 1
    sws_max: int | None = None          # None -> cores (paper default)
    k: int = 10
    spin_budget: float = DEFAULT_SPIN_BUDGET
    seed: int = 0
    oracle: str = "paper"               # SWS adaptation family (ORACLE_IDS)
    workload: str = "constant"          # hold-time model (WORKLOAD_IDS)
    wl_period: float = 1e-4             # bursty ON/OFF cycle length (s)
    wl_duty: float = 0.25               # ON fraction of the cycle
    wl_burst: float = 8.0               # OFF-phase NCS stretch factor
    wl_spread: float = 4.0              # hetero per-thread scale spread
    arrival_phase: float = 0.0          # seeded arrival-order offset
    #                                     (fraction of the mean NCS)
    arrival: str = "closed"             # open-loop arrival row (ARRIVAL_IDS)
    arrival_rate: float = 0.0           # base arrival rate (requests/s)
    queue_cap: int = QUEUE_MAX          # bounded request queue (<= QUEUE_MAX)
    slo: float = 1e-3                   # per-request latency SLO (seconds)
    tie_break: str = "id"               # same-step tie-break (TIE_BREAK_IDS)
    fault: str = "none"                 # interference row (FAULT_IDS)
    fault_rate: float = 0.0             # interference intensity in [0, 1]
    fault_scale: float = 5e-5           # fault window / timeout (seconds)
    park_cost: float = 1.0              # M:N environment axis: multiplies
    #                                     the sleep/wake round-trip (green
    #                                     threads << 1, kernel threads 1,
    #                                     oversubscribed VMs >> 1)

    def __post_init__(self):
        if self.lock not in POLICY_IDS:
            raise ValueError(f"unknown lock {self.lock!r}; "
                             f"options: {sorted(POLICY_IDS)}")
        if self.threads < 1 or self.cores < 1:
            raise ValueError("threads and cores must be >= 1")
        if self.oracle not in ORACLE_IDS:
            raise ValueError(f"unknown oracle {self.oracle!r}; "
                             f"options: {sorted(ORACLE_IDS)}")
        if self.workload not in WORKLOAD_IDS:
            raise ValueError(f"unknown workload {self.workload!r}; "
                             f"options: {sorted(WORKLOAD_IDS)}")
        if self.wl_period <= 0 or not (0.0 < self.wl_duty <= 1.0):
            raise ValueError("wl_period must be > 0 and wl_duty in (0, 1]")
        if self.wl_burst < 1.0 or self.wl_spread < 1.0:
            raise ValueError("wl_burst and wl_spread must be >= 1")
        if self.arrival_phase < 0.0:
            raise ValueError("arrival_phase must be >= 0")
        if self.arrival not in ARRIVAL_IDS:
            raise ValueError(f"unknown arrival {self.arrival!r}; "
                             f"options: {sorted(ARRIVAL_IDS)}")
        if self.arrival_rate < 0.0:
            raise ValueError("arrival_rate must be >= 0")
        if not (1 <= self.queue_cap <= QUEUE_MAX):
            raise ValueError(f"queue_cap must be in [1, {QUEUE_MAX}]")
        if self.slo <= 0.0:
            raise ValueError("slo must be > 0")
        if self.tie_break not in TIE_BREAK_IDS:
            raise ValueError(f"unknown tie_break {self.tie_break!r}; "
                             f"options: {sorted(TIE_BREAK_IDS)}")
        if self.fault not in FAULT_IDS:
            raise ValueError(f"unknown fault {self.fault!r}; "
                             f"options: {sorted(FAULT_IDS)}")
        if not (0.0 <= self.fault_rate <= 1.0):
            raise ValueError("fault_rate must be in [0, 1]")
        if self.fault_scale <= 0.0:
            raise ValueError("fault_scale must be > 0")
        if self.park_cost <= 0.0:
            raise ValueError("park_cost must be > 0")

    # -- derived quantities shared by both backends -----------------------
    @property
    def alpha_eff(self) -> float:
        return DEFAULT_ALPHA[self.lock] if self.alpha is None else self.alpha

    @property
    def sws_max_eff(self) -> int:
        return self.cores if self.sws_max is None else self.sws_max

    @property
    def sws_start(self) -> int:
        """Initial window per discipline under the unified A7 rule:
        spin/adaptive disciplines never sleep on arrival (window = threads),
        the sleep lock parks every waiter (window = 1), the mutable lock
        starts at ``sws_init``."""
        pid = POLICY_IDS[self.lock]
        if pid == SLEEP:
            return 1
        if pid in (MUTABLE, FISSILE):
            return max(1, min(self.sws_init, self.sws_max_eff))
        return self.threads             # tas/ttas/mcs/adaptive/fifo/hapax/bo

    def des_kwargs(self) -> dict:
        """Keyword form consumed by :func:`repro.core.des.simulate`."""
        kw: dict = {}
        if self.alpha is not None:
            kw["alpha"] = self.alpha
        if self.lock in ("mutable", "fissile"):
            from .oracle import make_oracle

            kw.update(initial_sws=self.sws_init, max_sws=self.sws_max,
                      oracle=make_oracle(self.oracle, k=self.k))
        if self.lock in ("adaptive", "fissile", "ttas_backoff"):
            kw["spin_budget"] = self.spin_budget
        return kw

    def workload_kwargs(self) -> dict:
        """Workload keywords consumed by :class:`repro.core.des.LockSim`
        (the event-driven twin of the workload rows)."""
        return dict(workload=self.workload, wl_period=self.wl_period,
                    wl_duty=self.wl_duty, wl_burst=self.wl_burst,
                    wl_spread=self.wl_spread,
                    arrival_phase=self.arrival_phase)

    @property
    def open_loop(self) -> bool:
        """True iff this config runs the open-loop arrival engine."""
        return ARRIVAL_IDS[self.arrival] != AR_CLOSED

    def arrival_kwargs(self) -> dict:
        """Open-loop keywords consumed by :class:`repro.core.des.LockSim`
        (the event-driven twin of the arrival rows)."""
        return dict(arrival=self.arrival, arrival_rate=self.arrival_rate,
                    queue_cap=self.queue_cap)

    def fault_kwargs(self) -> dict:
        """Fault keywords consumed by :class:`repro.core.des.LockSim`
        (the event-driven twin of the fault rows)."""
        return dict(fault=self.fault, fault_rate=self.fault_rate,
                    fault_scale=self.fault_scale)

    def env_kwargs(self) -> dict:
        """Environment keywords consumed by :class:`repro.core.des.LockSim`
        (the M:N parking axis)."""
        return dict(park_cost=self.park_cost)


def workload_mean_scale_columns(workload, wl_duty, wl_burst, wl_spread):
    """Vectorized twin of :func:`workload_mean_scale` over (C,) columns.

    ``workload`` is an integer-id array; the float columns are taken in
    float64 so the arithmetic matches the scalar (Python-float) path.
    Returns ``(cs_scale, ncs_scale)`` float64 arrays.
    """
    import numpy as np

    wid = np.asarray(workload)
    duty = np.asarray(wl_duty, np.float64)
    burst = np.asarray(wl_burst, np.float64)
    s = np.asarray(wl_spread, np.float64)
    cs = np.ones(wid.shape, np.float64)
    ncs = np.ones(wid.shape, np.float64)
    ncs = np.where(wid == WL_BURSTY, duty + (1.0 - duty) * burst, ncs)
    ss = np.where(s <= 1.0, 2.0, s)          # dummy where the log is unused
    m = np.where(s <= 1.0, 1.0, (ss - 1.0 / ss) / (2.0 * np.log(ss)))
    het = wid == WL_HETERO
    return np.where(het, m, cs), np.where(het, m, ncs)


#: Column order of the struct-of-arrays encoding (see encode_configs).
CONFIG_FIELDS = (
    "policy", "threads", "cores", "cs_lo", "cs_hi", "ncs_lo", "ncs_hi",
    "wake", "alpha", "sws_init", "sws_max", "k", "spin_budget", "seed",
    "oracle", "workload", "wl_period", "wl_duty", "wl_burst", "wl_spread",
    "arrival_phase", "arrival", "arr_rate", "q_cap", "slo", "tb",
    "fault", "flt_rate", "flt_scale", "park_cost",
)

#: Column order of the RAW (pre-encoding) struct-of-arrays form — the
#: array-native interchange format emitted by the catalog's column
#: generators and consumed by :func:`encode_columns` and the streaming
#: sweep.  Values keep SimConfig semantics and full float64 precision:
#: ``lock``/``oracle``/``workload`` are integer ids (or name strings),
#: ``alpha`` uses NaN for "default for this lock", ``sws_max`` uses -1
#: for "default (= cores)".
RAW_CONFIG_FIELDS = (
    "lock", "threads", "cores", "cs_lo", "cs_hi", "ncs_lo", "ncs_hi",
    "wake_latency", "alpha", "sws_init", "sws_max", "k", "spin_budget",
    "seed", "oracle", "workload", "wl_period", "wl_duty", "wl_burst",
    "wl_spread", "arrival_phase", "arrival", "arrival_rate", "queue_cap",
    "slo", "tie_break", "fault", "fault_rate", "fault_scale", "park_cost",
)

#: Defaults for the RAW open-loop columns — column producers written
#: before the open-loop engine may omit them; :func:`encode_columns`
#: fills these in (the closed defaults, bit-identical to the
#: pre-open-loop encoding).
RAW_OPEN_DEFAULTS = {
    "arrival": AR_CLOSED, "arrival_rate": 0.0, "queue_cap": QUEUE_MAX,
    "slo": 1e-3, "tie_break": 0,
}

#: Defaults for the RAW fault columns — same contract as
#: :data:`RAW_OPEN_DEFAULTS`: column producers written before the fault
#: rows may omit them and get the benign machine, bit-identical to the
#: pre-fault encoding.
RAW_FAULT_DEFAULTS = {
    "fault": FAULT_NONE, "fault_rate": 0.0, "fault_scale": 5e-5,
}

#: Defaults for the RAW environment columns — same contract: column
#: producers written before the M:N parking axis get 1:1 kernel threads,
#: bit-identical to the pre-park_cost encoding.
RAW_ENV_DEFAULTS = {
    "park_cost": 1.0,
}


def _ids_from(values, table, what: str):
    """Map an array/sequence of names or ids onto int32 ids (without ever
    materializing a numpy unicode array — the dict lookup is the fast
    path for name sequences)."""
    import numpy as np

    if isinstance(values, np.ndarray) and values.dtype.kind in "iu":
        return values.astype(np.int32)
    seq = values.tolist() if isinstance(values, np.ndarray) \
        else list(values)
    if seq and isinstance(seq[0], (int, np.integer)):
        return np.asarray(seq, np.int32)
    try:
        return np.fromiter((table[v] for v in seq), np.int32, len(seq))
    except KeyError as e:
        raise ValueError(f"unknown {what} {e.args[0]!r}; "
                         f"options: {sorted(table)}") from None


def config_columns(configs) -> dict:
    """Extract a list of :class:`SimConfig` into RAW struct-of-arrays form
    (:data:`RAW_CONFIG_FIELDS`) in ONE attribute pass — no per-field
    lambdas, no property calls.  Float columns keep float64 precision so
    downstream planning (:func:`repro.core.xdes.plan_schedule`) matches
    the per-object path exactly."""
    import operator

    import numpy as np

    configs = list(configs)
    if not configs:
        raise ValueError("empty config batch")
    get = operator.attrgetter(
        "lock", "threads", "cores", "cs", "ncs", "wake_latency", "alpha",
        "sws_init", "sws_max", "k", "spin_budget", "seed", "oracle",
        "workload", "wl_period", "wl_duty", "wl_burst", "wl_spread",
        "arrival_phase", "arrival", "arrival_rate", "queue_cap", "slo",
        "tie_break", "fault", "fault_rate", "fault_scale", "park_cost")
    (lock, threads, cores, cs, ncs, wake, alpha, sws_init, sws_max, k,
     spin_budget, seed, oracle, workload, wl_period, wl_duty, wl_burst,
     wl_spread, arrival_phase, arrival, arrival_rate, queue_cap, slo,
     tie_break, fault, fault_rate, fault_scale,
     park_cost) = zip(*map(get, configs))
    n = len(configs)
    cs = np.asarray(cs, np.float64)
    ncs = np.asarray(ncs, np.float64)
    return {
        "lock": _ids_from(lock, POLICY_IDS, "lock"),
        "threads": np.asarray(threads, np.int64).astype(np.int32),
        "cores": np.asarray(cores, np.int64).astype(np.int32),
        "cs_lo": cs[:, 0], "cs_hi": cs[:, 1],
        "ncs_lo": ncs[:, 0], "ncs_hi": ncs[:, 1],
        "wake_latency": np.asarray(wake, np.float64),
        "alpha": np.fromiter((np.nan if a is None else a for a in alpha),
                             np.float64, n),
        "sws_init": np.asarray(sws_init, np.int64).astype(np.int32),
        "sws_max": np.fromiter((-1 if s is None else s for s in sws_max),
                               np.int64, n).astype(np.int32),
        "k": np.asarray(k, np.int64).astype(np.int32),
        "spin_budget": np.asarray(spin_budget, np.float64),
        "seed": np.asarray(seed, np.int64).astype(np.uint32),
        "oracle": _ids_from(oracle, ORACLE_IDS, "oracle"),
        "workload": _ids_from(workload, WORKLOAD_IDS, "workload"),
        "wl_period": np.asarray(wl_period, np.float64),
        "wl_duty": np.asarray(wl_duty, np.float64),
        "wl_burst": np.asarray(wl_burst, np.float64),
        "wl_spread": np.asarray(wl_spread, np.float64),
        "arrival_phase": np.asarray(arrival_phase, np.float64),
        "arrival": _ids_from(arrival, ARRIVAL_IDS, "arrival"),
        "arrival_rate": np.asarray(arrival_rate, np.float64),
        "queue_cap": np.asarray(queue_cap, np.int64).astype(np.int32),
        "slo": np.asarray(slo, np.float64),
        "tie_break": _ids_from(tie_break, TIE_BREAK_IDS, "tie_break"),
        "fault": _ids_from(fault, FAULT_IDS, "fault"),
        "fault_rate": np.asarray(fault_rate, np.float64),
        "fault_scale": np.asarray(fault_scale, np.float64),
        "park_cost": np.asarray(park_cost, np.float64),
    }


def _validate_columns(cols, C: int) -> None:
    """Vectorized mirror of ``SimConfig.__post_init__`` for column inputs
    that never passed through the dataclass; names the first offending
    row."""
    import numpy as np

    def bad(mask, msg):
        idx = np.nonzero(np.asarray(mask))[0]
        if idx.size:
            raise ValueError(f"config column row {int(idx[0])}: {msg}")

    bad((cols["lock"] < 0) | (cols["lock"] >= len(POLICY_IDS)),
        f"unknown lock id; options: {sorted(POLICY_IDS.values())}")
    bad((cols["oracle"] < 0) | (cols["oracle"] >= len(ORACLE_IDS)),
        f"unknown oracle id; options: {sorted(ORACLE_IDS.values())}")
    bad((cols["workload"] < 0) | (cols["workload"] >= len(WORKLOAD_IDS)),
        f"unknown workload id; options: {sorted(WORKLOAD_IDS.values())}")
    bad((cols["threads"] < 1) | (cols["cores"] < 1),
        "threads and cores must be >= 1")
    bad(cols["wl_period"] <= 0, "wl_period must be > 0")
    bad((cols["wl_duty"] <= 0) | (cols["wl_duty"] > 1),
        "wl_duty must be in (0, 1] "
        "(pass strict=False to clamp out-of-range sweep columns)")
    bad((cols["wl_burst"] < 1) | (cols["wl_spread"] < 1),
        "wl_burst and wl_spread must be >= 1")
    bad(cols["arrival_phase"] < 0, "arrival_phase must be >= 0")
    bad((cols["arrival"] < 0) | (cols["arrival"] >= len(ARRIVAL_IDS)),
        f"unknown arrival id; options: {sorted(ARRIVAL_IDS.values())}")
    bad(cols["arrival_rate"] < 0,
        "arrival_rate must be >= 0 "
        "(pass strict=False to clamp out-of-range sweep columns)")
    bad((cols["queue_cap"] < 1) | (cols["queue_cap"] > QUEUE_MAX),
        f"queue_cap must be in [1, {QUEUE_MAX}] "
        "(pass strict=False to clamp out-of-range sweep columns)")
    bad(cols["slo"] <= 0, "slo must be > 0")
    bad((cols["tie_break"] < 0)
        | (cols["tie_break"] >= len(TIE_BREAK_IDS)),
        f"unknown tie_break id; options: {sorted(TIE_BREAK_IDS.values())}")
    bad((cols["fault"] < 0) | (cols["fault"] >= len(FAULT_IDS)),
        f"unknown fault id; options: {sorted(FAULT_IDS.values())}")
    bad((cols["fault_rate"] < 0) | (cols["fault_rate"] > 1),
        "fault_rate must be in [0, 1]")
    bad(cols["fault_scale"] <= 0, "fault_scale must be > 0")
    bad(cols["park_cost"] <= 0, "park_cost must be > 0")


#: DEFAULT_ALPHA indexed by policy id (the vectorized alpha_eff lookup).
def _alpha_by_id():
    import numpy as np

    return np.asarray([DEFAULT_ALPHA[POLICY_NAMES[i]]
                       for i in range(len(POLICY_IDS))], np.float64)


def encode_columns(cols, validate: bool = True, strict: bool = True) -> dict:
    """Encode RAW struct-of-arrays columns (:data:`RAW_CONFIG_FIELDS`;
    scalars broadcast, name strings accepted for the id columns) into the
    engine's :data:`CONFIG_FIELDS` form — the fully array-native path the
    streaming sweep feeds 100k+-config catalogs through.  Output is
    bit-identical to ``encode_configs`` of the equivalent
    :class:`SimConfig` list (same float64 -> float32 rounding, same
    derived ``alpha``/``sws_init``/``sws_max`` rules).

    Out-of-range values raise an actionable :class:`ValueError` naming the
    offending row.  ``strict=False`` instead clamps the continuous sweep
    knobs (``arrival_rate`` to >= 0, ``queue_cap`` to [1, QUEUE_MAX],
    ``wl_duty`` to (0, 1]) so mechanically-generated grids survive edge
    cells; discrete ids are never clamped."""
    import numpy as np

    cols = dict(cols)
    for f, v in RAW_OPEN_DEFAULTS.items():
        cols.setdefault(f, v)
    for f, v in RAW_FAULT_DEFAULTS.items():
        cols.setdefault(f, v)
    for f, v in RAW_ENV_DEFAULTS.items():
        cols.setdefault(f, v)
    for key, table, what in (("lock", POLICY_IDS, "lock"),
                             ("oracle", ORACLE_IDS, "oracle"),
                             ("workload", WORKLOAD_IDS, "workload"),
                             ("arrival", ARRIVAL_IDS, "arrival"),
                             ("tie_break", TIE_BREAK_IDS, "tie_break"),
                             ("fault", FAULT_IDS, "fault")):
        v = cols[key]
        if isinstance(v, str):
            cols[key] = table.get(v)
            if cols[key] is None:
                raise ValueError(f"unknown {what} {v!r}; "
                                 f"options: {sorted(table)}")
        elif not np.asarray(v).dtype.kind in "iu":
            cols[key] = _ids_from(v, table, what)
    C = max(np.size(cols[f]) for f in RAW_CONFIG_FIELDS if f in cols)
    full = {f: np.broadcast_to(np.asarray(cols[f]), (C,))
            for f in RAW_CONFIG_FIELDS}
    if not strict:
        full["arrival_rate"] = np.maximum(full["arrival_rate"], 0.0)
        full["queue_cap"] = np.clip(full["queue_cap"], 1, QUEUE_MAX)
        full["wl_duty"] = np.clip(full["wl_duty"],
                                  np.finfo(np.float64).tiny, 1.0)
    if validate:
        _validate_columns(full, C)

    lock = full["lock"].astype(np.int32)
    threads = full["threads"].astype(np.int32)
    cores = full["cores"].astype(np.int64)
    alpha = full["alpha"].astype(np.float64)
    alpha = np.where(np.isnan(alpha), _alpha_by_id()[lock], alpha)
    sws_max_eff = np.where(full["sws_max"] < 0, cores,
                           full["sws_max"]).astype(np.int64)
    # sws_start per discipline (the SimConfig.sws_start rule, vectorized)
    sws_start = np.where(
        lock == SLEEP, 1,
        np.where((lock == MUTABLE) | (lock == FISSILE),
                 np.clip(full["sws_init"], 1, np.maximum(sws_max_eff, 1)),
                 threads)).astype(np.int32)
    f32 = lambda key: full[key].astype(np.float32)
    return {
        "policy": lock,
        "threads": threads,
        "cores": cores.astype(np.float32),
        "cs_lo": f32("cs_lo"), "cs_hi": f32("cs_hi"),
        "ncs_lo": f32("ncs_lo"), "ncs_hi": f32("ncs_hi"),
        "wake": f32("wake_latency"),
        "alpha": alpha.astype(np.float32),
        "sws_init": sws_start,
        "sws_max": np.maximum(sws_max_eff, sws_start).astype(np.int32),
        "k": full["k"].astype(np.int32),
        "spin_budget": f32("spin_budget"),
        "seed": full["seed"].astype(np.uint32),
        "oracle": full["oracle"].astype(np.int32),
        "workload": full["workload"].astype(np.int32),
        "wl_period": f32("wl_period"), "wl_duty": f32("wl_duty"),
        "wl_burst": f32("wl_burst"), "wl_spread": f32("wl_spread"),
        "arrival_phase": f32("arrival_phase"),
        "arrival": full["arrival"].astype(np.int32),
        "arr_rate": f32("arrival_rate"),
        "q_cap": full["queue_cap"].astype(np.int32),
        "slo": f32("slo"),
        "tb": full["tie_break"].astype(np.int32),
        "fault": full["fault"].astype(np.int32),
        "flt_rate": f32("fault_rate"),
        "flt_scale": f32("fault_scale"),
        "park_cost": f32("park_cost"),
    }


def encode_configs(configs, strict: bool = True) -> dict:
    """Encode a batch of configs as struct-of-arrays (numpy).

    Accepts either a list of :class:`SimConfig` or a RAW column mapping
    (:data:`RAW_CONFIG_FIELDS`, as emitted by the catalog's ``*_columns``
    generators).  The result is the array program's input: every column
    has length ``C``; dtypes are int32 for discrete fields and float32
    for durations/rates.  ``policy`` uses the shared ids above, so the
    batched simulator and the Pallas kernel can branch with ``where``
    masks.

    Vectorized: column inputs go straight through numpy column math
    (:func:`encode_columns`, no per-config Python at all — the 100k+
    streaming path); object lists take one attribute pass
    (:func:`config_columns`) first.  Output is bit-identical to
    :func:`encode_configs_legacy`, the pre-streaming per-field
    implementation kept as the equality/bench baseline.
    """
    if isinstance(configs, dict):
        return encode_columns(configs, strict=strict)
    return encode_columns(config_columns(configs), validate=False)


def encode_configs_legacy(configs) -> dict:
    """The per-lambda baseline implementation of :func:`encode_configs`
    (one list comprehension per column, a Python lambda + property call
    per config per field).  Kept for the vectorized-equality tests and as
    the perf_bench speedup baseline — new code should call
    :func:`encode_configs`."""
    import numpy as np

    configs = list(configs)
    if not configs:
        raise ValueError("empty config batch")

    def col(fn, dtype):
        return np.asarray([fn(c) for c in configs], dtype=dtype)

    return {
        "policy": col(lambda c: POLICY_IDS[c.lock], np.int32),
        "threads": col(lambda c: c.threads, np.int32),
        "cores": col(lambda c: c.cores, np.float32),
        "cs_lo": col(lambda c: c.cs[0], np.float32),
        "cs_hi": col(lambda c: c.cs[1], np.float32),
        "ncs_lo": col(lambda c: c.ncs[0], np.float32),
        "ncs_hi": col(lambda c: c.ncs[1], np.float32),
        "wake": col(lambda c: c.wake_latency, np.float32),
        "alpha": col(lambda c: c.alpha_eff, np.float32),
        "sws_init": col(lambda c: c.sws_start, np.int32),
        "sws_max": col(lambda c: max(c.sws_max_eff, c.sws_start), np.int32),
        "k": col(lambda c: c.k, np.int32),
        "spin_budget": col(lambda c: c.spin_budget, np.float32),
        "seed": col(lambda c: c.seed, np.uint32),
        "oracle": col(lambda c: ORACLE_IDS[c.oracle], np.int32),
        "workload": col(lambda c: WORKLOAD_IDS[c.workload], np.int32),
        "wl_period": col(lambda c: c.wl_period, np.float32),
        "wl_duty": col(lambda c: c.wl_duty, np.float32),
        "wl_burst": col(lambda c: c.wl_burst, np.float32),
        "wl_spread": col(lambda c: c.wl_spread, np.float32),
        "arrival_phase": col(lambda c: c.arrival_phase, np.float32),
        "arrival": col(lambda c: ARRIVAL_IDS[c.arrival], np.int32),
        "arr_rate": col(lambda c: c.arrival_rate, np.float32),
        "q_cap": col(lambda c: c.queue_cap, np.int32),
        "slo": col(lambda c: c.slo, np.float32),
        "tb": col(lambda c: TIE_BREAK_IDS[c.tie_break], np.int32),
        "fault": col(lambda c: FAULT_IDS[c.fault], np.int32),
        "flt_rate": col(lambda c: c.fault_rate, np.float32),
        "flt_scale": col(lambda c: c.fault_scale, np.float32),
        "park_cost": col(lambda c: c.park_cost, np.float32),
    }

"""Continuous-batching scheduler driven by the paper's spinning window.

Mapping (paper → serving), per DESIGN.md §3.2:

    spinner                  → standby request (prefilled ahead, KV resident)
    sleeper                  → queued request (cold, costless)
    critical section         → a decode slot becoming free
    OS wake-up latency       → prefill latency on promotion
    "slept and not spun"     → a slot freed with NO standby ready → the next
                               request pays its prefill in the open (late wake)
    sws                      → standby-pool target size
    EvalSWS                  → grow pool ×2 on a late wake; shrink by 1 after
                               K clean handoffs

The scheduler is engine-agnostic (real :class:`DecodeEngine` or
:class:`SimulatedEngine`) and exposes the spin/sleep trade-off as metrics:
*handoff latency* (responsiveness) vs *standby KV residency* (resource
waste) — the serving twins of the paper's CS-access latency vs spin CPU.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from dataclasses import replace as dataclass_replace

from repro.core.oracle import EvalSWS, FixedOracle, Oracle
from repro.core.policy import QUEUE_MAX, SimConfig
from repro.core.window import SpinningWindow

from .engine import Request


@dataclass
class SchedStats:
    steps: int = 0
    handoffs: int = 0
    late_handoffs: int = 0            # slot freed, no standby ready
    completed: int = 0
    standby_residency: float = 0.0    # sum over steps of standby pool size
    queue_wait_steps: float = 0.0     # sum over steps of queue length
    slot_idle_steps: float = 0.0      # occupied-capacity shortfall
    submitted: int = 0                # offered requests (admitted + shed)
    shed: int = 0                     # rejected at the full queue
    window_trace: list = field(default_factory=list)

    def summary(self) -> dict:
        s = max(1, self.steps)
        return {
            "steps": self.steps,
            "completed": self.completed,
            "handoffs": self.handoffs,
            "late_handoff_rate": self.late_handoffs / max(1, self.handoffs),
            "avg_standby": self.standby_residency / s,
            "avg_queue": self.queue_wait_steps / s,
            "avg_slot_idle": self.slot_idle_steps / s,
            "submitted": self.submitted,
            "shed": self.shed,
            "shed_rate": self.shed / max(1, self.submitted),
        }


class ContinuousBatcher:
    """Admission + standby control for a slot-based decode engine.

    ``window.sws`` is the *standby-pool target*: how many queued requests to
    keep prefilled-ahead (hot).  ``oracle=None`` uses the paper's EvalSWS;
    pass :class:`FixedOracle` with ``initial`` for the static ablations
    (0 = pure sleep-lock behaviour, ``max`` = pure spin-lock behaviour).
    """

    def __init__(self, engine, max_standby: int | None = None,
                 initial: int = 1, oracle: Oracle | None = None,
                 k: int = 10, min_standby: int | None = None,
                 queue_cap: int | None = None):
        self.engine = engine
        #: open-loop admission bound: submissions past a full queue are
        #: shed (None = unbounded, the closed-loop legacy behaviour)
        self.queue_cap = queue_cap
        max_standby = max_standby or max(1, engine.max_slots)
        if min_standby is None:
            # static-zero ablation: a FixedOracle with initial=0 means
            # "never keep standby" (the pure sleep-lock analogue).  The
            # adaptive oracle keeps the paper's sws >= 1 clamp (doubling
            # from 0 could never grow).
            min_standby = 0 if (initial == 0
                                and isinstance(oracle, FixedOracle)) else 1
        self.window = SpinningWindow(
            max_size=max_standby, initial=initial, min_size=min_standby,
            oracle=oracle if oracle is not None else EvalSWS(k=k))
        self.queue: deque[Request] = deque()
        self.standby: deque[tuple[Request, object, int]] = deque()
        self.stats = SchedStats()

    @classmethod
    def from_policy(cls, engine, policy: str, max_standby: int | None = None,
                    k: int = 10) -> "ContinuousBatcher":
        """Build a batcher from a named admission policy.

        ``mutable`` — the paper's EvalSWS window (self-tuned standby pool);
        ``sleep``/``zero`` — never keep standby (pure sleep-lock analogue);
        ``spin``/``max`` — standby pool pinned at the maximum (pure
        spin-lock analogue).  Mirrors the lock registry in
        :mod:`repro.core.policy` so benchmarks and serving configs name
        disciplines consistently.
        """
        cap = max(1, engine.max_slots) if max_standby is None else max_standby
        if policy == "mutable":
            return cls(engine, max_standby=cap, initial=1, oracle=EvalSWS(k=k))
        if policy in ("sleep", "zero"):
            return cls(engine, max_standby=cap, initial=0,
                       oracle=FixedOracle())
        if policy in ("spin", "max"):
            return cls(engine, max_standby=cap, initial=cap,
                       oracle=FixedOracle())
        raise ValueError(f"unknown admission policy {policy!r}; "
                         "options: mutable|sleep|zero|spin|max")

    # -- client API ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit ``req`` (True) or shed it at a full queue (False).

        Admission reads the queue depth against ``queue_cap`` — the
        scheduler twin of the engine's bounded request ring: under an
        open-loop arrival process, offered load past saturation is shed
        here instead of growing the queue without bound."""
        self.stats.submitted += 1
        if (self.queue_cap is not None
                and len(self.queue) + len(self.standby) >= self.queue_cap):
            self.stats.shed += 1
            return False
        self.queue.append(req)
        return True

    def pending(self) -> int:
        return len(self.queue) + len(self.standby)

    def active(self) -> int:
        return int(self.engine.occupied.sum())

    def idle(self) -> bool:
        return not self.queue and not self.standby and self.active() == 0

    # -- internals ------------------------------------------------------------
    def _prefill_one(self) -> None:
        req = self.queue.popleft()
        first_tok, cache1 = self.engine.prefill(req.prompt)
        self.standby.append((req, cache1, first_tok))

    def _fill_standby(self) -> None:
        """Keep the hot pool at the window target (spinners)."""
        while self.queue and len(self.standby) < self.window.sws:
            self._prefill_one()

    def _handoff(self, slot: int) -> bool:
        """Slot freed → promote.  Returns True if the handoff was late."""
        late = False
        if self.standby:
            req, cache1, tok = self.standby.popleft()
        elif self.queue:
            late = True                     # pays prefill in the open
            self._prefill_one()
            req, cache1, tok = self.standby.popleft()
        else:
            return False
        self.engine.insert(slot, cache1, len(req.prompt), tok, req)
        self.stats.handoffs += 1
        self.stats.late_handoffs += late
        # the paper's oracle step: one observation per handoff ("release")
        occupancy = len(self.standby) + len(self.queue)
        corr = self.window.observe(late_wake=late, occupancy=occupancy)
        if corr > 0:                        # C1: promote extra sleepers now
            for _ in range(min(corr, len(self.queue))):
                self._prefill_one()
        # C2 (corr < 0) drains naturally: _fill_standby stops refilling.
        return late

    # -- one engine step ------------------------------------------------------
    def run_step(self) -> list[Request]:
        """Fill slots, decode one token, retire completions."""
        for slot in self.engine.free_slots():
            if not self.queue and not self.standby:
                break
            self._handoff(slot)
        self._fill_standby()

        finished: list[Request] = []
        for slot, _tok in self.engine.step():
            req = self.engine.slot_req[slot]
            if req is not None and req.done:
                self.engine.evict(slot)
                finished.append(req)
                self.stats.completed += 1

        self.stats.steps += 1
        self.stats.standby_residency += len(self.standby)
        self.stats.queue_wait_steps += len(self.queue)
        shortfall = self.engine.max_slots - self.active()
        if self.pending() > 0 and shortfall > 0:
            self.stats.slot_idle_steps += shortfall
        self.stats.window_trace.append(self.window.sws)
        return finished

    def run_until_drained(self, max_steps: int = 100_000) -> SchedStats:
        steps = 0
        while not self.idle() and steps < max_steps:
            self.run_step()
            steps += 1
        return self.stats


# --------------------------------------------------------------------------
# Scheduler-policy ablations through xdes — slot/standby dynamics encoded
# on the shared SimConfig row schema, so admission policies sweep on-device
# in the same batched call as the lock disciplines.
# --------------------------------------------------------------------------

#: Admission policy -> the discipline row that models it (DESIGN.md §3.2
#: mapping).  ``zero`` = no standby, every handoff pays prefill in the
#: open (the sleep lock: every waiter parked, wake latency exposed);
#: ``max`` = every waiting request held hot (the spin lock: every waiter
#: spinning, prefill always masked, residency maximal); ``mutable`` = the
#: paper's EvalSWS-tuned standby window.
SCHED_POLICY_LOCKS = {
    "zero": "sleep",
    "sleep": "sleep",
    "max": "ttas",
    "spin": "ttas",
    "mutable": "mutable",
}


@dataclass(frozen=True)
class SchedScenario:
    """One serving workload on the shared row schema.

    ``slots`` decode slots serve ``requests`` circulating requests; a slot
    is held for up to ``decode_s`` seconds per handoff (the CS), a retired
    request regenerates after up to ``think_s`` (the NCS), and promoting a
    cold request costs ``prefill_s`` (the OS wake-up latency).  Standby
    residency maps to spin CPU; cold promotions map to wake-ups.

    ``workload`` selects a hold-time row from
    :data:`repro.core.policy.WORKLOAD_ROWS` on the same schema: ``bursty``
    models diurnal/batchy admission (each request's think time stretches
    ``wl_burst`` x outside its ON window — traffic arrives in waves),
    ``hetero`` models mixed decode lengths (chat next to long-form
    generation), ``jitter`` models Poisson request arrivals.

    ``arrival`` turns the scenario OPEN-LOOP on the same schema
    (:data:`repro.core.policy.ARRIVAL_ROWS`): instead of ``requests``
    circulating forever, logical requests arrive at ``arrival_rate_rps``
    (the ``bursty`` row gates the rate through the ``wl_period_s`` /
    ``wl_duty`` burst phase), queue up to ``queue_cap`` deep (admission
    reads queue depth; offered load past saturation is shed), bind to one
    of the ``requests`` workers, and depart with a recorded sojourn —
    per-request p50/p95/p99 and the fraction violating ``slo_s`` come
    from the engine's on-device latency histograms.

    ``fault`` selects an interference row from
    :data:`repro.core.policy.FAULT_ROWS` on the same schema, in serving
    terms: ``preempt`` models a decode slot losing its device for whole
    windows (host preemption, GC pauses), ``oversub`` a fractional
    steady-state slowdown (noisy neighbours), ``lostwake`` a missed
    promotion callback recovered only after a ``fault_scale_s`` timeout,
    and ``jitter`` variable cold-start latency.  ``fault_scale_s = 0``
    auto-scales the fault window to 4 mean decode+think rounds (see
    docs/robustness.md).
    """

    slots: int
    requests: int
    decode_s: float = 50e-3
    think_s: float = 100e-3
    prefill_s: float = 8e-3
    seed: int = 0
    workload: str = "constant"
    wl_period_s: float = 0.0      # bursty cycle length; 0 -> auto-scaled
    wl_duty: float = 0.25
    wl_burst: float = 8.0
    wl_spread: float = 4.0
    arrival: str = "closed"       # open-loop arrival row (ARRIVAL_ROWS)
    arrival_rate_rps: float = 0.0
    queue_cap: int = QUEUE_MAX
    slo_s: float = 0.5            # per-request sojourn SLO (seconds)
    fault: str = "none"           # interference row (FAULT_ROWS)
    fault_rate: float = 0.0
    fault_scale_s: float = 0.0    # fault window; 0 -> auto-scaled

    @property
    def capacity_rps(self) -> float:
        """Closed-form service-capacity estimate (requests/s): the slot
        pool serializes at one handoff per mean decode hold, and below
        that each effective worker turns over a request per mean
        decode+think round."""
        mean_decode = 0.5 * self.decode_s
        mean_round = 0.5 * (self.decode_s + self.think_s)
        eff = min(self.requests, self.slots)
        return min(1.0 / max(mean_decode, 1e-12),
                   eff / max(mean_round, 1e-12))

    def to_sim_config(self, policy: str) -> SimConfig:
        """Encode this scenario under an admission policy as a SimConfig
        row — directly batchable with lock-sweep rows."""
        if policy not in SCHED_POLICY_LOCKS:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"options: {sorted(SCHED_POLICY_LOCKS)}")
        period = self.wl_period_s or 8.0 * (self.decode_s + self.think_s)
        return SimConfig(SCHED_POLICY_LOCKS[policy],
                         threads=self.requests, cores=self.slots,
                         cs=(0.0, self.decode_s), ncs=(0.0, self.think_s),
                         wake_latency=self.prefill_s, alpha=0.0,
                         seed=self.seed, workload=self.workload,
                         wl_period=period, wl_duty=self.wl_duty,
                         wl_burst=self.wl_burst, wl_spread=self.wl_spread,
                         arrival=self.arrival,
                         arrival_rate=self.arrival_rate_rps,
                         queue_cap=self.queue_cap, slo=self.slo_s,
                         fault=self.fault, fault_rate=self.fault_rate,
                         fault_scale=self.fault_scale_s
                         or 4.0 * (self.decode_s + self.think_s))


def sample_sched_scenarios(n_scenarios: int, seed: int = 0,
                           slots=(4, 8, 16),
                           workload: str = "constant",
                           arrival: str = "closed"
                           ) -> list[SchedScenario]:
    """Random serving workloads: under- to over-subscribed slot pools,
    decode/think/prefill times log-uniform across serving-realistic
    scales.  Stable draw order (the sweep-seed contract of
    :func:`repro.configs.catalog.sample_scenarios`): the base stream is
    untouched by ``workload`` and ``arrival``, so e.g. the bursty-
    admission sweep sees the SAME machines scenario-by-scenario as the
    constant one — the workload and arrival knobs come from separate
    salted streams.  ``arrival != "closed"`` makes the scenarios
    open-loop, with the offered load drawn from under-load to past
    saturation (0.3-1.2 x :attr:`SchedScenario.capacity_rps`) and the SLO
    at 8 mean decode+think rounds."""
    import numpy as np

    rng = np.random.default_rng(seed)
    wl_rng = np.random.default_rng(seed ^ 0x9E3779B9)
    ar_rng = np.random.default_rng(seed ^ 0x3C6EF372)
    out = []
    for i in range(n_scenarios):
        s = int(rng.choice(slots))
        kw = {}
        if workload == "bursty":
            kw = dict(wl_duty=float(wl_rng.uniform(0.15, 0.5)),
                      wl_burst=float(wl_rng.uniform(4.0, 16.0)))
        elif workload == "hetero":
            kw = dict(wl_spread=float(wl_rng.uniform(2.0, 8.0)))
        sc = SchedScenario(
            slots=s,
            requests=int(rng.integers(s, 4 * s + 1)),
            decode_s=float(np.exp(rng.uniform(np.log(5e-3), np.log(2e-1)))),
            think_s=float(np.exp(rng.uniform(np.log(1e-2), np.log(5e-1)))),
            prefill_s=float(np.exp(rng.uniform(np.log(2e-3), np.log(5e-2)))),
            seed=i, workload=workload, **kw)
        if arrival != "closed":
            rho = float(ar_rng.uniform(0.3, 1.2))
            sc = dataclass_replace(
                sc, arrival=arrival,
                arrival_rate_rps=rho * sc.capacity_rps,
                slo_s=4.0 * (sc.decode_s + sc.think_s))
        out.append(sc)
    return out


def xdes_policy_sweep(scenarios, policies=("zero", "max", "mutable"), *,
                      target_cs: int = 150, backend: str = "ref",
                      shard: bool | None = None, verbose: bool = False) -> dict:
    """Sweep every admission policy over every serving scenario in ONE
    batched :func:`repro.core.xdes.simulate_batch` call (scenario-major,
    policy-minor row order).

    Returns per-policy aggregates in the scheduler's vocabulary:
    ``handoffs_per_s`` (throughput), ``cold_promotions_per_handoff``
    (wake-ups per CS — the late-handoff analogue) and
    ``standby_s_per_handoff`` (spin CPU per CS — hot-pool residency).
    Open-loop scenarios (``SchedScenario.arrival != "closed"``) add
    per-request tail latency (``p50/p95/p99_s`` from the on-device
    histograms), ``slo_violation_frac`` and ``shed_frac``.
    """
    import numpy as np

    from repro.core import xdes

    scenarios = list(scenarios)
    configs = [sc.to_sim_config(p) for sc in scenarios for p in policies]
    res = xdes.simulate_batch(configs, target_cs=target_cs,
                              backend=backend, shard=shard)
    S, Pn = len(scenarios), len(policies)
    thr = res.throughput.reshape(S, Pn)
    wake = (res.wake_count / np.maximum(res.completed, 1)).reshape(S, Pn)
    standby = res.sync_cpu_per_cs.reshape(S, Pn)
    best = np.maximum(thr.max(axis=1), 1e-30)
    open_loop = any(c.open_loop for c in configs)

    out = {"meta": {"n_scenarios": S, "n_configs": len(configs),
                    "n_steps": res.n_steps, "backend": res.backend,
                    "open_loop": open_loop},
           "policies": {}}
    for j, p in enumerate(policies):
        out["policies"][p] = {
            "handoffs_per_s": float(thr[:, j].mean()),
            "mean_ratio_to_best": float((thr[:, j] / best).mean()),
            "cold_promotions_per_handoff": float(wake[:, j].mean()),
            "standby_s_per_handoff": float(standby[:, j].mean()),
        }
        if open_loop:
            sl = (slice(None), j)
            shed_frac = (res.shed.reshape(S, Pn)[sl]
                         / np.maximum(res.arrived.reshape(S, Pn)[sl], 1))
            out["policies"][p].update(
                p50_s=float(np.nanmean(res.p50.reshape(S, Pn)[sl])),
                p95_s=float(np.nanmean(res.p95.reshape(S, Pn)[sl])),
                p99_s=float(np.nanmean(res.p99.reshape(S, Pn)[sl])),
                slo_violation_frac=float(
                    np.nanmean(res.slo_frac.reshape(S, Pn)[sl])),
                shed_frac=float(shed_frac.mean()))
        if verbose:
            r = out["policies"][p]
            line = (f"{p:>8} handoffs/s {r['handoffs_per_s']:9.1f} "
                    f"ratio {r['mean_ratio_to_best']:5.3f} "
                    f"cold/handoff {r['cold_promotions_per_handoff']:5.3f} "
                    f"standby s/handoff {r['standby_s_per_handoff']:.4f}")
            if open_loop:
                line += (f" p95 {r['p95_s']:.4f}s "
                         f"slo-viol {r['slo_violation_frac']:.3f} "
                         f"shed {r['shed_frac']:.3f}")
            print(line)
    return out

"""Slot-based decode engine: prefill → KV-resident standby → active decode.

The engine is the resource the paper's spinning window governs at serving
time (DESIGN.md §3.2).  Request lifecycle:

    queued (cold)   — no device state, no cost, pays prefill on promotion
    standby (hot)   — PREFILLED AHEAD: KV cache resident, zero-latency entry
    active          — occupies a decode slot, one token per engine step
    done

``standby`` is the sleep→spin transition made concrete: a standby request
has already paid its wake-up latency (prefill) *before* a slot frees, so the
handoff is immediate — exactly like the woken thread that joins the spinning
window before the lock is released.  Holding standby KV is the resource
cost; the :class:`~repro.core.window.SpinningWindow` in
:mod:`repro.serve.scheduler` tunes how many to keep.

The engine below runs the *real* jitted model (tiny configs on CPU in tests
and examples).  :class:`SimulatedEngine` exposes the same interface with a
cost model for large-scale scheduler benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# Requests
# --------------------------------------------------------------------------
@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: list = field(default_factory=list)
    # bookkeeping for metrics
    t_prefill_start: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


# --------------------------------------------------------------------------
# Real engine
# --------------------------------------------------------------------------
class DecodeEngine:
    """Batched decode over ``max_slots`` sequences with insertable KV.

    prefill(tokens)           -> (next_token, cache_1)      [one sequence]
    insert(slot, cache_1, n)  -> write a prefilled sequence into the batch
    step()                    -> one greedy token for every occupied slot
    evict(slot)               -> free the slot
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int,
                 max_seq: int):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.cache = models.init_cache(cfg, max_slots, max_seq)
        self.occupied = np.zeros(max_slots, bool)
        self.slot_req: list[Request | None] = [None] * max_slots

        self._prefill = jax.jit(
            lambda p, toks: models.prefill(cfg, p, {"tokens": toks}))
        self._decode = jax.jit(
            lambda p, cache, toks: models.decode_step(cfg, p, cache, toks))
        self._tokens = np.zeros((max_slots, 1), np.int32)

    # -- prefill one request (B=1), outside the batch -----------------------
    def prefill(self, prompt: list):
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, toks)
        next_tok = int(jnp.argmax(logits[0]))
        return next_tok, cache1

    # -- slot management ----------------------------------------------------
    def insert(self, slot: int, cache1, prompt_len: int, first_token: int,
               req: Request) -> None:
        assert not self.occupied[slot]

        def put(big, small):
            if small is None or big is None:
                return big
            # big: (periods, max_slots, ...); small: (periods, 1, ...)
            if small.ndim >= 3 and small.shape[1] == 1:
                pad = [(0, 0)] * small.ndim
                if small.ndim >= 3 and big.shape[2] != small.shape[2]:
                    pad[2] = (0, big.shape[2] - small.shape[2])
                    small = jnp.pad(small, pad)
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=1)
            return big

        self.cache["stack"] = jax.tree.map(put, self.cache["stack"],
                                           cache1["stack"])
        self.cache["len"] = self.cache["len"].at[slot].set(prompt_len)
        self.occupied[slot] = True
        self.slot_req[slot] = req
        self._tokens[slot, 0] = first_token
        req.generated.append(first_token)

    def evict(self, slot: int) -> None:
        self.occupied[slot] = False
        self.slot_req[slot] = None
        self.cache["len"] = self.cache["len"].at[slot].set(0)

    def free_slots(self) -> list[int]:
        return [i for i in range(self.max_slots) if not self.occupied[i]]

    # -- one decode step over the whole batch -------------------------------
    def step(self) -> list[tuple[int, int]]:
        """Decode one token for every occupied slot.  Returns
        [(slot, token)] for occupied slots."""
        if not self.occupied.any():
            return []
        toks = jnp.asarray(self._tokens)
        logits, self.cache = self._decode(self.params, self.cache, toks)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = []
        # un-occupied slots decoded garbage; mask them out and rewind lens
        lens = np.array(self.cache["len"])
        for i in range(self.max_slots):
            if self.occupied[i]:
                tok = int(nxt[i])
                self._tokens[i, 0] = tok
                self.slot_req[i].generated.append(tok)
                out.append((i, tok))
            else:
                lens[i] = 0
        self.cache["len"] = jnp.asarray(lens)
        return out


# --------------------------------------------------------------------------
# Simulated engine: same interface, synthetic timing (for sched benchmarks)
# --------------------------------------------------------------------------
class SimulatedEngine:
    """Cost model: prefill takes ``prefill_cost`` seconds of engine time,
    a decode step takes ``step_cost(n_active)`` seconds.  Tokens are fake."""

    def __init__(self, max_slots: int, prefill_cost: float = 5e-3,
                 step_base: float = 1e-3, step_per_slot: float = 1e-4):
        self.max_slots = max_slots
        self.prefill_cost = prefill_cost
        self.step_base = step_base
        self.step_per_slot = step_per_slot
        self.occupied = np.zeros(max_slots, bool)
        self.slot_req: list[Request | None] = [None] * max_slots
        self.now = 0.0

    def prefill(self, prompt: list):
        self.now += self.prefill_cost
        return 0, {"sim": True}

    def insert(self, slot, cache1, prompt_len, first_token, req: Request):
        assert not self.occupied[slot]
        self.occupied[slot] = True
        self.slot_req[slot] = req
        req.generated.append(first_token)

    def evict(self, slot):
        self.occupied[slot] = False
        self.slot_req[slot] = None

    def free_slots(self):
        return [i for i in range(self.max_slots) if not self.occupied[i]]

    def step(self):
        n = int(self.occupied.sum())
        self.now += self.step_base + self.step_per_slot * n
        out = []
        for i in range(self.max_slots):
            if self.occupied[i]:
                self.slot_req[i].generated.append(0)
                out.append((i, 0))
        return out

"""Serving: slot-based decode engine + window-driven continuous batching."""

from .engine import DecodeEngine, Request, SimulatedEngine
from .scheduler import ContinuousBatcher, SchedStats

__all__ = ["DecodeEngine", "SimulatedEngine", "Request",
           "ContinuousBatcher", "SchedStats"]

"""Serving: slot-based decode engine + window-driven continuous batching."""

from .engine import DecodeEngine, Request, SimulatedEngine
from .scheduler import (ContinuousBatcher, SchedScenario, SchedStats,
                        sample_sched_scenarios, xdes_policy_sweep)

__all__ = ["DecodeEngine", "SimulatedEngine", "Request",
           "ContinuousBatcher", "SchedStats", "SchedScenario",
           "sample_sched_scenarios", "xdes_policy_sweep"]

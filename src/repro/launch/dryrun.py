import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without TPU hardware, that the distribution
config is coherent: parameters/optimizer state/caches shard onto the
production mesh, the program compiles under SPMD, fits per-device memory
(``memory_analysis``), and yields the roofline terms (loop-aware FLOPs /
traffic / collective bytes via :mod:`repro.launch.hloanalysis`).

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # one pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    ... --set seqcarry=model --set fsdp=data,model --tag sp_v2    # hillclimb

Artifacts land in reports/dryrun/<mesh>/<arch>__<shape>[__tag].json:
per-cell status, memory analysis, and the roofline terms from
:mod:`repro.launch.hloanalysis`.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import base as cbase
from repro.configs import inputs as cinputs
from repro.launch.hloanalysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.sharding import profiles, specs as sh
from repro.train import TrainConfig, make_train_step
from repro.train.train_step import init_state

ARCHS = ["gemma3-4b", "llama3.2-1b", "qwen2.5-14b", "stablelm-3b",
         "granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
         "jamba-1.5-large-398b", "chameleon-34b", "rwkv6-1.6b",
         "whisper-large-v3"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def default_tcfg(cfg) -> TrainConfig:
    n = models.param_count(cfg)
    # grad-accum defaults follow §Perf cell A: activation memory scales with
    # the microbatch, and (B/accum) must stay divisible by the 32-way pod2
    # batch sharding, so 8 is the deepest safe default.
    dl = cfg.d_model * cfg.num_layers
    if n >= 100e9:        # jamba-398b, qwen3-moe-235b: factored states
        return TrainConfig(optimizer="adafactor", master_weights=False,
                           grad_accum=8, accum_dtype="bfloat16")
    if dl >= 200_000:                      # qwen2.5-14b, chameleon-34b
        accum = 8
    elif (dl >= 80_000                     # gemma3, stablelm
          or cfg.family in ("ssm", "hybrid")   # scan-state memory (rwkv6)
          or cfg.is_encoder_decoder):      # two stacks (whisper)
        accum = 4
    else:
        accum = 1
    return TrainConfig(optimizer="adamw", grad_accum=accum)


def _shardings_for_tree(tree_shape, mesh, rules, kind: str):
    """kind: 'param' (regex param rules) | 'cache' | 'batch'."""
    if kind == "param":
        specs = sh.param_specs(tree_shape, mesh, rules)
    elif kind == "cache":
        specs = sh.cache_specs(tree_shape, mesh, rules)
    else:
        def one(leaf):
            logical = ("batch",) + (None,) * (len(leaf.shape) - 1)
            return sh.logical_to_spec(leaf.shape, logical, mesh, rules)
        specs = jax.tree.map(one, tree_shape)
    return sh.tree_shardings(specs, mesh)


def build_cell(arch: str, shape_name: str, mesh, overrides=None,
               tcfg: TrainConfig | None = None, tcfg_kw: dict | None = None):
    """Returns (jitted_fn, example_args_SDS) for the cell, under mesh rules."""
    import dataclasses
    cfg = cbase.get_config(arch)
    shape = cbase.SHAPES[shape_name]
    rules = profiles.rules_for(cfg, mesh, shape.step, overrides)
    tcfg = tcfg or default_tcfg(cfg)
    if tcfg_kw:
        tcfg = dataclasses.replace(tcfg, **tcfg_kw)

    if shape.step == "train":
        state_shape = jax.eval_shape(
            lambda k: init_state(cfg, tcfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        state_sh = _shardings_for_tree(state_shape, mesh, rules, "param")
        batch = cinputs.train_inputs(cfg, shape)
        batch_sh = _shardings_for_tree(batch, mesh, rules, "batch")
        step_fn = make_train_step(cfg, tcfg)

        def wrapped(state, b):
            with sh.use_mesh(mesh, rules):
                return step_fn(state, b)

        jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=0)
        return jitted, (state_shape, batch), rules, tcfg

    params_shape = jax.eval_shape(
        lambda k: models.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_sh = _shardings_for_tree(params_shape, mesh, rules, "param")

    if shape.step == "prefill":
        batch = cinputs.prefill_inputs(cfg, shape)
        batch_sh = _shardings_for_tree(batch, mesh, rules, "batch")

        def wrapped(p, b):
            with sh.use_mesh(mesh, rules):
                return models.prefill(cfg, p, b)

        out_shape = jax.eval_shape(wrapped, params_shape, batch)
        cache_sh = _shardings_for_tree(out_shape[1], mesh, rules, "cache")
        jitted = jax.jit(wrapped, in_shardings=(params_sh, batch_sh),
                         out_shardings=(None, cache_sh))
        return jitted, (params_shape, batch), rules, tcfg

    # decode: one new token against a seq_len KV cache
    cache_shape, tokens = cinputs.decode_inputs(cfg, shape)
    cache_sh = _shardings_for_tree(cache_shape, mesh, rules, "cache")
    tok_sh = _shardings_for_tree({"t": tokens}, mesh, rules, "batch")["t"]

    def wrapped(p, cache, toks):
        with sh.use_mesh(mesh, rules):
            return models.decode_step(cfg, p, cache, toks)

    jitted = jax.jit(wrapped, in_shardings=(params_sh, cache_sh, tok_sh),
                     out_shardings=(None, cache_sh), donate_argnums=1)
    return jitted, (params_shape, cache_shape, tokens), rules, tcfg


def model_flops(cfg, shape) -> float:
    """Assignment formula: 6*N_active*D train, 2*N_active*D inference."""
    n_active = models.active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.step != "decode"
                                   else 1)
    mult = 6.0 if shape.step == "train" else 2.0
    return mult * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             overrides=None, tag: str = "", force: bool = False,
             tcfg_kw: dict | None = None) -> dict:
    mesh_name = "pod2" if multi_pod else "pod1"
    cell_dir = os.path.join(out_dir, mesh_name)
    os.makedirs(cell_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(cell_dir, stem + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = cbase.get_config(arch)
    shape = cbase.SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "overrides": {k: v for k, v in (overrides or {}).items()},
           "tcfg_kw": dict(tcfg_kw or {}), "status": "running"}
    ok, reason = cbase.shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        jitted, args, rules, tcfg = build_cell(arch, shape_name, mesh,
                                               overrides, tcfg_kw=tcfg_kw)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        cost = analyze_hlo(hlo, default_group=n_chips)
        terms = roofline_terms(cost, cost.traffic_bytes)
        mf = model_flops(cfg, shape)
        total_hlo_flops = cost.flops * n_chips
        terms["model_flops"] = mf
        terms["useful_ratio"] = mf / total_hlo_flops if total_hlo_flops else 0
        # roofline fraction: useful model flops per second at the bound set
        # by the slowest term vs the pure-compute ideal
        t_bound = max(terms["compute_s"], terms["memory_s"],
                      terms["collective_s"])
        ideal = mf / (n_chips * 197e12)
        terms["roofline_fraction"] = ideal / t_bound if t_bound else 0.0

        rec.update(
            status="ok",
            n_chips=n_chips,
            rules={k: rules.resolve(k) for k in rules.__dataclass_fields__},
            optimizer=tcfg.optimizer if shape.step == "train" else None,
            grad_accum=tcfg.grad_accum if shape.step == "train" else None,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_per_device": (ma.argument_size_in_bytes
                                          + ma.output_size_in_bytes
                                          + ma.temp_size_in_bytes
                                          - ma.alias_size_in_bytes),
            },
            xla_cost={"flops": ca.get("flops"),
                      "bytes": ca.get("bytes accessed")},
            roofline=terms,
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=SHAPES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) for the chosen mesh")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    help="MeshRules override, e.g. --set seqcarry=model")
    ap.add_argument("--accum", type=int, default=None,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--optimizer", default=None,
                    choices=["adamw", "adafactor", "sgd"])
    ap.add_argument("--compress", default=None, choices=["none", "int8"],
                    help="cross-pod gradient compression (needs --multi-pod)")
    ap.add_argument("--accum-dtype", default=None,
                    choices=["float32", "bfloat16"])
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    overrides = profiles.parse_rule_overrides(args.sets) or None
    tcfg_kw = {}
    if args.accum is not None:
        tcfg_kw["grad_accum"] = args.accum
    if args.optimizer is not None:
        tcfg_kw["optimizer"] = args.optimizer
    if args.compress is not None:
        tcfg_kw["dp_compression"] = args.compress
    if args.accum_dtype is not None:
        tcfg_kw["accum_dtype"] = args.accum_dtype
    cells = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    results = []
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.out,
                       overrides, args.tag, args.force, tcfg_kw or None)
        r = rec.get("roofline", {})
        print(f"[{rec['status']:>7}] {arch:>24} {shape:<12} "
              f"mesh={rec['mesh']} wall={rec.get('wall_s', 0):>7}s "
              f"dom={r.get('dominant', '-'):<10} "
              f"frac={r.get('roofline_fraction', 0):.3f}"
              + (f"  ({rec.get('reason', rec.get('error', ''))[:60]})"
                 if rec["status"] != "ok" else ""),
              flush=True)
        results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(results)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""End-to-end training driver: data pipeline -> train loop -> checkpoints,
with heartbeat monitoring and crash-safe resume.

CPU-runnable (tiny configs) and mesh-aware (full configs on TPU):

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --tiny \\
        --steps 60 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The loop structure is the production one: prefetch depth self-tunes
(spinning window), checkpoints are async + atomic, a heartbeat board is
kept per step, and a simulated ``--fail-at`` kills the process state and
resumes from the last checkpoint to prove restartability.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.checkpoint import CheckpointManager
from repro.configs import base as cbase
from repro.configs import catalog
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.runtime import HeartbeatBoard, StragglerMonitor
from repro.sharding import profiles, specs as sh
from repro.train import TrainConfig, init_state, make_train_step


def build(cfg, tcfg, mesh=None, rules=None):
    step_fn = make_train_step(cfg, tcfg)
    if mesh is None:
        return jax.jit(step_fn)

    def wrapped(state, batch):
        with sh.use_mesh(mesh, rules):
            return step_fn(state, batch)

    state_shape = jax.eval_shape(
        lambda k: init_state(cfg, tcfg, k),
        jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    specs = sh.param_specs(state_shape, mesh, rules)
    shardings = sh.tree_shardings(specs, mesh)
    return jax.jit(wrapped, in_shardings=(shardings, None),
                   out_shardings=(shardings, None), donate_argnums=0)


def train_loop(cfg, tcfg, steps: int, batch: int, seq: int,
               ckpt_dir: str | None, ckpt_every: int = 20,
               fail_at: int | None = None, host_id: int = 0,
               log_every: int = 10, use_mesh_flag: bool = False):
    mesh = rules = None
    if use_mesh_flag:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        rules = profiles.rules_for(cfg, mesh, "train")
    step_jit = build(cfg, tcfg, mesh, rules)

    corpus = SyntheticCorpus(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=tcfg.seed))
    loader = PrefetchLoader(corpus, workers=2)
    board = HeartbeatBoard(n_hosts=1)
    monitor = StragglerMonitor(board, dead_after_s=60.0)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    state = init_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed))
    start = 0
    if mgr is not None:
        got = mgr.restore(state)
        if got[0] is not None:
            start, state = got[0] + 1, got[1]
            print(f"[resume] restored step {got[0]} from {ckpt_dir}")
            # fast-forward the data stream for exactly-once consumption
            loader.next_consume = start
            loader.next_produce = max(loader.next_produce, start)

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch_np = loader.get()
        batch_dev = jax.tree.map(jax.numpy.asarray, batch_np)
        state, metrics = step_jit(state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        board.beat(host_id, step)
        if mgr is not None and step > 0 and step % ckpt_every == 0:
            mgr.save(step, state)
        if fail_at is not None and step == fail_at:
            print(f"[failure-injection] dying at step {step} "
                  f"(last ckpt <= {step - step % ckpt_every})")
            if mgr:
                mgr.wait()
                mgr.close()
            loader.close()
            return {"died_at": step, "losses": losses}
        if step % log_every == 0:
            print(f"step {step:>5}  loss {loss:8.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
    rep = monitor.wait_for_step(steps - 1, timeout_s=1.0)
    if mgr is not None:
        mgr.save(steps - 1, state)
        mgr.wait()
        mgr.close()
    loader.close()
    print(f"done: {steps - start} steps, final loss {losses[-1]:.4f}, "
          f"prefetch late-rate "
          f"{loader.stats['empty_gets']}/{loader.stats['gets']}, "
          f"monitor ready={rep.ready}")
    return {"losses": losses, "state": state}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--mesh", action="store_true",
                    help="use the production mesh (TPU)")
    args = ap.parse_args(argv)

    cfg = cbase.get_config(args.arch)
    if args.tiny:
        cfg = catalog.tiny(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       decay_steps=max(100, args.steps),
                       grad_accum=args.accum)
    return train_loop(cfg, tcfg, args.steps, args.batch, args.seq,
                      args.ckpt_dir, ckpt_every=args.ckpt_every,
                      fail_at=args.fail_at, use_mesh_flag=args.mesh)


if __name__ == "__main__":
    main()

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first backend init — dryrun.py sets
``--xla_force_host_platform_device_count=512`` before importing us).

Production topology (assignment): one pod = 16 x 16 = 256 chips
(``data`` x ``model``); multi-pod = 2 pods = 512 chips with a leading
``pod`` axis that crosses DCN (pure data parallel + optional FSDP).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    # jax.sharding.AxisType landed after the pinned JAX; Auto is the
    # default there anyway, so only pass axis_types when it exists.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))

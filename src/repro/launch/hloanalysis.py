"""Loop-aware analysis of post-SPMD HLO text — the dry-run "profiler".

``compiled.cost_analysis()`` visits every computation exactly once: a
94-layer ``lax.scan`` reports 1-layer FLOPs (verified empirically on this
container).  The roofline therefore needs its own accounting.  This module
parses ``compiled.as_text()`` into computations, discovers ``while`` loops
and their trip counts (the scan bound is a visible ``constant(N)`` in the
condition computation), and recursively accumulates:

* ``flops``            — 2·M·N·K for every ``dot``; convolutions as
                         2·out·kernel; loop-multiplied.
* ``collective_bytes`` — per collective kind, operand bytes (assignment
                         formula) and ring-adjusted wire bytes; grouped by
                         mesh axis group size; loop-multiplied.
* ``traffic_bytes``    — HBM-traffic approximation: Σ over top-level
                         (post-fusion) instructions of unique operand bytes +
                         output bytes; loop-multiplied.

The parser is deliberately tolerant: HLO text it does not understand is
skipped, never fatal (the roofline is an estimate, not a checksum).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Instr:
    name: str
    shape: str               # result shape string (may be a tuple)
    op: str                  # opcode, e.g. "dot", "while", "fusion"
    operands: list
    raw: str
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _balanced(s: str, start: int) -> int:
    """Index of the char closing the paren opened at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr_line(stripped: str):
    """'%name = SHAPE op(args), attrs' -> (name, shape, op, args, attrs).

    Hand-rolled because tuple shapes contain nested parens, layout braces
    and '/*index=k*/' comments that defeat any single regex."""
    s = stripped
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3:].lstrip()
    if rest.startswith("("):                     # tuple-shaped result
        close = _balanced(rest, 0)
        shape, rest2 = rest[:close + 1], rest[close + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    par = rest2.find("(")
    if par < 0:
        return None
    op = rest2[:par].strip()
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    close = _balanced(rest2, par)
    args = rest2[par + 1:close]
    attrs = rest2[close + 1:]
    return name, shape, op, args, attrs


def parse_hlo(text: str) -> dict:
    """Parse HLO text into {computation_name: Computation}."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped == "}":
            cur = None
            continue
        if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(stripped)
        if parsed is None:
            continue
        name, shape, op, args, attrs = parsed
        ops = []
        depth = 0
        buf = ""
        for ch in args:
            if ch == "(" or ch == "{":
                depth += 1
            elif ch == ")" or ch == "}":
                depth -= 1
            if ch == "," and depth == 0:
                ops.append(buf.strip())
                buf = ""
            else:
                buf += ch
        if buf.strip():
            ops.append(buf.strip())
        ops = [o.lstrip("%") for o in ops]
        instr = Instr(name=name, shape=shape.strip(), op=op,
                      operands=ops, raw=stripped, attrs=attrs)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    comps["__entry__"] = comps.get(entry) or next(iter(comps.values()))
    return comps


# --------------------------------------------------------------------------
# Trip counts
# --------------------------------------------------------------------------
def _trip_count(comps: dict, cond_name: str) -> int:
    """Largest integer constant in the while-condition computation — exact
    for lax.scan/fori_loop counted loops; 1 if nothing found."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called(attrs: str, key: str):
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


# --------------------------------------------------------------------------
# FLOPs
# --------------------------------------------------------------------------
def _dot_flops(ins: Instr, comp: Computation, comps: dict) -> float:
    """2 * prod(output) * prod(contracting dims of lhs)."""
    _, out_dims = _shape_dims(ins.shape)
    lhs_shape = _operand_shape(ins.operands[0], comp, comps)
    if lhs_shape is None:
        return 0.0
    _, lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            d = int(d)
            if d < len(lhs_dims):
                contract *= lhs_dims[d]
    return 2.0 * math.prod(out_dims or [1]) * contract


def _conv_flops(ins: Instr, comp: Computation, comps: dict) -> float:
    _, out_dims = _shape_dims(ins.shape)
    rhs_shape = _operand_shape(ins.operands[1], comp, comps) \
        if len(ins.operands) > 1 else None
    if rhs_shape is None:
        return 0.0
    _, k_dims = _shape_dims(rhs_shape)
    # out spatial+batch+feature x kernel (input_feature * spatial)
    return 2.0 * math.prod(out_dims or [1]) * math.prod(k_dims[:-1] or [1])


_OPERAND_SHAPE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}/]+?))\s+%?([\w.\-]+)$")


def _operand_shape(operand: str, comp: Computation, comps: dict):
    """Operand text is either 'shape %name' or just a name to look up."""
    m = _OPERAND_SHAPE_RE.match(operand.strip())
    if m and "[" in m.group(1):
        return m.group(1)
    name = operand.strip().lstrip("%")
    ins = comp.by_name.get(name)
    if ins is not None:
        return ins.shape
    return None


# --------------------------------------------------------------------------
# Recursive accumulation
# --------------------------------------------------------------------------
@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_operand_bytes: dict = field(
        default_factory=lambda: defaultdict(float))
    collective_wire_bytes: dict = field(
        default_factory=lambda: defaultdict(float))
    collective_count: dict = field(default_factory=lambda: defaultdict(int))

    def scaled(self, k: float) -> "HloCost":
        out = HloCost(self.flops * k, self.traffic_bytes * k)
        for d_src, d_dst in (
                (self.collective_operand_bytes, out.collective_operand_bytes),
                (self.collective_wire_bytes, out.collective_wire_bytes)):
            for key, v in d_src.items():
                d_dst[key] = v * k
        for key, v in self.collective_count.items():
            out.collective_count[key] = int(v * k)
        return out

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.traffic_bytes += other.traffic_bytes
        for key, v in other.collective_operand_bytes.items():
            self.collective_operand_bytes[key] += v
        for key, v in other.collective_wire_bytes.items():
            self.collective_wire_bytes[key] += v
        for key, v in other.collective_count.items():
            self.collective_count[key] += v

    @property
    def total_collective_operand_bytes(self) -> float:
        return sum(self.collective_operand_bytes.values())

    @property
    def total_collective_wire_bytes(self) -> float:
        return sum(self.collective_wire_bytes.values())


def _group_size(ins: Instr, default_g: int) -> int:
    """Participants per replica group, e.g. replica_groups=[2,4]<=[8] -> 4,
    {{0,1},{2,3}} -> 2, {} -> all participants (default_g)."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", ins.raw)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", ins.raw)
    if m:
        return len(m.group(1).split(","))
    if "replica_groups={}" in ins.raw:
        return default_g
    return default_g if ins.op.startswith("all-") else 1


def _collective(ins: Instr, comp: Computation, comps: dict, cost: HloCost,
                default_g: int):
    kind = next((k for k in COLLECTIVE_KINDS if ins.op.startswith(k)), None)
    if kind is None:
        return
    g = _group_size(ins, default_g)
    op_bytes = 0
    for o in ins.operands:
        s = _operand_shape(o, comp, comps)
        if s:
            op_bytes += _shape_bytes(s)
    out_bytes = _shape_bytes(ins.shape)
    # ring-algorithm wire bytes per device
    if kind == "all-reduce":
        wire = 2.0 * op_bytes * (g - 1) / max(1, g)
    elif kind == "all-gather":
        wire = out_bytes * (g - 1) / max(1, g)
    elif kind == "reduce-scatter":
        wire = op_bytes * (g - 1) / max(1, g)
    elif kind == "all-to-all":
        wire = op_bytes * (g - 1) / max(1, g)
    else:  # collective-permute
        wire = op_bytes
    key = f"{kind}(g={g})"
    cost.collective_operand_bytes[key] += op_bytes
    cost.collective_wire_bytes[key] += wire
    cost.collective_count[key] += 1


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call", "custom-call",
                 "after-all", "partition-id", "replica-id"}


def _comp_cost(comps: dict, comp: Computation, memo: dict,
               default_g: int = 1) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = HloCost()      # break cycles defensively
    cost = HloCost()
    for ins in comp.instrs:
        if ins.op == "while":
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            trips = _trip_count(comps, cond)
            if body in comps:
                cost.add(_comp_cost(comps, comps[body], memo, default_g).scaled(trips))
            continue
        if ins.op in ("call", "async-start"):
            tgt = _called(ins.attrs, "to") or _called(ins.attrs, "calls")
            if tgt in comps:
                cost.add(_comp_cost(comps, comps[tgt], memo, default_g))
            continue
        if ins.op == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations=\{)([\w.,\-%\s]+)",
                                 ins.attrs):
                for t in re.split(r"[,\s}]+", m.group(1)):
                    t = t.strip().lstrip("%")
                    if t in comps:
                        cost.add(_comp_cost(comps, comps[t], memo, default_g))
            continue
        if ins.op == "fusion":
            tgt = _called(ins.attrs, "calls")
            if tgt in comps:
                inner = _comp_cost(comps, comps[tgt], memo, default_g)
                cost.flops += inner.flops      # dots inside fusions
            # fusion traffic = its operands + outputs (internals stay in reg)
            for o in ins.operands:
                s = _operand_shape(o, comp, comps)
                if s:
                    cost.traffic_bytes += _shape_bytes(s)
            cost.traffic_bytes += _shape_bytes(ins.shape)
            continue
        if ins.op == "dot":
            cost.flops += _dot_flops(ins, comp, comps)
        elif ins.op.startswith("convolution"):
            cost.flops += _conv_flops(ins, comp, comps)
        _collective(ins, comp, comps, cost, default_g)
        if ins.op not in _SKIP_TRAFFIC:
            op_bytes = [(_shape_bytes(s) if (s := _operand_shape(
                o, comp, comps)) else 0) for o in ins.operands]
            if ins.op in ("scatter", "dynamic-update-slice"):
                # in-place update under buffer aliasing: the big target is
                # neither copied nor re-written; only the update traffic
                # counts
                cost.traffic_bytes += sum(op_bytes) - max(op_bytes,
                                                          default=0)
            else:
                cost.traffic_bytes += sum(op_bytes)
                cost.traffic_bytes += _shape_bytes(ins.shape)
    memo[comp.name] = cost
    return cost


def analyze_hlo(text: str, default_group: int = 1) -> HloCost:
    """Loop-aware cost of the ENTRY computation of post-SPMD HLO text.
    ``default_group``: participants assumed when replica_groups={} (= all
    devices); pass the mesh size."""
    comps = parse_hlo(text)
    entry = comps["__entry__"]
    memo: dict = {}
    return _comp_cost(comps, entry, memo, default_group)


# --------------------------------------------------------------------------
# Roofline terms (TPU v5e constants per the assignment)
# --------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def roofline_terms(cost: HloCost, mem_bytes: float) -> dict:
    """Per-chip seconds for each roofline term.  ``cost`` is already the
    per-device (post-SPMD) program; ``mem_bytes`` is the per-device HBM
    traffic (falls back to cost.traffic_bytes)."""
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = (mem_bytes or cost.traffic_bytes) / HBM_BW
    collective_s = cost.total_collective_wire_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "flops": cost.flops,
        "traffic_bytes": mem_bytes or cost.traffic_bytes,
        "collective_operand_bytes": dict(cost.collective_operand_bytes),
        "collective_wire_bytes": dict(cost.collective_wire_bytes),
        "collective_count": dict(cost.collective_count),
    }

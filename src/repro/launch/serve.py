"""Serving driver: continuous batching with the window-tuned standby pool.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --tiny \\
        --requests 24 --slots 4

Runs the REAL model (tiny config on CPU; full config + mesh on TPU) under
the :class:`~repro.serve.scheduler.ContinuousBatcher` — the paper's
technique deciding how many requests to keep prefilled-ahead.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import base as cbase
from repro.configs import catalog
from repro.serve import ContinuousBatcher, DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--policy", default="mutable",
                    choices=["mutable", "zero", "max"])
    args = ap.parse_args(argv)

    cfg = cbase.get_config(args.arch)
    if args.tiny:
        cfg = catalog.tiny(cfg)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, params, max_slots=args.slots,
                          max_seq=args.max_seq)

    from repro.core.oracle import EvalSWS, FixedOracle
    oracle = {"mutable": EvalSWS(k=10), "zero": FixedOracle(),
              "max": FixedOracle()}[args.policy]
    initial = {"mutable": 1, "zero": 0, "max": args.slots}[args.policy]
    bat = ContinuousBatcher(engine, max_standby=args.slots, initial=initial,
                            oracle=oracle)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = list(rng.integers(2, cfg.vocab_size - 1,
                                   size=int(rng.integers(4, 12))))
        bat.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.max_new,
                           arrived_at=time.time()))
    stats = bat.run_until_drained(max_steps=5000)
    dt = time.time() - t0
    s = stats.summary()
    toks = s["completed"] * args.max_new
    print(f"served {s['completed']} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"late-handoff rate {s['late_handoff_rate']:.3f}  "
          f"avg standby {s['avg_standby']:.2f}  "
          f"window trace tail {stats.window_trace[-8:]}")
    return s


if __name__ == "__main__":
    main()

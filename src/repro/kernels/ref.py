"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *definitions*, not fast paths: direct dense math, f32 accumulate.
The model code has its own (chunked/blockwise) implementations; tests check
kernel == ref and model-path == ref independently.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (BH, Sq, hd); k, v: (BKV, Sk, hd)."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    g = BH // BKV
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qp >= kp
    if window:
        m &= (qp - kp) < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """Sequential definition.  r,k,v,w: (BH, T, n); u: (BH, n)."""
    BH, T, n = r.shape
    S = (jnp.zeros((BH, n, n), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(S, t):
        r_t, k_t, v_t, w_t = t                               # (BH, n)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bk,bkv->bv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    ts = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    S, ys = jax.lax.scan(step, S, ts)
    return ys.swapaxes(0, 1), S


def mamba_scan_ref(dt, x, Bm, Cm, a):
    """Sequential definition.  dt,x: (B,T,d); Bm,Cm: (B,T,N); a: (d,N)."""
    B, T, d = x.shape
    N = a.shape[-1]
    s0 = jnp.zeros((B, d, N), jnp.float32)

    def step(s, t):
        dt_t, x_t, B_t, C_t = t
        da = jnp.exp(dt_t[..., None] * a)
        s = s * da + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", s, C_t)
        return s, y

    ts = tuple(v.swapaxes(0, 1).astype(jnp.float32)
               for v in (dt, x, Bm, Cm))
    _, ys = jax.lax.scan(step, s0, ts)
    return ys.swapaxes(0, 1)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def lock_sim_step_ref(tstate, rem, alpha, cores, dt, has_budget):
    """One generalized-processor-sharing advance of the batched lock sim.

    The hot inner update of :mod:`repro.core.xdes` (paper §2 model): every
    runnable thread advances at rate ``min(1, cores / n_runnable)``; the CS
    holder is additionally slowed by cache-coherency pressure
    ``1 / (1 + alpha * n_spinners)``; spinners burn CPU, and the adaptive
    discipline's spinners consume their spin budget.

    tstate: (C, T) int32 thread states (repro.core.policy encoding);
    rem:    (C, T) f32 remaining work (CS/NCS) or spin budget (adaptive);
    alpha, cores, dt: (C,) f32; has_budget: (C,) bool.
    Returns ``(rem', spin_burn)`` with spin_burn (C,) f32 — the CPU-seconds
    burnt spinning this step (the paper's sync-waste metric).
    """
    from repro.core.policy import CS, NCS, SPIN

    is_cs = tstate == CS
    is_ncs = tstate == NCS
    is_spin = tstate == SPIN
    n_run = jnp.sum(is_cs | is_ncs | is_spin, axis=-1).astype(jnp.float32)
    n_spin = jnp.sum(is_spin, axis=-1).astype(jnp.float32)
    rate = jnp.minimum(1.0, cores / jnp.maximum(n_run, 1.0))
    holder_rate = rate / (1.0 + alpha * n_spin)
    d_rate = dt * rate
    burn = jnp.where(is_spin, d_rate[:, None], 0.0)
    dec = (jnp.where(is_cs, (dt * holder_rate)[:, None], 0.0)
           + jnp.where(is_ncs, d_rate[:, None], 0.0)
           + jnp.where(has_budget[:, None], burn, 0.0))
    return rem - dec, jnp.sum(burn, axis=-1)


# --------------------------------------------------------------------------
# The batched lock simulator's transition stage — the (C, T)-block reference
# behind the swappable kernel boundary.  repro.core.xdes calls either this
# function or its Pallas twin (repro.kernels.lock_sim.lock_transitions_step,
# which wraps the SAME body in a grid over config blocks); tests pin the two
# bit-identical.  All discipline decisions dispatch through
# repro.core.policy.DISCIPLINE_ROWS, all oracle decisions through
# ORACLE_ROWS — the engine itself is discipline-agnostic.
# --------------------------------------------------------------------------

#: Residual work (CPU-seconds) under which a CS/NCS counts as finished.
REM_EPS = 1e-9
#: Retired-ticket sentinel (no thread ever draws this many tickets).
NO_TICKET = 2**31 - 1

#: Canonical argument order of the transition boundary: per-thread (C, T)
#: state, per-config (C,) state, then the per-config context columns.
TRANSITION_THREAD_STATE = ("st", "rem", "wake_at", "slept", "spun", "ctr",
                           "ticket", "completed_pt")
TRANSITION_CONFIG_STATE = ("sws", "cnt", "ewma", "wuc", "permits", "nticket",
                           "completed", "wake_count")
TRANSITION_CONTEXT = ("now2", "stepi", "policy", "threads", "dt", "wake",
                      "cs_lo", "cs_hi", "ncs_lo", "ncs_hi", "k", "sws_max",
                      "spin_budget", "seed", "oracle", "workload",
                      "wl_period", "wl_duty", "wl_burst", "wl_spread",
                      "arrival", "arr_rate", "q_cap", "slo", "tb",
                      "fault", "flt_rate", "flt_scale", "park_cost")

#: Open-loop state appended after the closed carry (spin_cpu) — only
#: materialized when a batch contains an open-arrival config
#: (``SimConfig.arrival != "closed"``; see docs/open_loop.md).  Shapes:
#: ``req_t`` (C, T) f32 bound-request arrival times (-1 when the slot is
#: free), ``qbuf`` (C, QUEUE_MAX) f32 queued arrival times (a ring
#: buffer), ``hist`` (C, LAT_NBINS) i32 latency histogram, then (C,)
#: counters: queue head/length, arrived/shed/departed/SLO-violation
#: counts (i32), latency sum and queue+service occupancy time-integral
#: (f32) — the exact Little's-law pair (``occ_int - lat_sum`` equals the
#: summed ages of still-in-system requests at the horizon).
OPEN_STATE = ("req_t", "qbuf", "hist", "qhead", "qlen", "arrived", "shed",
              "departed", "slo_viol", "lat_sum", "occ_int")


def counter_uniform(seed, tid, ctr):
    """Counter-based RNG: uniform [0,1) per (config, thread, event) from a
    splitmix-style avalanche — deterministic, stateless, replayable per
    cell independently of batch composition."""
    x = seed ^ (tid.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)) \
        ^ ((ctr + jnp.uint32(1)) * jnp.uint32(0x85EBCA6B))
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x.astype(jnp.float32) * jnp.float32(2.0 ** -32)


# --------------------------------------------------------------------------
# Workload rows (repro.core.policy.WORKLOAD_ROWS) — the hold-time stage of
# the kernel boundary.  The helpers below precompute the per-thread
# workload state (duty-cycle phase, OFF gate, heterogeneity scale) from
# dedicated counter-RNG streams and feed policy.workload_hold, the masked
# row dispatch.  The Pallas kernels inherit them by applying the same
# transition/init bodies per block, so ref and Pallas lowerings of every
# workload row are bit-identical by construction.
# --------------------------------------------------------------------------
def workload_state(seed, tid, now, wl_period, wl_duty, wl_spread):
    """Per-(config, thread) workload state at time ``now``.

    Returns ``(phase_u, gate_off, tscale)``: the thread's persistent
    duty-cycle phase uniform, its 0/1 OFF-phase gate at ``now``, and its
    persistent heterogeneity scale.  ``seed``/``now``/parameter columns
    broadcast against ``tid``; the two uniforms come from salted counter
    streams (policy.WL_PHASE_SALT / WL_SPREAD_SALT), so they never collide
    with the event-draw stream and replay identically per cell."""
    from repro.core import policy as P

    zero = jnp.uint32(0)
    phase_u = counter_uniform(seed ^ jnp.uint32(P.WL_PHASE_SALT), tid, zero)
    spread_u = counter_uniform(seed ^ jnp.uint32(P.WL_SPREAD_SALT), tid,
                               zero)
    gate_off = P.workload_off_gate(now, phase_u, wl_period, wl_duty)
    tscale = P.workload_thread_scale(spread_u, wl_spread)
    return phase_u, gate_off, tscale


def workload_draw(u, lo, hi, is_ncs, workload, gate_off, tscale, wl_burst):
    """One workload-row hold-time draw from the uniform ``u``.

    ``is_ncs`` is a static 0/1 flag (CS vs NCS/arrival-gap draw); the
    exponential deviate for the jitter row is only materialized on the NCS
    path.  The constant row's output is bit-identical to the plain uniform
    draw ``lo + u * (hi - lo)``.

    The deviate clamps ``u`` below 1: ``counter_uniform`` casts a uint32
    to float32, which rounds the top ~2**8 values to exactly 1.0
    (probability ~6e-8 per draw), and ``-log1p(-1.0)`` is +inf — which
    the masked row dispatch would turn into NaN (``0.0 * inf``) for every
    non-jitter config.  Clamping caps the deviate at ~16.6 means instead
    and leaves ``base`` (hence the constant row) untouched."""
    from repro.core import policy as P

    base = lo + u * (hi - lo)
    expd = ((0.5 * (lo + hi))
            * (-jnp.log1p(-jnp.minimum(u, jnp.float32(1.0 - 2.0 ** -24))))
            if is_ncs else base)
    return P.workload_hold(workload, is_ncs, base, expd, gate_off, tscale,
                           wl_burst)


def workload_init_rem(seed, tid, ctr0, ncs_lo, ncs_hi, workload, wl_period,
                      wl_duty, wl_burst, wl_spread, arrival_phase):
    """The initial per-thread NCS draw (every thread starts in NCS),
    workload-modulated at ``now = 0``, plus the seeded per-thread
    arrival-order randomization: first arrivals are staggered by up to
    ``arrival_phase`` mean-NCS lengths drawn from the phase stream, so
    simultaneous arrivals no longer resolve in thread-id order.  With the
    constant row and ``arrival_phase = 0`` this is bit-identical to the
    plain uniform init draw."""
    u0 = counter_uniform(seed, tid, ctr0)
    phase_u, gate_off, tscale = workload_state(seed, tid, 0.0, wl_period,
                                               wl_duty, wl_spread)
    rem0 = workload_draw(u0, ncs_lo, ncs_hi, 1, workload, gate_off, tscale,
                         wl_burst)
    return rem0 + phase_u * arrival_phase * (0.5 * (ncs_lo + ncs_hi))


def fault_rewind(st, rem, alpha, cores, dt, now_start, seed, fault,
                 flt_rate, flt_scale):
    """Fault-row progress theft for one timestep (FAULT_ROWS dispatch).

    Recomputes the GPS progress each CS/NCS thread made during the step
    that :func:`lock_sim_step_ref` just applied (from the SAME pre-step
    ``st``, so the rates match bit-for-bit) and gives the stolen fraction
    back to ``rem``: a thread whose fault window is off-CPU makes no (or
    partial) progress while spinners keep burning CPU — the asymmetry
    that lets sleep-leaning disciplines overtake pure spin under heavy
    preemption.  Windows are ``flt_scale`` seconds; the per-(thread,
    window) gate uniform comes from the FLT_GATE_SALT counter stream, so
    an off-CPU stretch persists across every sub-step of its window.

    Applied through ``where(giveback > 0)``, so a fault-free config's
    ``rem`` is a structural passthrough — bit-identical to the pre-fault
    engine.  ``now_start`` is the step's START time ``i * dt`` (scalar or
    (C,)); spin burn and the adaptive budget are deliberately not
    modulated (see the FAULT_ROWS registry comment).
    """
    from repro.core import policy as P

    C, T = st.shape
    col = lambda v: v[:, None]
    is_cs = st == P.CS
    is_ncs = st == P.NCS
    is_spin = st == P.SPIN
    n_run = jnp.sum(is_cs | is_ncs | is_spin, axis=-1).astype(jnp.float32)
    n_spin = jnp.sum(is_spin, axis=-1).astype(jnp.float32)
    rate = jnp.minimum(1.0, cores / jnp.maximum(n_run, 1.0))
    holder_rate = rate / (1.0 + alpha * n_spin)
    prog = (jnp.where(is_cs, (dt * holder_rate)[:, None], 0.0)
            + jnp.where(is_ncs, (dt * rate)[:, None], 0.0))
    tid = jnp.arange(T, dtype=jnp.int32)[None, :]
    tidb = jnp.broadcast_to(tid, (C, T))
    win = jnp.floor(now_start / flt_scale).astype(jnp.int32) \
        .astype(jnp.uint32)
    winT = win[:, None] if jnp.ndim(win) else win
    gate_u = counter_uniform(col(seed) ^ jnp.uint32(P.FLT_GATE_SALT),
                             tidb, winT)
    scale = P.fault_progress_scale(col(fault), is_cs * 1.0, gate_u,
                                   col(flt_rate))
    giveback = prog * (1.0 - scale)
    return jnp.where(giveback > 0.0, rem + giveback, rem)


def lock_transitions_ref(st, rem, wake_at, slept, spun, ctr, ticket,
                         completed_pt, sws, cnt, ewma, wuc, permits,
                         nticket, completed, wake_count,
                         now2, stepi, policy, threads, dt, wake, cs_lo,
                         cs_hi, ncs_lo, ncs_hi, k, sws_max, spin_budget,
                         seed, oracle, workload, wl_period, wl_duty,
                         wl_burst, wl_spread, arrival, arr_rate, q_cap,
                         slo, tb, fault, flt_rate, flt_scale, park_cost, *,
                         open_state=None):
    """One transition step for a (C, T) block of configurations.

    Stages (same order as the event-driven DES resolves a timestep):
    [open-loop admission] -> budget exhaustion -> wake completions ->
    CS release/handoff [+ open-loop departure] -> arrivals ->
    [open-loop request binding + occupancy].  Per-thread state is
    int32/f32/uint32 arrays of shape (C, T) (``slept``/``spun`` as 0/1
    int32, ``ticket`` int32 with :data:`NO_TICKET` when not queued);
    per-config state and context are (C,) vectors; ``stepi`` is the
    global step index (int32 scalar or (C,), the counter of the per-step
    RNG streams).  Every CS/NCS duration draw dispatches through the
    workload rows (:func:`workload_draw`; constant rows reproduce the
    plain uniform draw bit-identically).  Returns the 16 updated state
    arrays in the canonical order (:data:`TRANSITION_THREAD_STATE` +
    :data:`TRANSITION_CONFIG_STATE`), plus the 11 :data:`OPEN_STATE`
    arrays when ``open_state`` is given.  A closed config
    (``arrival == AR_CLOSED``) inside an open batch takes every open
    stage as an exact masked no-op, and ``tb == 0`` reproduces the
    historical thread-id tie-break bit-identically.
    """
    from repro.core import policy as P

    C, T = st.shape
    inf = jnp.float32(jnp.inf)
    tid = jnp.arange(T, dtype=jnp.int32)[None, :]              # (1, T)
    tidb = jnp.broadcast_to(tid, (C, T))
    col = lambda v: v[:, None]                                 # (C,) -> (C,1)
    active = tid < col(threads)
    (hand_f, fifo_f, budget_f, w2s_f, repark_f,
     win_f, bscale_f, backoff_f) = P.discipline_flags(policy)
    teps = dt * jnp.float32(1e-3)
    stepu = jnp.asarray(stepi).astype(jnp.uint32)  # scalar or (C,)
    stepuT = stepu if stepu.ndim == 0 else stepu[:, None]

    # Effective per-(thread, step) wake latency under the config's fault
    # row (lost wake-ups recover at the `flt_scale` timeout; jitter rows
    # stretch the delay).  The FLT_WAKE/FLT_MAG streams are salted apart
    # from every other draw; for no-fault rows the masked dispatch returns
    # `wake` bit-identically, so `col(now2) + wake_eff` reproduces the
    # historical `col(now2 + wake)` exactly.
    flt_w1 = counter_uniform(col(seed) ^ jnp.uint32(P.FLT_WAKE_SALT), tidb,
                             stepuT)
    flt_w2 = counter_uniform(col(seed) ^ jnp.uint32(P.FLT_MAG_SALT), tidb,
                             stepuT)
    # M:N environment axis: park_cost re-prices the sleep/wake round trip
    # (green threads << 1, kernel threads 1, oversubscribed VMs >> 1).
    # The default 1.0 multiplies exactly, so pre-park_cost configs are
    # bit-identical.
    wake_base = col(wake) * col(park_cost)
    wake_eff = P.fault_wake_delay(col(fault), wake_base, flt_w1, flt_w2,
                                  col(flt_rate), col(flt_scale))
    # Fissile competitive pricing: a budget_scaled row spins for about the
    # park round trip before parking — spin_budget * sws * park_cost, with
    # the oracle window sws as the adaptive multiplier.  Exact *1.0 for
    # every other row (adaptive keeps its flat glibc budget).
    budget_eff = lambda sws_now: col(spin_budget) * jnp.where(
        col(bscale_f) > 0,
        col(sws_now).astype(jnp.float32) * col(park_cost),
        jnp.float32(1.0))

    # -- open-loop admission (arrival rows; see docs/open_loop.md) --------
    # Runs FIRST so a request admitted at step i is in the system for
    # steps i..j-1 when it departs at step j — the occupancy integral
    # accumulated at the END of the step then equals the recorded latency
    # (j - i)·dt exactly (the Little's-law invariant the property tests
    # pin).  Requests carry their admission timestamp ``now2`` through
    # the ring buffer into the bound thread's ``req_t`` slot.
    open_run = open_state is not None
    if open_run:
        (req_t, qbuf, hist, qhead, qlen, arrived, shed, departed,
         slo_viol, lat_sum, occ_int) = open_state
        Q = qbuf.shape[1]
        NB = hist.shape[1]
        openc = col(arrival != P.AR_CLOSED)
        zero_u = jnp.zeros_like(seed)
        ar_phase = counter_uniform(seed ^ jnp.uint32(P.AR_PHASE_SALT),
                                   zero_u, jnp.uint32(0))
        gate_on = 1.0 - P.workload_off_gate(now2, ar_phase, wl_period,
                                            wl_duty)
        rate = P.arrival_rate_at(arrival, arr_rate, gate_on, wl_burst)
        # Bernoulli-rounded count: floor(rate·dt) plus a trial on the
        # fractional part — the expected count is exactly rate·dt, so the
        # admitted load is dt-independent (closed rows: rate 0, count 0).
        m = rate * dt
        mf = jnp.floor(m)
        u_arr = counter_uniform(seed ^ jnp.uint32(P.AR_SALT), zero_u,
                                stepu)
        n_arr = (mf + (u_arr < (m - mf))).astype(jnp.int32)
        n_adm = jnp.minimum(n_arr, q_cap - qlen)   # bounded queue: shed
        qi = jnp.arange(Q, dtype=jnp.int32)[None, :]
        wr = ((qi - col(qhead + qlen)) % Q) < col(n_adm)
        qbuf = jnp.where(wr, col(now2), qbuf)
        qlen = qlen + n_adm
        arrived = arrived + n_arr
        shed = shed + (n_arr - n_adm)

    def first_oh(mask):
        """One-hot of the lowest-tid True per row (all-False rows stay
        all-False)."""
        idx = jnp.argmax(mask, axis=-1, keepdims=True)
        return (tid == idx) & jnp.any(mask, axis=-1, keepdims=True)

    def thc_of(s):
        """Algorithm 1's thc: holder + every waiter (CS/SPIN/SLEEP/WAKING),
        per config."""
        return jnp.sum((active & (s >= P.CS) & (s <= P.WAKING))
                       .astype(jnp.int32), axis=-1)

    wl_phase_u, wl_gate_off, wl_tscale = workload_state(
        col(seed), tidb, col(now2), col(wl_period), col(wl_duty),
        col(wl_spread))

    def draw_into(mask, lo, hi, c, is_ncs=0):
        u = counter_uniform(col(seed), tidb, c)
        val = workload_draw(u, col(lo), col(hi), is_ncs, col(workload),
                            wl_gate_off, wl_tscale, col(wl_burst))
        return val, jnp.where(mask, c + jnp.uint32(1), c)

    def park(mask, st, wake_at, permits, wake_count, slept, rem):
        """DES ``_sleep``: park, absorbing banked permits (semaphore law —
        an absorbed permit still pays the park/unpark round trip)."""
        rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
        grant = mask & (rank < col(permits))
        n_grant = jnp.sum(grant.astype(jnp.int32), axis=-1)
        st = jnp.where(grant, P.WAKING,
                       jnp.where(mask, P.SLEEP_ST, st))
        wake_at = jnp.where(grant, col(now2) + wake_eff, wake_at)
        return (st, wake_at, permits - n_grant, wake_count + n_grant,
                jnp.where(mask, 1, slept), jnp.where(mask, inf, rem))

    def oracle_acquire(happened, winner_oh, thc, sws, cnt, ewma, wuc):
        """A12-A33 at an acquisition: oracle family dispatch, A16-A17
        clamp, C1/C2 correction — windowed disciplines only."""
        do = happened & (win_f > 0)
        spun_w = jnp.sum(jnp.where(winner_oh, spun, 0), axis=-1)
        # budget_scaled rows feed the oracle "did this acquisition park?"
        # alone: every fissile arrival spins first, so the raw spun flag
        # would mask the late signal and freeze the window at 1.
        spun_w = spun_w * (1 - bscale_f)
        slept_w = jnp.sum(jnp.where(winner_oh, slept, 0), axis=-1)
        delta, cnt2, ewma2 = P.oracle_update(                  # E2-E11
            oracle, spun_w, slept_w, sws, cnt, ewma, k)
        delta = jnp.clip(delta, 1 - sws, sws_max - sws)        # A16-A17
        sws2 = sws + delta                                     # A20
        tmp = jnp.where((delta < 0) & (thc > sws2), thc - sws2,       # C2
                        jnp.where((delta > 0) & (thc > sws), thc - sws,
                                  0))                                 # C1
        corr = jnp.sign(delta) * jnp.minimum(jnp.abs(delta), tmp)  # A32
        return (jnp.where(do, sws2, sws), jnp.where(do, cnt2, cnt),
                jnp.where(do, ewma2, ewma), jnp.where(do, wuc + corr, wuc))

    # -- spin-budget exhaustion -> sleep (DES stage order) -----------------
    exhausted = (st == P.SPIN) & (col(budget_f) > 0) & (rem <= REM_EPS)
    st, wake_at, permits, wake_count, slept, rem = park(
        exhausted, st, wake_at, permits, wake_count, slept, rem)

    # -- wake completions --------------------------------------------------
    due = (st == P.WAKING) & (wake_at <= col(now2 + teps))
    holder_free = ~jnp.any(st == P.CS, axis=-1, keepdims=True)
    # FIFO rows that park (hapax) keep tickets through SLEEP/WAKING, and a
    # wake completion grants the oldest ticket, not the lowest tid.  For
    # every other row (and the never-parking fifo row) due threads carry
    # no ordering constraint and the historical id pick is unchanged.
    wkey = jnp.where(due, ticket, NO_TICKET)
    winA_f = first_oh(due & (wkey == jnp.min(wkey, axis=-1, keepdims=True)))
    winA = jnp.where(col(fifo_f) > 0, winA_f, first_oh(due)) & holder_free
    cs_val, ctr = draw_into(winA, cs_lo, cs_hi, ctr)
    rem = jnp.where(winA, cs_val, rem)
    st = jnp.where(winA, P.CS, st)
    # the sleep->spin transition's payoff: a woken thread that finds the
    # lock free acquired "slept and not spun" -> EvalSWS doubles the window
    sws, cnt, ewma, wuc = oracle_acquire(jnp.any(winA, axis=-1), winA,
                                         thc_of(st), sws, cnt, ewma, wuc)
    losers = due & ~winA
    to_spin = losers & (col(w2s_f) > 0)    # woken into the spinning window
    st = jnp.where(to_spin, P.SPIN, st)
    spun = jnp.where(to_spin, 1, spun)
    # fissile (budget_spin + wake_to_spin) re-arms a fresh bounded budget;
    # the mutable row's window spinners keep the unbounded inf sentinel
    rem = jnp.where(to_spin,
                    jnp.where(col(budget_f) > 0, budget_eff(sws), inf),
                    rem)
    to_park = losers & (col(repark_f) > 0)     # barged: park again
    st, wake_at, permits, wake_count, slept, rem = park(
        to_park, st, wake_at, permits, wake_count, slept, rem)

    # -- CS completion / release ------------------------------------------
    holder_done = (st == P.CS) & (rem <= REM_EPS)
    rel = jnp.any(holder_done, axis=-1)
    completed = completed + rel.astype(jnp.int32)
    completed_pt = completed_pt + holder_done.astype(jnp.int32)
    thc_pre = thc_of(st)                                   # R14 (pre-FAD)
    do_latch = rel & (win_f > 0)
    r_wuc = jnp.where(do_latch & (wuc >= 0), wuc, -1)      # R2-R6
    wuc = jnp.where(do_latch, jnp.where(wuc >= 0, 0, wuc + 1), wuc)  # R4/R7
    ncs_val, ctr = draw_into(holder_done, ncs_lo, ncs_hi, ctr, is_ncs=1)
    rem = jnp.where(holder_done, ncs_val, rem)
    st = jnp.where(holder_done, P.NCS, st)                 # R9-R10
    # -- open-loop departure: an open config's completed request leaves
    # the system instead of drawing a fresh NCS — latency = now2 - req_t
    # lands in the log-spaced histogram and the SLO/latency counters; the
    # thread slot frees (DONE) for the end-of-step binding stage.
    if open_run:
        depart = holder_done & openc
        latv = col(now2) - req_t
        binv = jnp.clip(
            jnp.floor(jnp.log2(jnp.maximum(latv, jnp.float32(1e-30))
                               / jnp.float32(P.LAT_BIN0))
                      * jnp.float32(P.LAT_BINS_PER_OCTAVE)),
            0, NB - 1).astype(jnp.int32)
        has_dep = jnp.any(depart, axis=-1)
        dep_bin = jnp.sum(jnp.where(depart, binv, 0), axis=-1)
        nbi = jnp.arange(NB, dtype=jnp.int32)[None, :]
        hist = hist + ((nbi == dep_bin[:, None]) & has_dep[:, None]
                       ).astype(jnp.int32)
        lat_sum = lat_sum + jnp.sum(jnp.where(depart, latv, 0.0), axis=-1)
        departed = departed + has_dep.astype(jnp.int32)
        slo_viol = slo_viol + jnp.sum(
            (depart & (latv > col(slo))).astype(jnp.int32), axis=-1)
        st = jnp.where(depart, P.DONE, st)
        rem = jnp.where(depart, inf, rem)
        req_t = jnp.where(depart, jnp.float32(-1.0), req_t)
    # handoff: grant priority is the arrival ticket for FIFO rows, the
    # thread id otherwise — or, with tie_break="random", a fresh seeded
    # per-(thread, step) key (the DES picks a spinner at random; tb == 0
    # keeps the historical id order bit-identically, equal random keys
    # fall back to it)
    spinners = st == P.SPIN
    can_handoff = rel & (hand_f > 0) & jnp.any(spinners, axis=-1)
    tb_u = counter_uniform(col(seed) ^ jnp.uint32(P.TB_SALT), tidb, stepuT)
    rkey = (tb_u * jnp.float32(2 ** 23)).astype(jnp.int32)
    key = jnp.where(spinners,
                    jnp.where(col(fifo_f) > 0, ticket,
                              jnp.where(col(tb) > 0, rkey, tidb)),
                    NO_TICKET)
    cand = spinners & (key == jnp.min(key, axis=-1, keepdims=True))
    winB = first_oh(cand) & col(can_handoff)
    cs_valB, ctr = draw_into(winB, cs_lo, cs_hi, ctr)
    rem = jnp.where(winB, cs_valB, rem)
    st = jnp.where(winB, P.CS, st)
    sws, cnt, ewma, wuc = oracle_acquire(can_handoff, winB, thc_pre - 1,
                                         sws, cnt, ewma, wuc)
    # wake quota: per-discipline rule (R11-R21 for the mutable row,
    # wake-one for sleep/adaptive, none for pure spin/FIFO)
    n_parked = jnp.sum(((st == P.SLEEP_ST) | (st == P.WAKING))
                       .astype(jnp.int32), axis=-1)
    quota = P.discipline_release_quota(policy, r_wuc, thc_pre, sws,
                                       n_parked,
                                       can_handoff.astype(jnp.int32))
    quota = jnp.where(rel, quota, 0)
    sleepers = st == P.SLEEP_ST
    rank_s = jnp.cumsum(sleepers.astype(jnp.int32), axis=-1) - 1
    sel_id = sleepers & (rank_s < col(quota))
    # FIFO rows wake the oldest ticket first (hapax head-of-queue unlock;
    # their quota is 0/1, so the single min-ticket pick covers it) — the
    # never-parking fifo row has no sleepers, leaving sel_id untouched.
    skey = jnp.where(sleepers, ticket, NO_TICKET)
    sel_f = first_oh(sleepers
                     & (skey == jnp.min(skey, axis=-1, keepdims=True))) \
        & (col(quota) > 0)
    sel = jnp.where(col(fifo_f) > 0, sel_f, sel_id)
    n_sel = jnp.sum(sel.astype(jnp.int32), axis=-1)
    st = jnp.where(sel, P.WAKING, st)
    wake_at = jnp.where(sel, col(now2) + wake_eff, wake_at)
    wake_count = wake_count + n_sel
    permits = permits + (quota - n_sel)    # park-free permits are banked

    # -- ttas_backoff polls (backoff rows only; exact no-op otherwise) ----
    # A handoff=0 release just frees the lock, so the poll IS the acquire
    # path: an eligible spinner (next-poll time reached, lock free) picks
    # the lock up here; every other eligible poller re-arms with a
    # truncated-binary-exponential delay ``spin_budget * 2^min(attempt,
    # BO_CAP) * u`` from the dedicated BO_SALT stream.  Backoff rows never
    # park, so ``wake_at`` doubles as the next-poll time and ``ticket`` as
    # the failed-attempt counter (both unread by the generic stages for
    # spinning threads).
    bo_u = counter_uniform(col(seed) ^ jnp.uint32(P.BO_SALT), tidb, stepuT)
    poll = (st == P.SPIN) & (col(backoff_f) > 0) \
        & (wake_at <= col(now2 + teps))
    holder_freeP = ~jnp.any(st == P.CS, axis=-1, keepdims=True)
    winP = first_oh(poll) & holder_freeP
    cs_valP, ctr = draw_into(winP, cs_lo, cs_hi, ctr)
    rem = jnp.where(winP, cs_valP, rem)
    st = jnp.where(winP, P.CS, st)
    poll_fail = poll & ~winP
    ticket = jnp.where(poll_fail, ticket + 1, ticket)
    bo_exp = jnp.exp2(jnp.minimum(ticket, P.BO_CAP).astype(jnp.float32))
    wake_at = jnp.where(poll_fail,
                        col(now2) + col(spin_budget) * bo_exp * bo_u,
                        wake_at)

    # -- arrivals (NCS finished) ------------------------------------------
    arr = (st == P.NCS) & (rem <= REM_EPS) & active
    thc_base = thc_of(st)
    rank_a = jnp.cumsum(arr.astype(jnp.int32), axis=-1) - 1
    thc_pre_i = col(thc_base) + rank_a                     # A4 per arrival
    slept = jnp.where(arr, 0, slept)                       # A3
    spun = jnp.where(arr, 0, spun)
    holder_free2 = ~jnp.any(st == P.CS, axis=-1, keepdims=True)
    sleeps = arr & (P.discipline_arrival_sleeps(
        col(policy), rank_a, thc_pre_i, col(sws),
        holder_free2.astype(jnp.int32)) > 0)               # A7 per row
    nonsleep = arr & ~sleeps
    winC = first_oh(nonsleep) & holder_free2
    cs_valC, ctr = draw_into(winC, cs_lo, cs_hi, ctr)
    rem = jnp.where(winC, cs_valC, rem)
    st = jnp.where(winC, P.CS, st)
    sws, cnt, ewma, wuc = oracle_acquire(jnp.any(winC, axis=-1), winC,
                                         thc_base + 1, sws, cnt, ewma, wuc)
    to_spinC = nonsleep & ~winC
    st = jnp.where(to_spinC, P.SPIN, st)
    spun = jnp.where(to_spinC, 1, spun)
    rem = jnp.where(to_spinC,
                    jnp.where(col(budget_f) > 0, budget_eff(sws), inf),
                    rem)
    # ticket-order bookkeeping: every new waiter takes the next ticket
    # (rank order within the step); only FIFO rows read them for grants.
    # FIFO rows that park (hapax) ticket their parking arrivals too — for
    # every other row the joiner set is exactly the new spinners.
    joiners = to_spinC | (sleeps & (col(fifo_f) > 0))
    rank_t = jnp.cumsum(joiners.astype(jnp.int32), axis=-1) - 1
    ticket = jnp.where(joiners, col(nticket) + rank_t, ticket)
    nticket = nticket + jnp.sum(joiners.astype(jnp.int32), axis=-1)
    # backoff rows: a new spinner starts its attempt counter at 0 and
    # schedules its first re-poll within one base delay
    bo_new = to_spinC & (col(backoff_f) > 0)
    ticket = jnp.where(bo_new, 0, ticket)
    wake_at = jnp.where(bo_new, col(now2) + col(spin_budget) * bo_u,
                        wake_at)
    st, wake_at, permits, wake_count, slept, rem = park(
        sleeps, st, wake_at, permits, wake_count, slept, rem)
    # retire tickets: spinners keep theirs; FIFO rows that park keep them
    # through SLEEP/WAKING so grants stay in arrival order
    queued = (st == P.SPIN) | ((col(fifo_f) > 0)
                               & ((st == P.SLEEP_ST) | (st == P.WAKING)))
    ticket = jnp.where(queued, ticket, NO_TICKET)

    if not open_run:
        return (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt,
                sws, cnt, ewma, wuc, permits, nticket, completed,
                wake_count)

    # -- open-loop binding: queued requests claim free thread slots (DONE
    # under an open config) in queue order, entering NCS with a workload
    # draw and carrying their admission timestamp; then the occupancy
    # integral accumulates LAST, so every in-system request (queued or
    # bound) is counted for exactly the steps between its admission and
    # its departure.
    freem = active & (st == P.DONE) & openc
    rank_f = jnp.cumsum(freem.astype(jnp.int32), axis=-1) - 1
    n_free = jnp.sum(freem.astype(jnp.int32), axis=-1)
    n_bind = jnp.minimum(qlen, n_free)
    bindm = freem & (rank_f < col(n_bind))
    qpos = (col(qhead) + rank_f) % Q
    rt = jnp.take_along_axis(qbuf, qpos, axis=1)
    ncs_b, ctr = draw_into(bindm, ncs_lo, ncs_hi, ctr, is_ncs=1)
    st = jnp.where(bindm, P.NCS, st)
    rem = jnp.where(bindm, ncs_b, rem)
    req_t = jnp.where(bindm, rt, req_t)
    slept = jnp.where(bindm, 0, slept)
    spun = jnp.where(bindm, 0, spun)
    qhead = (qhead + n_bind) % Q
    qlen = qlen - n_bind
    busy = jnp.sum((active & (req_t >= 0.0)).astype(jnp.int32), axis=-1)
    occ_int = occ_int + (qlen + busy).astype(jnp.float32) * dt

    return (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt,
            sws, cnt, ewma, wuc, permits, nticket, completed, wake_count,
            req_t, qbuf, hist, qhead, qlen, arrived, shed, departed,
            slo_viol, lat_sum, occ_int)


# --------------------------------------------------------------------------
# Time-blocked fused rollout body: GPS advance + transitions iterated for
# ``n_sub_steps`` timesteps in ONE call, with the whole (C, T) state block
# staying in registers/VMEM across the inner loop.  This is the reference
# twin of the Pallas kernel repro.kernels.lock_sim.lock_sim_block (which
# applies THIS function per config block), and the body repro.core.xdes
# drives from its chunked while_loop: the outer rollout shrinks from
# ``n_steps`` dispatches to ``n_steps / n_sub_steps``.
# --------------------------------------------------------------------------

#: Context columns of the block boundary, after the per-step state: the GPS
#: advance inputs, then the transition context minus ``now2`` (recomputed
#: inside the loop as ``(step0 + s + 1) * dt`` — the exact expression of
#: the per-step path, so blocked and per-step rollouts are bit-identical).
BLOCK_CONTEXT = ("step0", "limit", "alpha", "cores", "has_budget",
                 "policy", "threads", "dt", "wake", "cs_lo", "cs_hi",
                 "ncs_lo", "ncs_hi", "k", "sws_max", "spin_budget", "seed",
                 "oracle", "workload", "wl_period", "wl_duty", "wl_burst",
                 "wl_spread", "arrival", "arr_rate", "q_cap", "slo", "tb",
                 "fault", "flt_rate", "flt_scale", "park_cost")


def lock_sim_block_ref(st, rem, wake_at, slept, spun, ctr, ticket,
                       completed_pt, sws, cnt, ewma, wuc, permits, nticket,
                       completed, wake_count, spin_cpu,
                       step0, alpha, cores, has_budget,
                       policy, threads, dt, wake, cs_lo, cs_hi,
                       ncs_lo, ncs_hi, k, sws_max, spin_budget, seed,
                       oracle, workload, wl_period, wl_duty, wl_burst,
                       wl_spread, arrival, arr_rate, q_cap, slo, tb,
                       fault, flt_rate, flt_scale, park_cost,
                       *, n_sub_steps: int, limit=None, open_state=None):
    """``n_sub_steps`` fused timesteps for a (C, T) block of configurations.

    Each sub-step is exactly one per-step iteration of the legacy rollout
    — :func:`lock_sim_step_ref` (GPS advance) followed by
    :func:`lock_transitions_ref` — with ``now2 = (step0 + s + 1) * dt``
    computed from the global step index ``step0 + s`` in int32 before the
    float multiply, and ``spin_cpu`` accumulated inside the loop in the
    same order as the per-step carry.  Both choices make the blocked
    rollout bit-identical to the per-step path (pinned by tests).

    State is the 16 transition arrays plus ``spin_cpu`` (C,) f32;
    ``step0`` is the global index of the first sub-step (int32 scalar or
    (C,) vector); the remaining context matches
    :data:`TRANSITION_CONTEXT`/``has_budget`` of the advance.  Returns the
    17 updated state arrays — plus the 11 :data:`OPEN_STATE` arrays,
    carried through the loop and masked by ``limit`` exactly like the
    closed state, when ``open_state`` is given (open-loop batches).

    ``limit`` (int32 scalar or (C,) vector, optionally traced) caps the
    global step index: sub-steps with ``step0 + s >= limit`` select the
    pre-step state unchanged (a ``where`` passthrough), so a partial tail
    block of ``limit - step0`` live sub-steps is bit-identical to running
    exactly that many steps.  This is what lets the blocked rollout treat
    the total step count as a traced value (one compiled executable per
    padded shape instead of one per horizon).  ``limit=None`` keeps the
    legacy unmasked graph.
    """

    n_open = 0 if open_state is None else len(open_state)

    def body(s, carry):
        state, cpu = carry[:16], carry[16]
        ostate = carry[17:]
        st_s, rem_s = state[0], state[1]
        i = step0 + s
        now2 = (i.astype(jnp.float32) + 1.0) * dt
        rem_s, burn = lock_sim_step_ref(st_s, rem_s, alpha, cores, dt,
                                        has_budget)
        rem_s = fault_rewind(st_s, rem_s, alpha, cores, dt,
                             i.astype(jnp.float32) * dt, seed, fault,
                             flt_rate, flt_scale)
        out = lock_transitions_ref(st_s, rem_s, *state[2:], now2, i,
                                   policy, threads, dt, wake, cs_lo,
                                   cs_hi, ncs_lo, ncs_hi, k, sws_max,
                                   spin_budget, seed, oracle, workload,
                                   wl_period, wl_duty, wl_burst,
                                   wl_spread, arrival, arr_rate, q_cap,
                                   slo, tb, fault, flt_rate, flt_scale,
                                   park_cost,
                                   open_state=ostate if n_open else None)
        new, onew = out[:16], out[16:]
        if limit is None:
            return (*new, cpu + burn, *onew)
        act = i < limit                       # bool scalar or (C,)
        actT = act[..., None] if jnp.ndim(act) else act   # (C, 1) for (C, T)
        state = tuple(jnp.where(actT if n.ndim == 2 else act, n, o)
                      for n, o in zip(new, state))
        ostate = tuple(jnp.where(actT if n.ndim == 2 else act, n, o)
                       for n, o in zip(onew, ostate))
        return (*state, cpu + jnp.where(act, burn, 0.0), *ostate)

    carry = (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt,
             sws, cnt, ewma, wuc, permits, nticket, completed, wake_count,
             spin_cpu, *(open_state or ()))
    return jax.lax.fori_loop(0, n_sub_steps, body, carry)


def oracle_update_ref(oracle_id, spun, slept, sws, cnt, ewma, k, sws_max):
    """Batched SWS-oracle observation over ``(C,)`` config vectors.

    Pure-jnp reference for the fused Pallas kernel
    :func:`repro.kernels.lock_sim.oracle_step`: one observation of every
    oracle family row (:data:`repro.core.policy.ORACLE_ROWS` — paper
    EvalSWS, AIMD, fixed-budget retrial, history EWMA) dispatched by
    ``oracle_id``, with the A16-A17 clamp applied.  All inputs int32
    except ``spun``/``slept`` (bool or 0/1 int32).  Returns
    ``(delta, cnt', ewma')`` with ``1 <= sws + delta <= sws_max``.
    """
    from repro.core.policy import oracle_update

    delta, cnt1, ewma1 = oracle_update(oracle_id, spun, slept, sws, cnt,
                                       ewma, k)
    delta = jnp.clip(delta, 1 - sws, sws_max - sws)
    return delta, cnt1, ewma1

"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *definitions*, not fast paths: direct dense math, f32 accumulate.
The model code has its own (chunked/blockwise) implementations; tests check
kernel == ref and model-path == ref independently.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (BH, Sq, hd); k, v: (BKV, Sk, hd)."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    g = BH // BKV
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qp >= kp
    if window:
        m &= (qp - kp) < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """Sequential definition.  r,k,v,w: (BH, T, n); u: (BH, n)."""
    BH, T, n = r.shape
    S = (jnp.zeros((BH, n, n), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(S, t):
        r_t, k_t, v_t, w_t = t                               # (BH, n)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bk,bkv->bv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    ts = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    S, ys = jax.lax.scan(step, S, ts)
    return ys.swapaxes(0, 1), S


def mamba_scan_ref(dt, x, Bm, Cm, a):
    """Sequential definition.  dt,x: (B,T,d); Bm,Cm: (B,T,N); a: (d,N)."""
    B, T, d = x.shape
    N = a.shape[-1]
    s0 = jnp.zeros((B, d, N), jnp.float32)

    def step(s, t):
        dt_t, x_t, B_t, C_t = t
        da = jnp.exp(dt_t[..., None] * a)
        s = s * da + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", s, C_t)
        return s, y

    ts = tuple(v.swapaxes(0, 1).astype(jnp.float32)
               for v in (dt, x, Bm, Cm))
    _, ys = jax.lax.scan(step, s0, ts)
    return ys.swapaxes(0, 1)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def lock_sim_step_ref(tstate, rem, alpha, cores, dt, has_budget):
    """One generalized-processor-sharing advance of the batched lock sim.

    The hot inner update of :mod:`repro.core.xdes` (paper §2 model): every
    runnable thread advances at rate ``min(1, cores / n_runnable)``; the CS
    holder is additionally slowed by cache-coherency pressure
    ``1 / (1 + alpha * n_spinners)``; spinners burn CPU, and the adaptive
    discipline's spinners consume their spin budget.

    tstate: (C, T) int32 thread states (repro.core.policy encoding);
    rem:    (C, T) f32 remaining work (CS/NCS) or spin budget (adaptive);
    alpha, cores, dt: (C,) f32; has_budget: (C,) bool.
    Returns ``(rem', spin_burn)`` with spin_burn (C,) f32 — the CPU-seconds
    burnt spinning this step (the paper's sync-waste metric).
    """
    from repro.core.policy import CS, NCS, SPIN

    is_cs = tstate == CS
    is_ncs = tstate == NCS
    is_spin = tstate == SPIN
    n_run = jnp.sum(is_cs | is_ncs | is_spin, axis=-1).astype(jnp.float32)
    n_spin = jnp.sum(is_spin, axis=-1).astype(jnp.float32)
    rate = jnp.minimum(1.0, cores / jnp.maximum(n_run, 1.0))
    holder_rate = rate / (1.0 + alpha * n_spin)
    d_rate = dt * rate
    burn = jnp.where(is_spin, d_rate[:, None], 0.0)
    dec = (jnp.where(is_cs, (dt * holder_rate)[:, None], 0.0)
           + jnp.where(is_ncs, d_rate[:, None], 0.0)
           + jnp.where(has_budget[:, None], burn, 0.0))
    return rem - dec, jnp.sum(burn, axis=-1)


def oracle_update_ref(oracle_id, spun, slept, sws, cnt, ewma, k, sws_max):
    """Batched SWS-oracle observation over ``(C,)`` config vectors.

    Pure-jnp reference for the fused Pallas kernel
    :func:`repro.kernels.lock_sim.oracle_step`: one observation of every
    oracle family row (:data:`repro.core.policy.ORACLE_ROWS` — paper
    EvalSWS, AIMD, fixed-budget retrial, history EWMA) dispatched by
    ``oracle_id``, with the A16-A17 clamp applied.  All inputs int32
    except ``spun``/``slept`` (bool or 0/1 int32).  Returns
    ``(delta, cnt', ewma')`` with ``1 <= sws + delta <= sws_max``.
    """
    from repro.core.policy import oracle_update

    delta, cnt1, ewma1 = oracle_update(oracle_id, spun, slept, sws, cnt,
                                       ewma, k)
    delta = jnp.clip(delta, 1 - sws, sws_max - sws)
    return delta, cnt1, ewma1

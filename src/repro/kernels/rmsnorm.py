"""Fused RMSNorm Pallas kernel.

y = x * rsqrt(mean(x^2) + eps) * (1 + w) — one HBM pass instead of the
three (square-reduce, rsqrt-broadcast, scale) an unfused lowering makes.
Grid tiles rows (everything before the feature dim); the feature dim stays
whole in VMEM (d_model ≤ 8192 → ≤ 32 KB/row in f32, trivially resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .pallas_compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                        # (bm, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x: (..., D); w: (D,).  Returns x.dtype."""
    shape = x.shape
    D = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    bm = min(block_rows, rows)
    pr = (-rows) % bm
    if pr:
        x2 = jnp.pad(x2, ((0, pr), (0, 0)))
    nm = (rows + pr) // bm

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pr, D), x.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
    )(x2, w)
    return out[:rows].reshape(shape)

"""Pallas kernels for the batched lock simulator.

The stages of one :mod:`repro.core.xdes` timestep live here as fused
kernels, bit-identical to their XLA references in :mod:`repro.kernels.ref`:

* :func:`lock_sim_block` — the time-blocked rollout kernel (the default
  engine path): GPS advance + oracle update + transitions iterated for
  ``n_sub_steps`` timesteps in ONE dispatch, the whole
  ``(block_configs, T)`` state block staying in VMEM/registers across the
  inner loop.  The body IS
  :func:`repro.kernels.ref.lock_sim_block_ref` applied per block.
* :func:`lock_sim_step` — the standalone GPS advance: runnable counts, the
  generalized-processor-sharing rate ``min(1, cores/n_runnable)``, the
  cache-contention slowdown of the CS holder (``1/(1 + alpha·n_spinners)``,
  paper §2), work advance and spin-CPU burn — one VMEM-resident pass over
  the ``(configs, threads)`` state block (the legacy per-step scan path).
* :func:`lock_transitions_step` — the transition stage (budget exhaustion,
  wake completions, release/handoff with discipline-row dispatch incl.
  FIFO ticket grants, arrivals) as a grid over config blocks.  The kernel
  body IS :func:`repro.kernels.ref.lock_transitions_ref` applied to each
  block, so ref and Pallas backends share one implementation and stay
  bit-identical by construction (and by test).
* :func:`oracle_step` — the standalone fused SWS-oracle observation.

Rows are configurations (grid-parallel); the thread axis stays whole in
VMEM (T ≤ 128 lanes after padding — a few KB per row).  ``interpret=None``
auto-detects: interpret mode on CPU-only hosts, compiled lowering when a
GPU/TPU is attached (:func:`repro.kernels.pallas_compat.default_interpret`).

The row-registry contract: all policy decisions inside these kernels —
oracle families, waiting disciplines, workload hold-time models — come
from the registries in :mod:`repro.core.policy` (``ORACLE_ROWS``,
``DISCIPLINE_ROWS``, ``WORKLOAD_ROWS``), dispatched per config by integer
columns with masked arithmetic selects.  Adding a row therefore never
touches this module: the Pallas kernels apply the *ref* bodies per block,
so a row lands in :mod:`repro.kernels.ref` once and both lowerings stay
bit-identical by construction.  When changing kernel signatures, update
the context tuples in lockstep: ``TRANSITION_CONTEXT``/``BLOCK_CONTEXT``
(ref), ``_CONTEXT_DTYPES``/``_BLOCK_CTX_DTYPES`` (here) and
``_PRM_FIELDS`` (:mod:`repro.core.xdes`).  Blocked-rollout invariants:
``now2 = (step0 + s + 1) * dt`` with the step index carried in int32, and
``spin_cpu`` accumulated inside the inner loop — both required for the
blocked path to stay bit-identical to the per-step scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.policy import CS, NCS, SPIN, oracle_update

from .pallas_compat import CompilerParams, resolve_interpret
from .ref import NO_TICKET, lock_sim_block_ref, lock_transitions_ref

LANE = 128          # TPU lane width: thread axis is padded to this


def _kernel(state_ref, rem_ref, alpha_ref, cores_ref, dt_ref, budget_ref,
            rem_out_ref, burn_out_ref):
    st = state_ref[...]                                       # (bc, T) int32
    rem = rem_ref[...]                                        # (bc, T) f32
    is_cs = st == CS
    is_ncs = st == NCS
    is_spin = st == SPIN
    n_run = jnp.sum((is_cs | is_ncs | is_spin).astype(jnp.float32),
                    axis=-1, keepdims=True)                   # (bc, 1)
    n_spin = jnp.sum(is_spin.astype(jnp.float32), axis=-1, keepdims=True)
    cores = cores_ref[...]                                    # (bc, 1)
    rate = jnp.minimum(1.0, cores / jnp.maximum(n_run, 1.0))
    holder_rate = rate / (1.0 + alpha_ref[...] * n_spin)
    dt = dt_ref[...]                                          # (bc, 1)
    d_rate = dt * rate
    burn = jnp.where(is_spin, d_rate, 0.0)
    dec = (jnp.where(is_cs, dt * holder_rate, 0.0)
           + jnp.where(is_ncs, d_rate, 0.0)
           + jnp.where(budget_ref[...] > 0, burn, 0.0))
    rem_out_ref[...] = rem - dec
    burn_out_ref[...] = jnp.sum(burn, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_configs", "interpret"))
def lock_sim_step(tstate, rem, alpha, cores, dt, has_budget, *,
                  block_configs: int = 256, interpret: bool | None = None):
    """Pallas-fused GPS advance; signature mirrors ``lock_sim_step_ref``.

    tstate: (C, T) int32; rem: (C, T) f32; alpha/cores/dt: (C,) f32;
    has_budget: (C,) bool.  Returns ``(rem', spin_burn)``.
    ``interpret=None`` auto-detects the backend (interpret iff no GPU/TPU).
    """
    interpret = resolve_interpret(interpret)
    C, T = tstate.shape
    bc = min(block_configs, C)
    pc = (-C) % bc
    pt = (-T) % LANE
    # Pad threads to the lane width with DONE-state slots (no rate effect)
    # and configs to the block size.
    st2 = jnp.pad(tstate, ((0, pc), (0, pt)), constant_values=5)  # DONE
    rem2 = jnp.pad(rem, ((0, pc), (0, pt)))
    col = lambda v, dt_: jnp.pad(v.astype(dt_), (0, pc))[:, None]
    nc = (C + pc) // bc

    rem_new, burn = pl.pallas_call(
        _kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C + pc, T + pt), jnp.float32),
            jax.ShapeDtypeStruct((C + pc, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(st2, rem2, col(alpha, jnp.float32), col(cores, jnp.float32),
      col(dt, jnp.float32), col(has_budget, jnp.int32))
    return rem_new[:C, :T], burn[:C, 0]


# --------------------------------------------------------------------------
# Fused SWS-oracle observation: one elementwise pass over (C,) config
# vectors evaluating every oracle family row (paper EvalSWS / AIMD /
# fixed-budget / history, repro.core.policy.ORACLE_ROWS) and selecting by
# oracle id, A16-A17 clamp included.  This is the building block for
# moving the scan body's transition stage into the kernel (scalar-prefetch
# grid over configs); the batched simulator evaluates the same rows today
# via repro.core.policy inside its vmapped transition step, and tests pin
# kernel == ref == scalar rows bit-identically.
# --------------------------------------------------------------------------
def _oracle_kernel(oid_ref, spun_ref, slept_ref, sws_ref, cnt_ref,
                   ewma_ref, k_ref, smax_ref,
                   delta_out_ref, cnt_out_ref, ewma_out_ref):
    sws = sws_ref[...]
    delta, cnt1, ewma1 = oracle_update(
        oid_ref[...], spun_ref[...], slept_ref[...], sws,
        cnt_ref[...], ewma_ref[...], k_ref[...])
    delta_out_ref[...] = jnp.clip(delta, 1 - sws, smax_ref[...] - sws)
    cnt_out_ref[...] = cnt1
    ewma_out_ref[...] = ewma1


@functools.partial(jax.jit, static_argnames=("block_configs", "interpret"))
def oracle_step(oracle_id, spun, slept, sws, cnt, ewma, k, sws_max, *,
                block_configs: int = 1024, interpret: bool | None = None):
    """Pallas-fused oracle observation; signature mirrors
    :func:`repro.kernels.ref.oracle_update_ref`.

    All inputs ``(C,)``: ``oracle_id/sws/cnt/ewma/k/sws_max`` int32,
    ``spun``/``slept`` bool or 0/1 int32.  Returns ``(delta, cnt', ewma')``
    int32 with the A16-A17 clamp applied to ``delta``.
    ``interpret=None`` auto-detects the backend (interpret iff no GPU/TPU).
    """
    interpret = resolve_interpret(interpret)
    C = oracle_id.shape[0]
    bc = min(block_configs, C)
    pc = (-C) % bc
    nc = (C + pc) // bc
    col = lambda v: jnp.pad(v.astype(jnp.int32), (0, pc))[:, None]
    spec = pl.BlockSpec((bc, 1), lambda i: (i, 0))

    delta, cnt1, ewma1 = pl.pallas_call(
        _oracle_kernel,
        grid=(nc,),
        in_specs=[spec] * 8,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((C + pc, 1), jnp.int32)] * 3,
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(col(oracle_id), col(spun), col(slept), col(sws), col(cnt),
      col(ewma), col(k), col(sws_max))
    return delta[:C, 0], cnt1[:C, 0], ewma1[:C, 0]


# --------------------------------------------------------------------------
# Fused transition stage: the whole discipline-row state machine (budget
# exhaustion -> wakes -> release/handoff -> arrivals) as ONE kernel over
# (block_configs, T) state blocks.  The body is literally
# repro.kernels.ref.lock_transitions_ref applied per block, so the two
# backends cannot drift: same code, same dtypes, bit-identical results
# (padded thread lanes sit in DONE state and padded config rows have
# threads=0, so neither contributes to any mask or reduction).
# --------------------------------------------------------------------------

#: (name, dtype, thread-axis pad value) of the 8 (C, T) state arrays, in
#: the canonical TRANSITION_THREAD_STATE order.
_THREAD_STATE_SPEC = (
    ("st", jnp.int32, 5),               # DONE — inert in every mask
    ("rem", jnp.float32, 0),
    ("wake_at", jnp.float32, 0),
    ("slept", jnp.int32, 0),
    ("spun", jnp.int32, 0),
    ("ctr", jnp.uint32, 0),
    ("ticket", jnp.int32, NO_TICKET),
    ("completed_pt", jnp.int32, 0),
)

#: dtypes of the 29 per-config context columns (TRANSITION_CONTEXT order).
_CONTEXT_DTYPES = (
    jnp.float32,                        # now2
    jnp.int32,                          # stepi (per-step RNG counter)
    jnp.int32, jnp.int32,               # policy, threads
    jnp.float32, jnp.float32,           # dt, wake
    jnp.float32, jnp.float32, jnp.float32, jnp.float32,  # cs/ncs lo/hi
    jnp.int32, jnp.int32,               # k, sws_max
    jnp.float32,                        # spin_budget
    jnp.uint32, jnp.int32,              # seed, oracle
    jnp.int32,                          # workload
    jnp.float32, jnp.float32, jnp.float32, jnp.float32,  # wl_* knobs
    jnp.int32, jnp.float32,             # arrival, arr_rate
    jnp.int32, jnp.float32, jnp.int32,  # q_cap, slo, tb
    jnp.int32, jnp.float32, jnp.float32,  # fault, flt_rate, flt_scale
    jnp.float32,                        # park_cost
)

_N_THREAD, _N_CONF, _N_CTX = 8, 8, len(_CONTEXT_DTYPES)

#: dtypes of the 8 (C,) open-loop counter columns (OPEN_STATE[3:] order:
#: qhead, qlen, arrived, shed, departed, slo_viol int32; lat_sum, occ_int
#: float32).  The first three OPEN_STATE arrays are 2-d: ``req_t`` (C, T)
#: f32 (padded thread lanes hold the -1 free sentinel), ``qbuf``
#: (C, QUEUE_MAX) f32 and ``hist`` (C, LAT_NBINS) i32 (their second axes
#: are never thread-padded).
_OPEN_COL_DTYPES = (jnp.int32,) * 6 + (jnp.float32,) * 2
_N_OPEN = 3 + len(_OPEN_COL_DTYPES)


def _pad_open(open_state, pc, pt):
    """Pad the 11 OPEN_STATE arrays to the kernel's block grid: config
    rows with copies of zero / free sentinels, thread lanes of ``req_t``
    with -1 (free — inert in the busy count, which is also gated by
    ``threads``)."""
    req_t, qbuf, hist = open_state[:3]
    padded = [jnp.pad(req_t.astype(jnp.float32), ((0, pc), (0, pt)),
                      constant_values=-1.0),
              jnp.pad(qbuf.astype(jnp.float32), ((0, pc), (0, 0))),
              jnp.pad(hist.astype(jnp.int32), ((0, pc), (0, 0)))]
    padded += [jnp.pad(v.astype(d), (0, pc))[:, None]
               for v, d in zip(open_state[3:], _OPEN_COL_DTYPES)]
    return padded


def _open_specs_shapes(open_state, bc, C, pc, Tp, mat, colspec):
    """(in/out specs, out shapes) for the 11 OPEN_STATE kernel operands."""
    Qn = open_state[1].shape[1]
    NBn = open_state[2].shape[1]
    specs = [mat, pl.BlockSpec((bc, Qn), lambda i: (i, 0)),
             pl.BlockSpec((bc, NBn), lambda i: (i, 0))] + [colspec] * 8
    shapes = [jax.ShapeDtypeStruct((C + pc, Tp), jnp.float32),
              jax.ShapeDtypeStruct((C + pc, Qn), jnp.float32),
              jax.ShapeDtypeStruct((C + pc, NBn), jnp.int32)] \
        + [jax.ShapeDtypeStruct((C + pc, 1), d) for d in _OPEN_COL_DTYPES]
    return specs, shapes


def _read_open(orefs):
    """Materialize the open-state refs for the ref body: 2-d arrays whole,
    counter columns squeezed to (C,)."""
    return [orefs[0][...], orefs[1][...], orefs[2][...]] \
        + [r[...][:, 0] for r in orefs[3:]]


def _transitions_kernel(open_run, *refs):
    n_in = _N_THREAD + _N_CONF + _N_CTX + (_N_OPEN if open_run else 0)
    ins, outs = refs[:n_in], refs[n_in:]
    thread = [r[...] for r in ins[:_N_THREAD]]
    conf = [r[...][:, 0] for r in ins[_N_THREAD:_N_THREAD + _N_CONF]]
    base = _N_THREAD + _N_CONF
    ctx = [r[...][:, 0] for r in ins[base:base + _N_CTX]]
    ostate = _read_open(ins[base + _N_CTX:]) if open_run else None
    out = lock_transitions_ref(*thread, *conf, *ctx, open_state=ostate)
    for r, v in zip(outs, out):
        r[...] = v if v.ndim == 2 else v[:, None]


@functools.partial(jax.jit, static_argnames=("block_configs", "interpret"))
def lock_transitions_step(st, rem, wake_at, slept, spun, ctr, ticket,
                          completed_pt, sws, cnt, ewma, wuc, permits,
                          nticket, completed, wake_count,
                          now2, stepi, policy, threads, dt, wake, cs_lo,
                          cs_hi, ncs_lo, ncs_hi, k, sws_max, spin_budget,
                          seed, oracle, workload, wl_period, wl_duty,
                          wl_burst, wl_spread, arrival, arr_rate, q_cap,
                          slo, tb, fault, flt_rate, flt_scale, park_cost, *,
                          open_state=None,
                          block_configs: int = 256,
                          interpret: bool | None = None):
    """Pallas-fused transition stage; signature mirrors
    :func:`repro.kernels.ref.lock_transitions_ref` and returns the same
    16 updated state arrays (27 with ``open_state``, the 11 OPEN_STATE
    arrays appended).  ``interpret=None`` auto-detects the backend
    (interpret iff no GPU/TPU is attached)."""
    interpret = resolve_interpret(interpret)
    C, T = st.shape
    bc = min(block_configs, C)
    pc = (-C) % bc
    pt = (-T) % LANE
    Tp = T + pt
    nc = (C + pc) // bc

    thread_in = []
    for arr, (_, dtype, padval) in zip(
            (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt),
            _THREAD_STATE_SPEC):
        thread_in.append(jnp.pad(arr.astype(dtype), ((0, pc), (0, pt)),
                                 constant_values=padval))
    conf_in = [jnp.pad(v.astype(jnp.int32), (0, pc))[:, None]
               for v in (sws, cnt, ewma, wuc, permits, nticket, completed,
                         wake_count)]
    ctx_in = [jnp.pad(jnp.broadcast_to(jnp.asarray(v, dtype), (C,)),
                      (0, pc))[:, None]
              for v, dtype in zip((now2, stepi, policy, threads, dt, wake,
                                   cs_lo, cs_hi, ncs_lo, ncs_hi, k, sws_max,
                                   spin_budget, seed, oracle, workload,
                                   wl_period, wl_duty, wl_burst, wl_spread,
                                   arrival, arr_rate, q_cap, slo, tb,
                                   fault, flt_rate, flt_scale, park_cost),
                                  _CONTEXT_DTYPES)]

    mat = pl.BlockSpec((bc, Tp), lambda i: (i, 0))
    colspec = pl.BlockSpec((bc, 1), lambda i: (i, 0))
    open_run = open_state is not None
    open_in, open_specs, open_shapes = [], [], []
    if open_run:
        open_in = _pad_open(open_state, pc, pt)
        open_specs, open_shapes = _open_specs_shapes(
            open_state, bc, C, pc, Tp, mat, colspec)
    out = pl.pallas_call(
        functools.partial(_transitions_kernel, open_run),
        grid=(nc,),
        in_specs=[mat] * _N_THREAD + [colspec] * (_N_CONF + _N_CTX)
        + open_specs,
        out_specs=[mat] * _N_THREAD + [colspec] * _N_CONF + open_specs,
        out_shape=[jax.ShapeDtypeStruct((C + pc, Tp), s[1])
                   for s in _THREAD_STATE_SPEC]
        + [jax.ShapeDtypeStruct((C + pc, 1), jnp.int32)] * _N_CONF
        + open_shapes,
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(*thread_in, *conf_in, *ctx_in, *open_in)
    nclosed = _N_THREAD + _N_CONF
    res = tuple(v[:C, :T] for v in out[:_N_THREAD]) \
        + tuple(v[:C, 0] for v in out[_N_THREAD:nclosed])
    if open_run:
        o = out[nclosed:]
        res += (o[0][:C, :T], o[1][:C], o[2][:C]) \
            + tuple(v[:C, 0] for v in o[3:])
    return res


# --------------------------------------------------------------------------
# Time-blocked fused simulation kernel: GPS advance + transitions iterated
# for n_sub_steps timesteps in ONE dispatch, with the (block_configs, T)
# state block resident in VMEM/registers across the inner fori_loop.  The
# body is repro.kernels.ref.lock_sim_block_ref applied per block — the
# same single-implementation trick as lock_transitions_step — so ref and
# Pallas blocked rollouts are bit-identical by construction (and by test).
# One dispatch per step-block replaces the legacy two-dispatches-per-step
# scan: 2*B pad/slice round trips and kernel launches become 1 per block.
# --------------------------------------------------------------------------

#: dtypes of the 32 per-config context columns of the block kernel
#: (repro.kernels.ref.BLOCK_CONTEXT order): step0, the step limit, the GPS
#: advance inputs (alpha, cores, has_budget), then TRANSITION_CONTEXT
#: minus now2 and stepi (both recomputed in-block from step0 + s).
_BLOCK_CTX_DTYPES = (jnp.int32, jnp.int32, jnp.float32, jnp.float32,
                     jnp.int32) + _CONTEXT_DTYPES[2:]

_N_BLOCK_CTX = len(_BLOCK_CTX_DTYPES)


def _block_kernel(n_sub_steps, open_run, *refs):
    n_in = _N_THREAD + 1 + _N_CONF + _N_BLOCK_CTX \
        + (_N_OPEN if open_run else 0)
    ins, outs = refs[:n_in], refs[n_in:]
    thread = [r[...] for r in ins[:_N_THREAD]]
    spin_cpu = ins[_N_THREAD][...][:, 0]
    conf = [r[...][:, 0] for r in ins[_N_THREAD + 1:_N_THREAD + 1 + _N_CONF]]
    base = _N_THREAD + 1 + _N_CONF
    ctx = [r[...][:, 0] for r in ins[base:base + _N_BLOCK_CTX]]
    step0, limit, alpha, cores, hb = ctx[:5]
    ostate = _read_open(ins[base + _N_BLOCK_CTX:]) if open_run else None
    out = lock_sim_block_ref(*thread, *conf, spin_cpu, step0, alpha, cores,
                             hb > 0, *ctx[5:], n_sub_steps=n_sub_steps,
                             limit=limit, open_state=ostate)
    for r, v in zip(outs, out):
        r[...] = v if v.ndim == 2 else v[:, None]


@functools.partial(jax.jit, static_argnames=("n_sub_steps", "block_configs",
                                             "interpret"))
def lock_sim_block(st, rem, wake_at, slept, spun, ctr, ticket,
                   completed_pt, sws, cnt, ewma, wuc, permits, nticket,
                   completed, wake_count, spin_cpu,
                   step0, alpha, cores, has_budget,
                   policy, threads, dt, wake, cs_lo, cs_hi, ncs_lo, ncs_hi,
                   k, sws_max, spin_budget, seed, oracle, workload,
                   wl_period, wl_duty, wl_burst, wl_spread, arrival,
                   arr_rate, q_cap, slo, tb, fault, flt_rate, flt_scale,
                   park_cost, *,
                   n_sub_steps: int, block_configs: int = 256,
                   interpret: bool | None = None, limit=None,
                   open_state=None):
    """Pallas time-blocked rollout kernel; signature mirrors
    :func:`repro.kernels.ref.lock_sim_block_ref` and returns the same 17
    updated state arrays after ``n_sub_steps`` fused timesteps (28 with
    ``open_state``, the 11 OPEN_STATE arrays appended).  ``step0``
    (int32 scalar or (C,) vector) is the global index of the block's first
    step; ``limit`` (same broadcast, optionally traced) masks sub-steps at
    global index >= limit into exact passthroughs (see the ref twin) and
    defaults to unlimited.  ``interpret=None`` auto-detects the backend
    (interpret iff no GPU/TPU is attached)."""
    interpret = resolve_interpret(interpret)
    if limit is None:
        limit = jnp.int32(2**31 - 1)      # no masked sub-steps
    C, T = st.shape
    bc = min(block_configs, C)
    pc = (-C) % bc
    pt = (-T) % LANE
    Tp = T + pt
    nc = (C + pc) // bc

    thread_in = []
    for arr, (_, dtype, padval) in zip(
            (st, rem, wake_at, slept, spun, ctr, ticket, completed_pt),
            _THREAD_STATE_SPEC):
        thread_in.append(jnp.pad(arr.astype(dtype), ((0, pc), (0, pt)),
                                 constant_values=padval))
    cpu_in = jnp.pad(spin_cpu.astype(jnp.float32), (0, pc))[:, None]
    conf_in = [jnp.pad(v.astype(jnp.int32), (0, pc))[:, None]
               for v in (sws, cnt, ewma, wuc, permits, nticket, completed,
                         wake_count)]
    ctx_in = [jnp.pad(jnp.broadcast_to(jnp.asarray(v, dtype), (C,)),
                      (0, pc))[:, None]
              for v, dtype in zip((step0, limit, alpha, cores, has_budget,
                                   policy, threads, dt, wake, cs_lo, cs_hi,
                                   ncs_lo, ncs_hi, k, sws_max, spin_budget,
                                   seed, oracle, workload, wl_period,
                                   wl_duty, wl_burst, wl_spread, arrival,
                                   arr_rate, q_cap, slo, tb,
                                   fault, flt_rate, flt_scale, park_cost),
                                  _BLOCK_CTX_DTYPES)]

    mat = pl.BlockSpec((bc, Tp), lambda i: (i, 0))
    colspec = pl.BlockSpec((bc, 1), lambda i: (i, 0))
    open_run = open_state is not None
    open_in, open_specs, open_shapes = [], [], []
    if open_run:
        open_in = _pad_open(open_state, pc, pt)
        open_specs, open_shapes = _open_specs_shapes(
            open_state, bc, C, pc, Tp, mat, colspec)
    out = pl.pallas_call(
        functools.partial(_block_kernel, n_sub_steps, open_run),
        grid=(nc,),
        in_specs=[mat] * _N_THREAD
        + [colspec] * (1 + _N_CONF + _N_BLOCK_CTX) + open_specs,
        out_specs=[mat] * _N_THREAD + [colspec] * (_N_CONF + 1)
        + open_specs,
        out_shape=[jax.ShapeDtypeStruct((C + pc, Tp), s[1])
                   for s in _THREAD_STATE_SPEC]
        + [jax.ShapeDtypeStruct((C + pc, 1), jnp.int32)] * _N_CONF
        + [jax.ShapeDtypeStruct((C + pc, 1), jnp.float32)]
        + open_shapes,
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(*thread_in, cpu_in, *conf_in, *ctx_in, *open_in)
    nclosed = _N_THREAD + _N_CONF + 1
    res = tuple(v[:C, :T] for v in out[:_N_THREAD]) \
        + tuple(v[:C, 0] for v in out[_N_THREAD:nclosed])
    if open_run:
        o = out[nclosed:]
        res += (o[0][:C, :T], o[1][:C], o[2][:C]) \
            + tuple(v[:C, 0] for v in o[3:])
    return res

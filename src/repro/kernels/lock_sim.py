"""Pallas kernel for the batched lock simulator's per-step GPS update.

This is the hot inner loop of :mod:`repro.core.xdes`: for thousands of
``(lock, threads, cores, cs, ncs, wake_latency, alpha)`` configurations at
once, compute each configuration's runnable count, the generalized-
processor-sharing rate ``min(1, cores/n_runnable)``, the cache-contention
slowdown of the CS holder (``1/(1 + alpha·n_spinners)``, paper §2), and
advance remaining work / burn spin CPU — one VMEM-resident pass over the
``(configs, threads)`` state block instead of the six separate HBM round
trips an unfused lowering makes.

Rows are configurations (grid-parallel); the thread axis stays whole in
VMEM (T ≤ 128 lanes after padding — a few KB per row).  The pure-jnp
oracle is :func:`repro.kernels.ref.lock_sim_step_ref`; tests pin
kernel == ref, and :mod:`repro.core.xdes` treats the two as swappable
backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.policy import CS, NCS, SPIN, oracle_update

from .pallas_compat import CompilerParams

LANE = 128          # TPU lane width: thread axis is padded to this


def _kernel(state_ref, rem_ref, alpha_ref, cores_ref, dt_ref, budget_ref,
            rem_out_ref, burn_out_ref):
    st = state_ref[...]                                       # (bc, T) int32
    rem = rem_ref[...]                                        # (bc, T) f32
    is_cs = st == CS
    is_ncs = st == NCS
    is_spin = st == SPIN
    n_run = jnp.sum((is_cs | is_ncs | is_spin).astype(jnp.float32),
                    axis=-1, keepdims=True)                   # (bc, 1)
    n_spin = jnp.sum(is_spin.astype(jnp.float32), axis=-1, keepdims=True)
    cores = cores_ref[...]                                    # (bc, 1)
    rate = jnp.minimum(1.0, cores / jnp.maximum(n_run, 1.0))
    holder_rate = rate / (1.0 + alpha_ref[...] * n_spin)
    dt = dt_ref[...]                                          # (bc, 1)
    d_rate = dt * rate
    burn = jnp.where(is_spin, d_rate, 0.0)
    dec = (jnp.where(is_cs, dt * holder_rate, 0.0)
           + jnp.where(is_ncs, d_rate, 0.0)
           + jnp.where(budget_ref[...] > 0, burn, 0.0))
    rem_out_ref[...] = rem - dec
    burn_out_ref[...] = jnp.sum(burn, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_configs", "interpret"))
def lock_sim_step(tstate, rem, alpha, cores, dt, has_budget, *,
                  block_configs: int = 256, interpret: bool = True):
    """Pallas-fused GPS advance; signature mirrors ``lock_sim_step_ref``.

    tstate: (C, T) int32; rem: (C, T) f32; alpha/cores/dt: (C,) f32;
    has_budget: (C,) bool.  Returns ``(rem', spin_burn)``.
    """
    C, T = tstate.shape
    bc = min(block_configs, C)
    pc = (-C) % bc
    pt = (-T) % LANE
    # Pad threads to the lane width with DONE-state slots (no rate effect)
    # and configs to the block size.
    st2 = jnp.pad(tstate, ((0, pc), (0, pt)), constant_values=5)  # DONE
    rem2 = jnp.pad(rem, ((0, pc), (0, pt)))
    col = lambda v, dt_: jnp.pad(v.astype(dt_), (0, pc))[:, None]
    nc = (C + pc) // bc

    rem_new, burn = pl.pallas_call(
        _kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, T + pt), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C + pc, T + pt), jnp.float32),
            jax.ShapeDtypeStruct((C + pc, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(st2, rem2, col(alpha, jnp.float32), col(cores, jnp.float32),
      col(dt, jnp.float32), col(has_budget, jnp.int32))
    return rem_new[:C, :T], burn[:C, 0]


# --------------------------------------------------------------------------
# Fused SWS-oracle observation: one elementwise pass over (C,) config
# vectors evaluating every oracle family row (paper EvalSWS / AIMD /
# fixed-budget / history, repro.core.policy.ORACLE_ROWS) and selecting by
# oracle id, A16-A17 clamp included.  This is the building block for
# moving the scan body's transition stage into the kernel (scalar-prefetch
# grid over configs); the batched simulator evaluates the same rows today
# via repro.core.policy inside its vmapped transition step, and tests pin
# kernel == ref == scalar rows bit-identically.
# --------------------------------------------------------------------------
def _oracle_kernel(oid_ref, spun_ref, slept_ref, sws_ref, cnt_ref,
                   ewma_ref, k_ref, smax_ref,
                   delta_out_ref, cnt_out_ref, ewma_out_ref):
    sws = sws_ref[...]
    delta, cnt1, ewma1 = oracle_update(
        oid_ref[...], spun_ref[...], slept_ref[...], sws,
        cnt_ref[...], ewma_ref[...], k_ref[...])
    delta_out_ref[...] = jnp.clip(delta, 1 - sws, smax_ref[...] - sws)
    cnt_out_ref[...] = cnt1
    ewma_out_ref[...] = ewma1


@functools.partial(jax.jit, static_argnames=("block_configs", "interpret"))
def oracle_step(oracle_id, spun, slept, sws, cnt, ewma, k, sws_max, *,
                block_configs: int = 1024, interpret: bool = True):
    """Pallas-fused oracle observation; signature mirrors
    :func:`repro.kernels.ref.oracle_update_ref`.

    All inputs ``(C,)``: ``oracle_id/sws/cnt/ewma/k/sws_max`` int32,
    ``spun``/``slept`` bool or 0/1 int32.  Returns ``(delta, cnt', ewma')``
    int32 with the A16-A17 clamp applied to ``delta``.
    """
    C = oracle_id.shape[0]
    bc = min(block_configs, C)
    pc = (-C) % bc
    nc = (C + pc) // bc
    col = lambda v: jnp.pad(v.astype(jnp.int32), (0, pc))[:, None]
    spec = pl.BlockSpec((bc, 1), lambda i: (i, 0))

    delta, cnt1, ewma1 = pl.pallas_call(
        _oracle_kernel,
        grid=(nc,),
        in_specs=[spec] * 8,
        out_specs=[spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((C + pc, 1), jnp.int32)] * 3,
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
    )(col(oracle_id), col(spun), col(slept), col(sws), col(cnt),
      col(ewma), col(k), col(sws_max))
    return delta[:C, 0], cnt1[:C, 0], ewma1[:C, 0]

"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Blockwise online-softmax attention — the production path for the attention
hot-spot that the XLA lowering (repro.models.attention.attend_qchunk)
materializes f32 scores for.  The dry-run's §Roofline shows train/prefill
cells are memory-term dominated precisely because of those scores; this
kernel keeps the (block_q x block_k) score tile in VMEM and never writes it
to HBM.

TPU adaptation (DESIGN.md §3): tiles are MXU-aligned (block_q/block_k
multiples of 128 on the lane dim, head_dim padded to 128 lanes by the
caller), accumulation is f32 in VMEM scratch, the kv loop is the innermost
*arbitrary* grid dimension so the Mosaic pipeline overlaps the HBM->VMEM
streaming of K/V blocks with compute — the kernel-level analogue of the
paper's spinning window: enough buffers in flight to mask fetch latency,
no more (VMEM is the wasted resource).

Supports: causal masking, sliding-window (local) attention, GQA (query
groups share one KV head), logit softcap (gemma).

Layout: q (BH, Sq, hd) with BH = batch*num_q_heads; k/v (BKV, Sk, hd) with
BKV = batch*num_kv_heads.  The ops.py wrapper maps model-layout tensors to
this layout and back.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .pallas_compat import CompilerParams

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, seq_k: int, causal: bool,
            window: int, softcap: float, scale: float):
    """One (q-block, k-block) grid step.  Grid: (BH, nq, nk) with nk
    innermost/arbitrary.  Scratch acc/m/l persist across the nk loop."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = k_pos < seq_k                                  # kv padding
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                   # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)                      # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    if causal or window > 0:
        # skip k-blocks that are fully masked for this q-block
        first_q = qi * block_q
        last_q = first_q + block_q - 1
        first_k = ki * block_k
        last_k = first_k + block_k - 1
        live = jnp.asarray(True)
        if causal:
            live &= last_q >= first_k
        if window > 0:            # newest allowed k is q_pos - window + 1
            live &= last_k > first_q - window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BKV, Sk, hd); BH % BKV == 0 (GQA groups).

    Returns (BH, Sq, hd) in q.dtype.  Sq/Sk are padded to block multiples
    internally; kv padding is masked, q padding rows are dropped on return.
    """
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    assert BH % BKV == 0, (BH, BKV)
    group = BH // BKV
    scale = 1.0 / math.sqrt(hd)

    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // block_q, Skp // block_k

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, seq_k=Sk, causal=causal,
        window=window, softcap=softcap, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
    return out[:, :Sq, :]

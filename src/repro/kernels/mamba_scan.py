"""Mamba selective-scan Pallas kernel.

Recurrence per channel block (state s: (bd, N)):

    s_t = exp(dt_t ⊙ a) ⊙ s_{t-1} + (dt_t ⊙ x_t) B_tᵀ
    y_t = s_t C_t

TPU adaptation: like the RWKV kernel, the op is bandwidth-bound; the state
block stays in VMEM scratch for the whole sequence.  Grid is
(B, d_blocks, num_chunks): batch and channel-blocks parallel, chunks
sequential (arbitrary) so input chunk streaming overlaps compute.  The
channel dim is tiled by ``block_d`` (lane-aligned); ``a`` is (d, N) and the
kernel reads only its (block_d, N) tile.

Layout: dt, x: (B, T, d); Bm, Cm: (B, T, N).  Returns y: (B, T, d) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .pallas_compat import CompilerParams


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, s_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[...].astype(jnp.float32)                        # (bd, N)

    def step(t, _):
        dt_t = dt_ref[0, t].astype(jnp.float32)               # (bd,)
        x_t = x_ref[0, t].astype(jnp.float32)                 # (bd,)
        B_t = b_ref[0, t].astype(jnp.float32)                 # (N,)
        C_t = c_ref[0, t].astype(jnp.float32)                 # (N,)
        da = jnp.exp(dt_t[:, None] * a)                       # (bd, N)
        s = s_ref[...] * da + (dt_t * x_t)[:, None] * B_t[None, :]
        s_ref[...] = s
        # y_t = s C_t  — (bd, N) @ (N,) matvec on the MXU
        y = jax.lax.dot_general(
            s, C_t[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0, unroll=False)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(dt, x, Bm, Cm, a, *, chunk: int = 64, block_d: int = 128,
               interpret: bool = True):
    """dt, x: (B, T, d); Bm, Cm: (B, T, N); a: (d, N) negative.
    Returns y: (B, T, d) f32."""
    B, T, d = x.shape
    N = a.shape[-1]
    bd = min(block_d, d)
    pd = (-d) % bd
    if pd:
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pd)))
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pd)))
        a = jnp.pad(a, ((0, pd), (0, 0)))
    dp = d + pd
    pt = (-T) % chunk
    if pt:
        dt, x = (jnp.pad(v, ((0, 0), (0, pt), (0, 0))) for v in (dt, x))
        Bm, Cm = (jnp.pad(v, ((0, 0), (0, pt), (0, 0))) for v in (Bm, Cm))
    Tp = T + pt
    nd, nc = dp // bd, Tp // chunk

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, i, c: (b, c, i)),  # dt
            pl.BlockSpec((1, chunk, bd), lambda b, i, c: (b, c, i)),  # x
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),   # B
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),   # C
            pl.BlockSpec((bd, N), lambda b, i, c: (i, 0)),            # a
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, i, c: (b, c, i)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(dt, x, Bm, Cm, a)
    return y[:, :T, :d]

"""Pallas TPU kernels for the roofline hot-spots + pure-jnp oracles.

flash_attention — blockwise online-softmax attention (causal/SWA/GQA/softcap)
rwkv6_scan      — WKV linear-attention scan, state resident in VMEM
mamba_scan      — selective-scan, state resident in VMEM
rmsnorm         — fused norm

Use via :mod:`repro.kernels.ops` (layout mapping + backend dispatch).
"""

from . import ops, ref
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .rmsnorm import rmsnorm
from .rwkv6_scan import rwkv6_scan

__all__ = ["flash_attention", "rwkv6_scan", "mamba_scan", "rmsnorm",
           "ops", "ref"]

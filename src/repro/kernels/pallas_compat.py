"""Version-robust aliases for the Pallas TPU API.

The pinned JAX exposes TPU compiler parameters as
``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams`` (and deprecated the old name).  Every kernel
imports :data:`CompilerParams` from here so the repo tracks either
spelling without per-module try/except blocks.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]

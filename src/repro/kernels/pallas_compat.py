"""Version-robust aliases for the Pallas TPU API.

The pinned JAX exposes TPU compiler parameters as
``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams`` (and deprecated the old name).  Every kernel
imports :data:`CompilerParams` from here so the repo tracks either
spelling without per-module try/except blocks.

Also home of :func:`default_interpret` — the shared backend auto-detect
for every ``pallas_call`` site: interpret mode only when no accelerator is
attached (CPU hosts, CI), compiled lowering on real GPU/TPU devices.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True iff Pallas should run in interpret mode on this host.

    Kernels take ``interpret: bool | None = None`` and resolve ``None``
    through this helper: interpret on CPU-only hosts (Pallas has no CPU
    lowering), compiled on any attached GPU/TPU.  Pass an explicit bool to
    override (tests pin ``interpret=True`` for determinism on CPU).
    """
    return jax.default_backend() not in ("gpu", "tpu", "cuda", "rocm")


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> :func:`default_interpret`; bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)


__all__ = ["CompilerParams", "default_interpret", "resolve_interpret"]

"""RWKV6 (Finch) WKV scan Pallas kernel.

Recurrence per head (state S: (n, n) matrix, n = head_dim):

    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t            w_t ∈ (0,1) data-dependent

TPU adaptation: the hot property of this op is that it is *bandwidth*-bound
(state never leaves VMEM; every input element is read exactly once).  The
kernel keeps S resident in VMEM scratch across the whole sequence — grid is
(B·H, num_chunks) with the chunk dimension *arbitrary* (sequential) so
Mosaic streams r/k/v/w chunks HBM→VMEM while the current chunk computes.
Inside a chunk we run the exact diagonal recurrence (fori_loop over time,
rank-1 MXU updates) rather than the 1/decay-normalized matmul form, which
overflows f32 for long chunks with small w — numerical robustness is part
of the spec (ref.py is the oracle).

Layout: r,k,v,w: (BH, T, n); u: (BH, n) (broadcast from (H, n) by ops.py).
Returns y: (BH, T, n) and final state (BH, n, n).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .pallas_compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            S_ref, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = s0_ref[0]

    u = u_ref[0].astype(jnp.float32)                         # (n,)

    def step(t, _):
        r_t = r_ref[0, t].astype(jnp.float32)                # (n,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                     # (n, n) rank-1
        S = S_ref[...]
        # y_t = r·S + (r·(u*k)) v   — matvec on MXU + rank-1 bonus
        y_main = jax.lax.dot_general(
            r_t[None, :], S, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]           # (n,)
        bonus = jnp.sum(r_t * u * k_t) * v_t
        y_ref[0, t] = (y_main + bonus).astype(y_ref.dtype)
        S_ref[...] = w_t[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0, unroll=False)

    @pl.when(ci == nc - 1)
    def _fin():
        sT_ref[0] = S_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk: int = 64,
               interpret: bool = True):
    """r,k,v,w: (BH, T, n) — w is the decay in (0,1); u: (BH, n);
    s0: (BH, n, n) or None.  Returns (y (BH, T, n) f32, sT (BH, n, n) f32)."""
    BH, T, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((BH, n, n), jnp.float32)
    pt = (-T) % chunk
    if pt:
        r, k, v = (jnp.pad(a, ((0, 0), (0, pt), (0, 0))) for a in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pt), (0, 0)), constant_values=1.0)
    Tp = T + pt
    nc = Tp // chunk

    y, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # r
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # k
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # v
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # w
            pl.BlockSpec((1, n), lambda b, c: (b, 0)),             # u
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),       # s0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),   # y
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),       # sT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, n), jnp.float32),
            jax.ShapeDtypeStruct((BH, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(r, k, v, w, u, s0)
    return y[:, :T], sT

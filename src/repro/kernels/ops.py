"""jit'd dispatch wrappers: model-layout tensors -> kernel layouts.

``use_pallas()`` decides the execution path at trace time:

* TPU backend      -> compiled Pallas kernels (production)
* CPU + TEST flag  -> interpret-mode Pallas (CI correctness)
* CPU (default)    -> the models' own XLA paths (dry-run / smoke tests)

Set ``REPRO_USE_PALLAS=1`` to force the kernels (interpret mode on CPU).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .rmsnorm import rmsnorm
from .rwkv6_scan import rwkv6_scan


def use_pallas() -> bool:
    if os.environ.get("REPRO_USE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# Attention: model layout q (B, Sq, H, hd), k/v (B, Sk, KV, hd)
# --------------------------------------------------------------------------
def attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    qk = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    if use_pallas():
        o = flash_attention(qk, kk, vk, causal=causal, window=int(window),
                            softcap=softcap, interpret=_interpret())
    else:
        o = ref.flash_attention_ref(qk, kk, vk, causal=causal,
                                    window=int(window), softcap=softcap)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


# --------------------------------------------------------------------------
# RWKV6: model layout r/k/v/w (B, T, D) with H = D // n heads; u (D,)
# --------------------------------------------------------------------------
def wkv(r, k, v, w, u, head_dim: int, s0=None):
    B, T, D = r.shape
    n = head_dim
    H = D // n

    def to_bh(x):
        return x.reshape(B, T, H, n).transpose(0, 2, 1, 3).reshape(
            B * H, T, n)

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u.reshape(H, n), (B, H, n)).reshape(B * H, n)
    s0b = (None if s0 is None
           else s0.reshape(B * H, n, n))
    if use_pallas():
        y, sT = rwkv6_scan(rb, kb, vb, wb, ub, s0b, interpret=_interpret())
    else:
        y, sT = ref.rwkv6_scan_ref(rb, kb, vb, wb, ub, s0b)
    y = y.reshape(B, H, T, n).transpose(0, 2, 1, 3).reshape(B, T, D)
    return y, sT.reshape(B, H, n, n)


# --------------------------------------------------------------------------
# Mamba selective scan (model layout already matches the kernel)
# --------------------------------------------------------------------------
def selective_scan(dt, x, Bm, Cm, a):
    if use_pallas():
        return mamba_scan(dt, x, Bm, Cm, a, interpret=_interpret())
    return ref.mamba_scan_ref(dt, x, Bm, Cm, a)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def fused_rmsnorm(x, w, eps: float = 1e-6):
    if use_pallas():
        return rmsnorm(x, w, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, w, eps)

"""Benchmark orchestrator — one entry per paper artifact.

    PYTHONPATH=src python -m benchmarks.run [--full]

| benchmark  | paper artifact         | module                  |
|------------|------------------------|-------------------------|
| fig1       | Fig. 1 timelines       | benchmarks.lockbench    |
| fig3       | Fig. 3 lockbench grid  | benchmarks.lockbench (xdes; --engine des legacy) |
| sweep      | Fig. 3 grid + scenario | benchmarks.sweep (xdes) |
| phold      | Fig. 4 PHOLD/PDES      | benchmarks.phold        |
| sched      | §3 technique on TPU    | benchmarks.sched_bench  |
| oracle     | §5 oracle families     | benchmarks.oracle_ablation (xdes) |
| discipline | discipline x oracle map| benchmarks.discipline_diagram (sharded xdes) |
| workload   | workload x lock map    | benchmarks.workload_diagram (sharded xdes) |
| arrival    | open-loop traffic map  | benchmarks.arrival_diagram (sharded xdes) |
| fault      | fault x lock map       | benchmarks.fault_diagram (sharded xdes) |
| park       | park-cost x lock map   | benchmarks.park_diagram (sharded xdes) |
| perf       | engine perf trajectory | benchmarks.perf_bench   |
| fidelity   | dt-convergence study   | benchmarks.fidelity_study (xdes vs DES; not in --quick/--full, run on demand) |

Artifacts land in reports/* (JSON plus the oracle and discipline
phase-diagram CSV/markdown, and the measured perf trajectory —
``BENCH_xdes.json`` at the repo root is the committed perf BASELINE,
refreshed only by an explicit ``perf_bench --out BENCH_xdes.json``); a
summary CSV is printed at the end.  ``--quick`` runs the batched xdes sweep, the oracle-family grid,
the discipline/workload/arrival/fault diagrams and the perf
microbenchmark at smoke scale (~2-3 min) — the fast signal that the
simulation stack works end to end and hasn't slowed down.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sample counts (slower)")
    ap.add_argument("--quick", action="store_true",
                    help="batched-sweep smoke only (<60 s)")
    args = ap.parse_args(argv)
    os.makedirs("reports", exist_ok=True)
    t0 = time.time()
    summary: list[tuple[str, object]] = []

    if args.quick:
        print("=" * 72)
        print("[quick] batched xdes sweep smoke (fig3 grid + scenarios)")
        print("=" * 72)
        from benchmarks import sweep
        sw = sweep.main(["--quick"])
        for claim, ok in sw["fig3"]["claims"].items():
            summary.append((f"sweep.fig3.{claim}", ok))
        summary.append(("sweep.scenario.mutable.mean_ratio",
                        round(sw["scenario"]["mean_ratio_to_best"]
                              ["mutable"], 3)))
        print("\n" + "=" * 72)
        print("[quick] oracle-family grid smoke (phase-diagram report)")
        print("=" * 72)
        from benchmarks import oracle_ablation
        oa = oracle_ablation.main(["--quick"])
        for fam, row in oa["families"].items():
            summary.append((f"oracle.{fam}.best_tuned_ratio",
                            round(row["best_tuned_mean_ratio"], 3)))
        print("\n" + "=" * 72)
        print("[quick] discipline x oracle diagram smoke (sharded xdes)")
        print("=" * 72)
        from benchmarks import discipline_diagram
        dd = discipline_diagram.main(["--quick"])
        for disc, row in dd["disciplines"].items():
            summary.append((f"discipline.{disc}.wins", row["wins"]))
        print("\n" + "=" * 72)
        print("[quick] workload x discipline diagram smoke (sharded xdes)")
        print("=" * 72)
        from benchmarks import workload_diagram
        wd = workload_diagram.main(["--quick"])
        for w, rows in wd["workloads"].items():
            top = max(rows, key=lambda d: rows[d]["wins"])
            summary.append((f"workload.{w}.top", top))
        print("\n" + "=" * 72)
        print("[quick] arrival x discipline diagram smoke (open-loop xdes)")
        print("=" * 72)
        from benchmarks import arrival_diagram
        ad = arrival_diagram.main(["--quick"])
        for cell in ad["phase"]:
            summary.append(
                (f"arrival.{cell['arrival']}.rho{cell['rho']}.winner",
                 cell["winner"]))
        print("\n" + "=" * 72)
        print("[quick] fault x discipline diagram smoke (sharded xdes)")
        print("=" * 72)
        from benchmarks import fault_diagram
        fd = fault_diagram.main(["--quick"])
        for fl, rows in fd["faults"].items():
            top = max(rows, key=lambda d: rows[d]["wins"])
            summary.append((f"fault.{fl}.top", top))
        print("\n" + "=" * 72)
        print("[quick] park-cost x discipline diagram smoke (sharded xdes)")
        print("=" * 72)
        from benchmarks import park_diagram
        # 4 scenarios keep the park_cost=100 horizons (the slowest cells
        # in the whole quick path) inside the smoke budget
        pd = park_diagram.main(["--quick", "--scenarios", "4"])
        for p, rows in pd["park_costs"].items():
            top = max(rows, key=lambda d: rows[d]["wins"])
            summary.append((f"park.{p}.top", top))
        print("\n" + "=" * 72)
        print("[quick] xdes perf microbenchmark")
        print("=" * 72)
        from benchmarks import perf_bench
        # reports/ output: the repo-root BENCH_xdes.json is the committed
        # baseline the CI gate compares against — refresh it deliberately
        # via `perf_bench --full-size --out BENCH_xdes.json`.
        pb = perf_bench.main(["--quick",
                              "--out", "reports/bench_xdes_quick.json"])
        for name, x in pb["speedups"].items():
            summary.append((f"perf.{name}", x))
        print("\n" + "=" * 72)
        print(f"quick smoke done in {time.time()-t0:.0f}s — summary CSV")
        print("=" * 72)
        print("name,value")
        for k, v in summary:
            print(f"{k},{v}")
        return

    print("=" * 72)
    print("[1/12] lockbench fig1 (paper Fig. 1 timelines)")
    print("=" * 72)
    from benchmarks import lockbench
    f1 = lockbench.fig1()
    summary.append(("fig1.spin.makespan_slots",
                    f1["ttas"]["makespan_slots"]))
    summary.append(("fig1.sleep.makespan_slots",
                    f1["sleep"]["makespan_slots"]))
    summary.append(("fig1.mutable.makespan_slots",
                    f1["mutable"]["makespan_slots"]))

    print("\n" + "=" * 72)
    print("[2/12] lockbench fig3 (paper Fig. 3 grid, batched xdes engine)")
    print("=" * 72)
    f3 = lockbench.fig3(target_cs=400 if args.full else 200)
    for regime, data in f3.items():
        for lock in ("mutable", "pt-exp"):
            summary.append((f"fig3.{regime}.{lock}.ratio",
                            round(data["summary"][lock]["ratio_to_opt"], 3)))
    with open("reports/lockbench.json", "w") as f:
        json.dump({"fig1": f1, "fig3": f3}, f, indent=1)

    print("\n" + "=" * 72)
    print("[3/12] batched xdes sweep (fig3 grid + 1000-config scenarios)")
    print("=" * 72)
    from benchmarks import sweep
    sw = sweep.main(["--target-cs", "250" if args.full else "150"])
    for claim, ok in sw["fig3"]["claims"].items():
        summary.append((f"sweep.fig3.{claim}", ok))
    for lock, r in sw["scenario"]["mean_ratio_to_best"].items():
        summary.append((f"sweep.scenario.{lock}.mean_ratio", round(r, 3)))

    print("\n" + "=" * 72)
    print("[4/12] PHOLD on share-everything PDES (paper Fig. 4)")
    print("=" * 72)
    from benchmarks import phold
    ph = phold.run_phold(n_events=3000 if args.full else 1500)
    with open("reports/phold.json", "w") as f:
        json.dump(ph, f, indent=1)
    for g, rows in ph.items():
        for tc, locks in rows.items():
            summary.append((f"phold.{g}.t{tc}.mutable.speedup",
                            locks["mutable"]["speedup"]))

    print("\n" + "=" * 72)
    print("[5/12] serving-window scheduler (the technique on TPU batches)")
    print("=" * 72)
    from benchmarks import sched_bench
    sb = sched_bench.main(["--requests", "400" if args.full else "250"])
    for pol, agg in sb.items():
        summary.append((f"sched.{pol}.late_handoff_rate",
                        round(agg["late_handoff_rate"], 3)))
        summary.append((f"sched.{pol}.avg_standby",
                        round(agg["avg_standby"], 2)))

    print("\n" + "=" * 72)
    print("[6/12] oracle-family grid (paper §5 future work, batched xdes)")
    print("=" * 72)
    from benchmarks import oracle_ablation
    oa = oracle_ablation.main(
        ["--scenarios", "200" if args.full else "100",
         "--target-cs", "150" if args.full else "100"])
    for fam, row in oa["families"].items():
        summary.append((f"oracle.{fam}.wins", row["wins"]))
        summary.append((f"oracle.{fam}.best_tuned_ratio",
                        round(row["best_tuned_mean_ratio"], 3)))

    print("\n" + "=" * 72)
    print("[7/12] discipline x oracle diagram (sharded batched xdes)")
    print("=" * 72)
    from benchmarks import discipline_diagram
    dd = discipline_diagram.main(
        [] if args.full else ["--scenarios", "100", "--target-cs", "100"])
    for disc, row in dd["disciplines"].items():
        summary.append((f"discipline.{disc}.wins", row["wins"]))
        summary.append((f"discipline.{disc}.best_variant_ratio",
                        round(row["best_variant_mean_ratio"], 3)))

    print("\n" + "=" * 72)
    print("[8/12] workload x discipline diagram (sharded batched xdes)")
    print("=" * 72)
    from benchmarks import workload_diagram
    wd = workload_diagram.main(
        [] if args.full else ["--scenarios", "50", "--target-cs", "100"])
    for w, rows in wd["workloads"].items():
        top = max(rows, key=lambda d: rows[d]["wins"])
        summary.append((f"workload.{w}.top", top))
        summary.append((f"workload.{w}.mutable.best_ratio",
                        round(rows["mutable"]["best_variant_mean_ratio"],
                              3)))

    print("\n" + "=" * 72)
    print("[9/12] arrival x discipline diagram (open-loop sharded xdes)")
    print("=" * 72)
    from benchmarks import arrival_diagram
    ad = arrival_diagram.main(
        [] if args.full else ["--scenarios", "25", "--target-cs", "100"])
    for cell in ad["phase"]:
        summary.append(
            (f"arrival.{cell['arrival']}.rho{cell['rho']}.winner",
             cell["winner"]))
        summary.append(
            (f"arrival.{cell['arrival']}.rho{cell['rho']}.slo_frac",
             round(cell["mean_slo_frac"], 3)))

    print("\n" + "=" * 72)
    print("[10/12] fault x discipline diagram (sharded batched xdes)")
    print("=" * 72)
    from benchmarks import fault_diagram
    fd = fault_diagram.main(
        [] if args.full else ["--scenarios", "50", "--target-cs", "100"])
    for fl, rows in fd["faults"].items():
        top = max(rows, key=lambda d: rows[d]["wins"])
        summary.append((f"fault.{fl}.top", top))
        ret = rows["sleep"]["mean_retained_vs_none"]
        summary.append((f"fault.{fl}.sleep.retained",
                        None if ret is None else round(ret, 3)))

    print("\n" + "=" * 72)
    print("[11/12] park-cost x discipline diagram (sharded batched xdes)")
    print("=" * 72)
    from benchmarks import park_diagram
    pkd = park_diagram.main(
        [] if args.full else ["--scenarios", "25", "--target-cs", "100"])
    for p, rows in pkd["park_costs"].items():
        top = max(rows, key=lambda d: rows[d]["wins"])
        summary.append((f"park.{p}.top", top))
        ret = rows["sleep"]["mean_retained_vs_unit"]
        summary.append((f"park.{p}.sleep.retained",
                        None if ret is None else round(ret, 3)))

    print("\n" + "=" * 72)
    print("[12/12] xdes perf microbenchmark (reports/bench_xdes.json)")
    print("=" * 72)
    from benchmarks import perf_bench
    pb = perf_bench.main(["--full-size"] if args.full else [])
    with open("reports/perf_bench.md", "w") as f:
        f.write(perf_bench.summarize(pb) + "\n")
    for name, x in pb["speedups"].items():
        summary.append((f"perf.{name}", x))

    print("\n" + "=" * 72)
    print(f"benchmark suite done in {time.time()-t0:.0f}s — summary CSV")
    print("=" * 72)
    print("name,value")
    for k, v in summary:
        print(f"{k},{v}")


if __name__ == "__main__":
    main()

"""Arrival-rate x discipline diagram — which lock serves traffic best.

Every open-loop arrival row (``repro.core.policy.ARRIVAL_ROWS``: constant-
rate Poisson and the ON/OFF bursty row) at every offered-load fraction of
the scenario's service capacity, crossed with every discipline-diagram
variant, on random scenarios of the adaptive-spin design space — simulated
by a SINGLE jit-compiled :func:`repro.core.xdes.simulate_batch` call with
the open-loop engine on (sharded over all visible devices), reporting
per-request p50/p95/p99, SLO-violation fraction, and shed fraction from
the on-device latency histograms.

Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/arrival_diagram.json`` — full per-(arrival, rho, variant) stats
* ``reports/arrival_phase_diagram.csv`` — throughput AND p95 winner per
  (arrival row x offered load) cell
* ``reports/arrival_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.arrival_diagram [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep
from benchmarks.discipline_diagram import auto_scenarios


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "arrival_phase_diagram"
                        ) -> tuple[str, str]:
    """Render the arrival grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    variant_names = result["meta"]["variant_names"]

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("arrival,rho,n,winner,win_share,lat_winner,lat_win_share,"
                "mean_slo_frac,mean_shed_frac,"
                + ",".join(f"wins_{n}" for n in variant_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['arrival']},{cell['rho']},{cell['n']},"
                    f"{cell['winner']},{cell['win_share']},"
                    f"{cell['lat_winner']},{cell['lat_win_share']},"
                    f"{cell['mean_slo_frac']:.6f},"
                    f"{cell['mean_shed_frac']:.6f},"
                    + ",".join(str(cell["wins_by_variant"].get(n, 0))
                               for n in variant_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    meta = result["meta"]
    with open(md_path, "w") as f:
        f.write("# Arrival phase diagram — which lock serves traffic "
                "best\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_arrivals']} arrival rows x {meta['n_rhos']} "
                f"load fractions x {meta['n_variants']} (discipline, "
                f"oracle) variants = {meta['n_configs']} configurations, "
                f"one {'sharded ' if meta['sharded'] else ''}open-loop "
                f"batched xdes call ({meta['backend']} backend, "
                f"{meta['n_devices']} device(s), {meta['n_steps']} steps, "
                f"{meta['wall_s']}s wall).\n\nArrival rows and the "
                "latency-histogram semantics: docs/open_loop.md; "
                "discipline rows: docs/disciplines.md.\n\n")
        f.write("## Phase diagram\n\nCells: arrival row x offered load "
                "(fraction rho of the scenario's closed-form service "
                "capacity).  Winners by throughput and by mean p95 "
                "sojourn; SLO/shed fractions are cell means.\n\n")
        f.write("| arrival | rho | n | thr winner | share | p95 winner "
                "| share | SLO-viol | shed |\n"
                "|---|---|---|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['arrival']} | {cell['rho']} | {cell['n']} "
                    f"| {cell['winner']} | {cell['win_share']:.2f} "
                    f"| {cell['lat_winner']} "
                    f"| {cell['lat_win_share']:.2f} "
                    f"| {cell['mean_slo_frac']:.3f} "
                    f"| {cell['mean_shed_frac']:.3f} |\n")
        f.write("\n## Variant detail\n\n| arrival | rho | variant | thr "
                "wins | p95 wins | mean p50 (µs) | mean p95 (µs) "
                "| mean p99 (µs) | SLO-viol | shed |\n"
                "|---|---|---|---|---|---|---|---|---|---|\n")
        for v in result["variants"]:
            f.write(f"| {v['arrival']} | {v['rho']} | {v['name']} "
                    f"| {v['wins']} | {v['lat_wins']} "
                    f"| {v['mean_p50_us']:.1f} | {v['mean_p95_us']:.1f} "
                    f"| {v['mean_p99_us']:.1f} | {v['mean_slo_frac']:.3f} "
                    f"| {v['mean_shed_frac']:.3f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<60 s on CPU)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: auto-sized to the device count "
                         "(50/device full, 6/device with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-shard", action="store_true",
                    help="disable the shard_map path even on multi-device "
                         "hosts")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/arrival_diagram.json")
    args = ap.parse_args(argv)

    from repro.configs.catalog import (LOCK_ARRIVAL_RHOS, LOCK_ARRIVALS,
                                       lock_arrival_variants)

    n_variants = len(lock_arrival_variants())
    base = 6 if args.quick else 50
    n_scenarios = args.scenarios or auto_scenarios(base, n_variants)
    result = sweep.arrival_grid(
        n_scenarios=n_scenarios,
        target_cs=args.target_cs or (40 if args.quick else 150),
        backend=args.backend, seed=args.seed,
        arrivals=LOCK_ARRIVALS, rhos=LOCK_ARRIVAL_RHOS,
        shard=False if args.no_shard else None,
        stream={"auto": None, "on": True, "off": False}[args.stream],
        mem_mb=args.mem_mb)

    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

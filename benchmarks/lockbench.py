"""lockbench — the paper's synthetic benchmark (Fig. 1 timelines + Fig. 3
grid).  The Fig. 3 grid runs on the batched xdes engine by default (one
device call via ``benchmarks.sweep.fig3_batched``); the original per-cell
event-driven loop survives behind ``--engine des`` as the legacy exactness
reference.  Fig. 1 stays event-driven (a 3-thread deterministic timeline).
Real-thread mode is optional.

Fig. 3 regimes (paper §4): CS and NCS lengths uniform in [0, 3.7)µs (short)
or [0, 366)µs (long); 2x2 grid.  Metrics per (lock, thread count):

    throughput      — critical sections per second (higher better)
    sync CPU        — CPU-seconds burnt in spin per CS (lower better)
    ratio           — avg throughput / avg optimum  (paper right column)
    PT-EXP          — mean of PT-SPINLOCK (ttas) and PT-MUTEX (sleep):
                      the expected value of a blind static choice

Paper claims validated here (and asserted in tests/test_paper_claims.py):
  C1 (Fig 1): sleep locks need ~5 slots for 3 CSes (-40% throughput);
      the mutable lock matches spin-lock latency with sleep-level waste.
  C2 (Fig 3a/c): with short CSes MUTLOCK is within ~10% of spin locks and
      beats PT-EXP on average.
  C3 (Fig 3d/e): with long CSes MUTLOCK cuts sync CPU by ~an order of
      magnitude vs spin locks at high thread counts, with bounded
      (<~10-15%) loss from the optimum.
  C4 (Fig 3g-i): at low contention all locks converge.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core.des import simulate

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)
WAKE = 8e-6                  # OS wake-up latency (order of a futex wake)
CORES = 20                   # the paper's test machine
LOCKS = ["ttas", "mcs", "sleep", "adaptive", "mutable"]
REGIMES = {
    "cs_short_ncs_short": (SHORT, SHORT),   # Fig 3(a-c)
    "cs_long_ncs_short": (LONG, SHORT),     # Fig 3(d-f)
    "cs_short_ncs_long": (SHORT, LONG),     # Fig 3(g-i)
    "cs_long_ncs_long": (LONG, LONG),       # Fig 3(j-l)
}
THREADS = [2, 4, 8, 12, 16, 20, 26, 32]     # >20 = time-sharing regime


# --------------------------------------------------------------------------
# Fig. 1: three threads, CS duration == wake-up latency
# --------------------------------------------------------------------------
def fig1(verbose: bool = True) -> dict:
    """Deterministic timeline experiment (paper Fig. 1): 3 threads, each
    executes ONE critical section; CS duration == wake-up latency == 1 slot;
    NCS ~ 0.  Measures the makespan in slots for the 3 CSes.

    Expected: spin = 3 slots (b2b CSes, 3 slots of spin waste);
    sleep = 5 slots (two exposed wake-ups, 2 slots waste);
    mutable = 3 slots (wake-up masked by the spinner's CS), 2 slots waste.
    The mutable row uses the steady-state window (sws=2) the oracle reaches
    after its first late wake-up — fig1_convergence shows the transient.
    """
    res = {}
    unit = 10e-6
    for lock, kw in (("ttas", {}), ("sleep", {}),
                     ("mutable", {"initial_sws": 2})):
        r = simulate(lock, threads=3, cores=3, cs=(unit, unit),
                     ncs=(1e-9, 1e-9), wake_latency=unit,
                     target_cs=3, seed=1, max_cs_per_thread=1,
                     lock_kwargs=kw)
        res[lock] = {
            "makespan_slots": round(r.t_end / unit, 2),
            "spin_waste_slots": round(r.spin_cpu / unit, 2),
            "wakes": r.wake_count,
        }
        if verbose:
            print(f"fig1 {lock:>8}: {res[lock]}")

    # oracle dynamics: from sws=1, the doubling rule must fire on the first
    # exposed wake-up (growth) and the K-rule must decay it back when late
    # wake-ups stop (the steady state here is carried by banked semaphore
    # permits pre-waking the next thread — wake-up latency stays masked).
    sim_r = simulate("mutable", threads=3, cores=3, cs=(unit, unit),
                     ncs=(1e-9, 1e-9), wake_latency=unit, target_cs=400,
                     seed=1, lock_kwargs={"initial_sws": 1})
    trace = [s for _, s in sim_r.sws_trace]
    res["convergence"] = {"max_sws": max(trace), "final_sws": trace[-1],
                          "grew": max(trace) > 1}
    if verbose:
        print(f"fig1 oracle dynamics: {res['convergence']}")
    return res


# --------------------------------------------------------------------------
# Fig. 3 grid
# --------------------------------------------------------------------------
def fig3(target_cs: int = 400, seeds=(0, 1), verbose: bool = True,
         engine: str = "xdes") -> dict:
    """The Fig. 3 grid.  ``engine="xdes"`` (default) runs the whole grid
    as ONE batched device call through ``benchmarks.sweep.fig3_batched``;
    ``engine="des"`` is the legacy per-cell event-driven loop (exact event
    times, minutes of Python) kept as the exactness reference."""
    if engine == "xdes":
        from benchmarks.sweep import fig3_batched

        f3 = fig3_batched(target_cs=target_cs, seeds=seeds, verbose=verbose)
        return {k: v for k, v in f3.items() if k in REGIMES}
    if engine != "des":
        raise ValueError(f"unknown engine {engine!r} (xdes|des)")
    out: dict = {}
    for regime, (cs, ncs) in REGIMES.items():
        rows = {}
        for lock in LOCKS:
            per_tc = []
            for tc in THREADS:
                thr = cpu = 0.0
                for seed in seeds:
                    r = simulate(lock, threads=tc, cores=CORES, cs=cs,
                                 ncs=ncs, wake_latency=WAKE,
                                 target_cs=target_cs, seed=seed)
                    thr += r.throughput / len(seeds)
                    cpu += r.sync_cpu_per_cs / len(seeds)
                per_tc.append({"threads": tc, "throughput": thr,
                               "sync_cpu_per_cs": cpu})
            rows[lock] = per_tc
        # optimum per thread count + averages (paper right column)
        n = len(THREADS)
        opt = [max(rows[l][i]["throughput"] for l in LOCKS)
               for i in range(n)]
        avg_opt = sum(opt) / n
        summary = {}
        for lock in LOCKS:
            avg = sum(r["throughput"] for r in rows[lock]) / n
            summary[lock] = {"avg_throughput": avg,
                             "ratio_to_opt": avg / avg_opt}
        pt_exp = 0.5 * (summary["ttas"]["avg_throughput"]
                        + summary["sleep"]["avg_throughput"])
        summary["pt-exp"] = {"avg_throughput": pt_exp,
                             "ratio_to_opt": pt_exp / avg_opt}
        out[regime] = {"rows": rows, "summary": summary}
        if verbose:
            print(f"\n=== {regime} ===")
            print(f"{'lock':>10} {'avg thr (cs/s)':>16} {'ratio':>7} "
                  f"{'cpu/cs @20t (µs)':>18}")
            for lock in LOCKS + ["pt-exp"]:
                s = out[regime]["summary"][lock]
                cpu20 = ("" if lock == "pt-exp" else
                         f"{rows[lock][5]['sync_cpu_per_cs']*1e6:18.2f}")
                print(f"{lock:>10} {s['avg_throughput']:16.0f} "
                      f"{s['ratio_to_opt']:7.3f} {cpu20}")
    return out


# --------------------------------------------------------------------------
# Real-thread mode (GIL caveats documented in DESIGN.md §2)
# --------------------------------------------------------------------------
def real_threads(n_threads: int = 4, iters: int = 300,
                 verbose: bool = True) -> dict:
    import threading

    from repro.core import make_lock

    res = {}
    for kind in ("ttas", "sleep", "adaptive", "mutable"):
        lock = make_lock(kind, **({"max_sws": 4} if kind == "mutable" else {}))
        counter = [0]
        t0 = time.monotonic()

        def worker():
            for _ in range(iters):
                with lock:
                    counter[0] += 1
                    time.sleep(2e-5)       # CS: I/O-ish work, releases GIL
                time.sleep(1e-5)           # NCS

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        wall = time.monotonic() - t0
        assert counter[0] == n_threads * iters, "lost updates!"
        res[kind] = {"wall_s": round(wall, 3),
                     "cs_per_s": round(counter[0] / wall, 1)}
        if kind == "mutable":
            res[kind]["final_sws"] = lock.sws
            res[kind]["late_wakeups"] = (lock.stats.late_wakeups
                                         if lock.stats else None)
        if verbose:
            print(f"threads {kind:>9}: {res[kind]}")
    return res


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="lockbench: Fig. 1 timelines + Fig. 3 grid.  fig3 runs "
                    "on the batched xdes engine by default; --engine des "
                    "selects the LEGACY per-cell event-driven Python loop "
                    "(exact event times, much slower — kept as the "
                    "exactness reference).  fig1 always uses the DES: it "
                    "is a 3-thread deterministic timeline, not a sweep.")
    ap.add_argument("--fig1", action="store_true")
    ap.add_argument("--fig3", action="store_true")
    ap.add_argument("--threads", action="store_true")
    ap.add_argument("--engine", choices=("xdes", "des"), default="xdes",
                    help="fig3 engine: batched xdes (default) or the "
                         "legacy per-cell DES loop")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="CS samples per cell (default: 400 xdes / "
                         "2000 des)")
    ap.add_argument("--out", default="reports/lockbench.json")
    args = ap.parse_args(argv)
    run_all = not (args.fig1 or args.fig3 or args.threads)
    target_cs = args.target_cs or (400 if args.engine == "xdes" else 2000)

    results = {}
    if args.fig1 or run_all:
        results["fig1"] = fig1()
    if args.fig3 or run_all:
        results["fig3"] = fig3(target_cs=target_cs, engine=args.engine)
    if args.threads or run_all:
        results["real_threads"] = real_threads()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}")
    return results


if __name__ == "__main__":
    main()

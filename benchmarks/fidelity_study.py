"""dt-convergence study: xdes quantization error vs the event-driven DES.

The batched engine (:mod:`repro.core.xdes`) quantizes time to a fixed
``dt`` and resolves simultaneous events in thread-id order; the
event-driven DES (:mod:`repro.core.des`) is exact.  This study pins the
quantization-error band: it sweeps ``dt`` across two decades around the
planner's default (``plan_schedule`` picks ``min(cs_mean, wake)/6``) on
three workload rows and reports the relative throughput and spin-CPU
error of xdes against seed-averaged DES ground truth — every xdes cell
from ONE batched call (per-config ``dt`` column, shared horizon, early
exit).

The headline numbers live in the "Fidelity" section of
docs/performance.md; regenerate them with

    PYTHONPATH=src python -m benchmarks.fidelity_study

Artifacts: ``reports/fidelity_dt.json`` (full grid) and
``reports/fidelity_dt.md`` (the table the docs quote).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import xdes
from repro.core.des import simulate
from repro.core.policy import SimConfig

SHORT = (0.0, 3.7e-6)
WAKE = 8e-6

#: The workload rows of the study (3 of the 4 registry rows; hetero is
#: covered by the parity tests — its per-thread scales make seed-averaged
#: DES ground truth needlessly expensive for a dt sweep).
ROWS = ("constant", "bursty", "jitter")
#: (lock, threads, cores) cells: a windowed and a pure-spin discipline.
CELLS = (("mutable", 8, 4), ("ttas", 12, 4))
#: dt grid (s): two decades around the planner default (~0.3 µs here).
DTS = (1e-7, 3e-7, 1e-6, 3e-6, 1e-5)


def _cfg(row, lock, threads, cores, seed):
    return SimConfig(lock, threads=threads, cores=cores, cs=SHORT,
                     ncs=SHORT, wake_latency=WAKE, seed=seed, workload=row,
                     wl_period=8e-5)


def run_study(seeds=(0, 1, 2), des_target: int = 2500,
              xdes_target: int = 1200, n_steps: int = 150_000,
              verbose: bool = True) -> dict:
    """Returns the full (workload x cell x dt) error grid.

    DES ground truth is seed-averaged throughput / spin-CPU-per-CS; the
    xdes side runs every (workload, cell, seed, dt) combination in one
    ``simulate_batch`` call with a per-config ``dt`` column.
    """
    t0 = time.time()
    des_ref = {}
    for row in ROWS:
        for lock, tc, cores in CELLS:
            rs = [simulate(lock, threads=tc, cores=cores, cs=SHORT,
                           ncs=SHORT, wake_latency=WAKE,
                           target_cs=des_target, seed=s,
                           **_cfg(row, lock, tc, cores, s)
                           .workload_kwargs())
                  for s in seeds]
            des_ref[(row, lock)] = {
                "throughput": float(np.mean([r.throughput for r in rs])),
                "sync_cpu_per_cs":
                    float(np.mean([r.sync_cpu_per_cs for r in rs])),
            }
    des_wall = time.time() - t0

    cfgs, dts = [], []
    for row in ROWS:
        for lock, tc, cores in CELLS:
            for s in seeds:
                for dt in DTS:
                    cfgs.append(_cfg(row, lock, tc, cores, s))
                    dts.append(dt)
    t0 = time.time()
    res = xdes.simulate_batch(cfgs, dt=np.asarray(dts, np.float32),
                              n_steps=n_steps, target_cs=xdes_target,
                              early_exit=True)
    xdes_wall = time.time() - t0

    S, D = len(seeds), len(DTS)
    thr = res.throughput.reshape(len(ROWS), len(CELLS), S, D).mean(axis=2)
    cpu = res.sync_cpu_per_cs.reshape(len(ROWS), len(CELLS), S,
                                      D).mean(axis=2)

    grid = []
    for ri, row in enumerate(ROWS):
        for ci, (lock, tc, cores) in enumerate(CELLS):
            ref = des_ref[(row, lock)]
            for di, dt in enumerate(DTS):
                thr_err = thr[ri, ci, di] / ref["throughput"] - 1.0
                cpu_err = (cpu[ri, ci, di]
                           / max(ref["sync_cpu_per_cs"], 1e-12) - 1.0)
                grid.append({
                    "workload": row, "lock": lock, "threads": tc,
                    "cores": cores, "dt": dt,
                    "throughput_rel_err": round(float(thr_err), 4),
                    "spin_cpu_rel_err": round(float(cpu_err), 4),
                })

    band = {f"{dt:g}": round(float(max(
        abs(g["throughput_rel_err"]) for g in grid if g["dt"] == dt)), 4)
        for dt in DTS}
    out = {
        "meta": {"rows": list(ROWS),
                 "cells": [list(c) for c in CELLS], "dts": list(DTS),
                 "seeds": list(seeds), "des_target_cs": des_target,
                 "xdes_target_cs": xdes_target,
                 "des_wall_s": round(des_wall, 1),
                 "xdes_wall_s": round(xdes_wall, 1),
                 "n_configs": len(cfgs)},
        "des_reference": {f"{r}/{l}": v for (r, l), v in des_ref.items()},
        "grid": grid,
        "throughput_err_band_by_dt": band,
    }
    if verbose:
        print(f"fidelity study: {len(cfgs)} xdes configs in one call "
              f"({xdes_wall:.1f}s) vs {len(des_ref) * len(seeds)} DES runs "
              f"({des_wall:.1f}s)")
        print(f"{'dt (s)':>8}  max |throughput err|")
        for dt in DTS:
            print(f"{dt:8g}  {band[f'{dt:g}']:.1%}")
    return out


def write_md(out: dict, path: str) -> None:
    with open(path, "w") as f:
        f.write("# dt-convergence study — xdes vs event-driven DES\n\n")
        m = out["meta"]
        f.write(f"Workload rows {m['rows']}, cells {m['cells']} "
                f"(lock, threads, cores), seeds {m['seeds']}; xdes side is "
                f"{m['n_configs']} configs in ONE batched call "
                f"({m['xdes_wall_s']}s).  Reading guide: "
                "docs/performance.md#fidelity-the-dt-quantization-error-"
                "band, docs/workloads.md.\n\n")
        f.write("## Max |relative throughput error| by dt\n\n"
                "| dt (s) | band |\n|---|---|\n")
        for dt in m["dts"]:
            f.write(f"| {dt:g} | "
                    f"{out['throughput_err_band_by_dt'][f'{dt:g}']:.1%} "
                    "|\n")
        f.write("\n## Full grid\n\n| workload | lock | dt (s) "
                "| throughput err | spin-CPU err |\n|---|---|---|---|---|\n")
        for g in out["grid"]:
            f.write(f"| {g['workload']} | {g['lock']} | {g['dt']:g} "
                    f"| {g['throughput_rel_err']:+.1%} "
                    f"| {g['spin_cpu_rel_err']:+.1%} |\n")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer seeds / shorter horizons (~4x faster)")
    ap.add_argument("--out", default="reports/fidelity_dt.json")
    args = ap.parse_args(argv)
    out = run_study(seeds=(0,) if args.quick else (0, 1, 2),
                    des_target=800 if args.quick else 2500,
                    xdes_target=400 if args.quick else 1200)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    md_path = os.path.splitext(args.out)[0] + ".md"
    write_md(out, md_path)
    print(f"wrote {args.out}, {md_path}")
    return out


if __name__ == "__main__":
    main()

"""Fault x discipline diagram — which lock survives which failure mode.

Every fault/interference row (``repro.core.policy.FAULT_ROWS``: the
benign baseline, lock-holder preemption, CPU oversubscription, lost
wake-ups with timeout recovery, and timer jitter) crossed with every
(discipline, oracle) variant of the discipline diagram, on every random
scenario of the adaptive-spin design space — simulated by a SINGLE
jit-compiled :func:`repro.core.xdes.simulate_batch` program, sharded
over all visible devices (``shard_map`` over the config axis).

This is the robustness companion to the discipline diagram: the
``none`` row reproduces the benign "which lock wins where" map on the
same scenarios, and the fault rows show where that ranking flips —
lock-holder preemption starves spinners (whose burn the fault does not
modulate, but whose holder it stalls) until sleep-heavy disciplines
overtake them, while wake-path faults tax only the sleepers.  Row
encodings, the scenario-scaled fault window, and how to read the
retention column: docs/robustness.md.

Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/fault_diagram.json`` — full per-(fault, variant) stats
* ``reports/fault_phase_diagram.csv`` — which (discipline, oracle) wins
  per (fault x CS length x subscription) bucket
* ``reports/fault_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.fault_diagram [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep
from benchmarks.discipline_diagram import auto_scenarios


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "fault_phase_diagram"
                        ) -> tuple[str, str]:
    """Render the fault grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    variant_names = result["meta"]["variant_names"]
    faults = result["meta"]["faults"]

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("fault,cs,subscription,n,winner,win_share,"
                + ",".join(f"wins_{n}" for n in variant_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['fault']},{cell['cs']},{cell['sub']},"
                    f"{cell['n']},{cell['winner']},{cell['win_share']},"
                    + ",".join(str(cell["wins_by_variant"].get(n, 0))
                               for n in variant_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    meta = result["meta"]
    with open(md_path, "w") as f:
        f.write("# Fault phase diagram — which lock survives which "
                "failure mode\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_faults']} fault rows x {meta['n_variants']} "
                f"(discipline, oracle) variants = {meta['n_configs']} "
                f"configurations, one "
                f"{'sharded ' if meta['sharded'] else ''}batched xdes call "
                f"({meta['backend']} backend, {meta['n_devices']} "
                f"device(s), {meta['n_steps']} steps, {meta['wall_s']}s "
                f"wall).\n\nFault rows, their encodings and the "
                "scenario-scaled fault window: docs/robustness.md; "
                "discipline rows: docs/disciplines.md.\n\n")
        f.write("## Fault summary (wins and throughput retained vs the "
                "benign row)\n\n")
        f.write("| fault | " + " | ".join(
            f"{d} wins / retained"
            for d in next(iter(result["faults"].values()))) + " |\n")
        f.write("|---|" + "---|" * len(
            next(iter(result["faults"].values()))) + "\n")
        for fl in faults:
            rows = result["faults"][fl]
            cells = []
            for d, r in rows.items():
                ret = ("—" if r["mean_retained_vs_none"] is None
                       else f"{r['mean_retained_vs_none']:.2f}")
                cells.append(f"{r['wins']} / {ret}")
            f.write(f"| {fl} | " + " | ".join(cells) + " |\n")
        f.write("\n## Phase diagram\n\nBuckets: fault row x CS length "
                "(short ≤ 10 µs < mid ≤ 100 µs < long) x subscription "
                "(threads vs cores).  The `none` rows reproduce the "
                "benign discipline diagram on the same scenarios.\n\n")
        f.write("| fault | CS | subscription | n | winning variant "
                "| win share |\n|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['fault']} | {cell['cs']} | {cell['sub']} "
                    f"| {cell['n']} | {cell['winner']} "
                    f"| {cell['win_share']:.2f} |\n")
        f.write("\n## Variant detail\n\n| fault | variant | wins "
                "| mean ratio | p10 ratio | retained vs none "
                "| spin CPU/CS (µs) |\n|---|---|---|---|---|---|---|\n")
        for v in result["variants"]:
            ret = ("—" if v["mean_retained_vs_none"] is None
                   else f"{v['mean_retained_vs_none']:.3f}")
            f.write(f"| {v['fault']} | {v['name']} | {v['wins']} "
                    f"| {v['mean_ratio_to_best']:.3f} "
                    f"| {v['p10_ratio_to_best']:.3f} | {ret} "
                    f"| {v['mean_sync_cpu_per_cs_us']:.2f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<60 s on CPU)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: auto-sized to the device count "
                         "(100/device full, 12/device with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-shard", action="store_true",
                    help="disable the shard_map path even on multi-device "
                         "hosts")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/fault_diagram.json")
    args = ap.parse_args(argv)

    from repro.configs.catalog import (LOCK_FAULTS,
                                       lock_discipline_variants)

    n_cells = len(LOCK_FAULTS) * len(lock_discipline_variants())
    base = 12 if args.quick else 100
    n_scenarios = args.scenarios or auto_scenarios(base, n_cells)
    result = sweep.fault_grid(
        n_scenarios=n_scenarios,
        target_cs=args.target_cs or (40 if args.quick else 150),
        backend=args.backend, seed=args.seed,
        shard=False if args.no_shard else None,
        stream={"auto": None, "on": True, "off": False}[args.stream],
        mem_mb=args.mem_mb)

    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

"""PHOLD on a share-everything PDES (paper Fig. 4), with REAL locks.

The paper runs PHOLD on their share-everything Parallel Discrete Event
Simulator: worker threads repeatedly grab the next event, lock the target
Logical Process (LP), process the event (a busy loop of 25/50/100 µs), and
schedule a follow-up event.  32 of 1024 LPs are hot-spots receiving 50% of
events, so LP locks contend.

Adaptation to this container (1 hardware core, CPython GIL): event
processing is ``time.sleep(granularity)`` instead of a busy loop — sleeping
releases the GIL, so event processing genuinely overlaps across threads and
wall-clock speedup is measurable, emulating a many-core machine.  What the
lock discipline changes is how waiters behave on contended hot-spot LPs:
spin (latency), sleep (wake-up delay on the critical path), or the mutable
lock's tuned window.  ``MutableLock(max_sws=20)`` mirrors the paper's
"max = number of cores" on the emulated 20-core box.

Metrics: speedup vs sequential execution of the same event count, and lock
spin-iterations (the CPU-waste proxy; exact cycle accounting is not
meaningful under the GIL — DESIGN.md §2).
"""

from __future__ import annotations

import argparse
import heapq
import json
import os
import random
import threading
import time

from repro.core import make_lock

N_LPS = 1024
N_HOT = 32
HOT_FRACTION = 0.5


class ShareEverythingPDES:
    """Minimal share-everything PDES: a global future-event list + per-LP
    locks; workers process events optimistically in timestamp order."""

    def __init__(self, lock_kind: str, n_threads: int, n_events: int,
                 granularity_s: float, seed: int = 0):
        self.n_threads = n_threads
        self.n_events = n_events
        self.granularity_s = granularity_s
        self.rng = random.Random(seed)
        kind, kw = lock_kind, {}
        if lock_kind == "mutable":            # paper: max SWS = core count
            kw = {"max_sws": 20}              # the emulated 20-core machine
        elif lock_kind == "mutable-1core":    # max = REAL cores on this box
            kind, kw = "mutable", {"max_sws": 1}
        self.lp_locks = [make_lock(kind, **kw) for _ in range(N_LPS)]
        self.fel_lock = make_lock(kind, **kw)            # future event list
        self.fel: list[tuple[float, int, int]] = []
        self.processed = 0
        self.done = threading.Event()
        for i in range(4 * n_threads):                   # initial population
            heapq.heappush(self.fel, (self.rng.random(), i, self._target()))

    def _target(self) -> int:
        if self.rng.random() < HOT_FRACTION:
            return self.rng.randrange(N_HOT)
        return self.rng.randrange(N_HOT, N_LPS)

    def _worker(self, wid: int) -> None:
        rng = random.Random(1000 + wid)
        while True:
            with self.fel_lock:
                if self.processed >= self.n_events:
                    self.done.set()
                    return
                if not self.fel:
                    continue
                ts, eid, lp = heapq.heappop(self.fel)
                self.processed += 1
                my_count = self.processed
            lock = self.lp_locks[lp]
            with lock:                       # the contended critical section
                time.sleep(self.granularity_s)   # event processing (GIL-free)
            tgt = (rng.randrange(N_HOT) if rng.random() < HOT_FRACTION
                   else rng.randrange(N_HOT, N_LPS))
            nxt = (ts + rng.expovariate(1.0), my_count * 100 + wid, tgt)
            with self.fel_lock:
                heapq.heappush(self.fel, nxt)

    def run(self) -> float:
        t0 = time.monotonic()
        ts = [threading.Thread(target=self._worker, args=(i,))
              for i in range(self.n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        return time.monotonic() - t0

    def spin_iters(self) -> int:
        total = 0
        for lk in self.lp_locks + [self.fel_lock]:
            if hasattr(lk, "spin_iters"):
                total += lk.spin_iters
            elif hasattr(lk, "spn_obj"):
                pass                        # mutable: TTAS iterations not
        return total                        # individually counted


def run_phold(locks=("ttas", "sleep", "adaptive", "mutable",
               "mutable-1core"),
              n_threads=(16, 20), granularities=(25e-6, 50e-6, 100e-6),
              n_events: int = 1500, verbose: bool = True) -> dict:
    out: dict = {}
    for gran in granularities:
        seq_time = n_events * gran          # sequential = sum of all events
        gkey = f"{int(gran*1e6)}us"
        out[gkey] = {}
        for tc in n_threads:
            row = {}
            for kind in locks:
                sim = ShareEverythingPDES(kind, tc, n_events, gran)
                wall = sim.run()
                speedup = seq_time / wall
                row[kind] = {"wall_s": round(wall, 3),
                             "speedup": round(speedup, 2)}
                if verbose:
                    print(f"phold {gkey} t={tc:<3} {kind:>14}: "
                          f"speedup {speedup:6.2f} (wall {wall:.2f}s)",
                          flush=True)
            out[gkey][tc] = row
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="[legacy] PHOLD on a share-everything PDES with REAL "
                    "Python threads (paper Fig. 4).  Kept as the "
                    "wall-clock artifact; it cannot batch (real threads, "
                    "GIL).  For simulation-scale discipline comparisons "
                    "use the batched engine instead: benchmarks.sweep / "
                    "benchmarks.discipline_diagram.")
    ap.add_argument("--events", type=int, default=1500)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="reports/phold.json")
    args = ap.parse_args(argv)
    if args.quick:
        res = run_phold(n_threads=(16,), granularities=(50e-6,),
                        n_events=min(args.events, 600))
    else:
        res = run_phold(n_events=args.events)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {args.out}")
    return res


if __name__ == "__main__":
    main()

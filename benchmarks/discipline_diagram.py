"""Discipline x oracle diagram — the full "which lock wins where" map.

Every waiting-discipline row (``repro.core.policy.DISCIPLINE_ROWS``: the
spin family, the pure sleep lock, the glibc adaptive mutex, the paper's
mutable lock, and the FIFO/MCS ticket-handoff row) crossed with every SWS
oracle family (``ORACLE_ROWS``: paper EvalSWS, AIMD, fixed-budget,
history), on every random scenario of the adaptive-spin design space —
simulated by a SINGLE jit-compiled :func:`repro.core.xdes.simulate_batch`
program, sharded over all visible devices (``shard_map`` over the config
axis; the scenario count auto-sizes to the device count, targeting
10-100k configurations on multi-device hosts).

Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/discipline_diagram.json`` — full per-variant stats
* ``reports/discipline_phase_diagram.csv`` — which (discipline, oracle)
  wins per workload bucket (CS length x subscription x wake latency)
* ``reports/discipline_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.discipline_diagram [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep


def auto_scenarios(base: int, n_variants: int,
                   max_configs: int = 100_000) -> int:
    """Scale the scenario count to the attached devices: ``base`` per
    device, capped so the grid stays under ``max_configs`` rows."""
    import jax

    return min(base * max(1, len(jax.devices())),
               max(base, max_configs // max(1, n_variants)))


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "discipline_phase_diagram"
                        ) -> tuple[str, str]:
    """Render the discipline grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    variant_names = [v["name"] for v in result["variants"]]

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("cs,subscription,wake,n,winner,win_share,"
                + ",".join(f"wins_{n}" for n in variant_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['cs']},{cell['sub']},{cell['wake']},"
                    f"{cell['n']},{cell['winner']},{cell['win_share']},"
                    + ",".join(str(cell["wins_by_variant"].get(n, 0))
                               for n in variant_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    meta = result["meta"]
    with open(md_path, "w") as f:
        f.write("# Discipline phase diagram — which lock wins where\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_variants']} (discipline, oracle) variants = "
                f"{meta['n_configs']} configurations, one "
                f"{'sharded ' if meta['sharded'] else ''}batched xdes call "
                f"({meta['backend']} backend, {meta['n_devices']} "
                f"device(s), {meta['n_steps']} steps, {meta['wall_s']}s "
                f"wall).\n\nDiscipline rows and how to add one: "
                "docs/disciplines.md; oracle families: docs/oracles.md.\n\n")
        f.write("## Discipline summary (best variant per scenario)\n\n")
        f.write("| discipline | wins | best-variant mean ratio-to-best "
                "| mean spin CPU/CS (µs) |\n|---|---|---|---|\n")
        for name, row in result["disciplines"].items():
            f.write(f"| {name} | {row['wins']} "
                    f"| {row['best_variant_mean_ratio']:.3f} "
                    f"| {row['mean_sync_cpu_per_cs_us']:.2f} |\n")
        f.write("\n## Phase diagram\n\nBuckets: CS length (short ≤ 10 µs "
                "< mid ≤ 100 µs < long), subscription (threads vs cores), "
                "wake latency (fast ≤ 10 µs < slow).\n\n")
        f.write("| CS | subscription | wake | n | winning variant "
                "| win share |\n|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['cs']} | {cell['sub']} | {cell['wake']} "
                    f"| {cell['n']} | {cell['winner']} "
                    f"| {cell['win_share']:.2f} |\n")
        f.write("\n## Variant detail\n\n| variant | wins | mean ratio "
                "| p10 ratio | spin CPU/CS (µs) |\n|---|---|---|---|---|\n")
        for v in sorted(result["variants"],
                        key=lambda v: -v["mean_ratio_to_best"]):
            f.write(f"| {v['name']} | {v['wins']} "
                    f"| {v['mean_ratio_to_best']:.3f} "
                    f"| {v['p10_ratio_to_best']:.3f} "
                    f"| {v['mean_sync_cpu_per_cs_us']:.2f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<60 s on CPU)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: auto-sized to the device count "
                         "(200/device full, 24/device with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-shard", action="store_true",
                    help="disable the shard_map path even on multi-device "
                         "hosts")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--refine", action="store_true",
                    help="also run the coarse->dense phase-boundary "
                         "refinement lattice (sweep.refine_grid) and "
                         "attach it under result['refine']")
    ap.add_argument("--out", default="reports/discipline_diagram.json")
    args = ap.parse_args(argv)

    from repro.configs.catalog import lock_discipline_variants

    n_variants = len(lock_discipline_variants())
    base = 24 if args.quick else 200
    n_scenarios = args.scenarios or auto_scenarios(base, n_variants)
    result = sweep.discipline_grid(
        n_scenarios=n_scenarios,
        target_cs=args.target_cs or (40 if args.quick else 150),
        backend=args.backend, seed=args.seed,
        shard=False if args.no_shard else None,
        stream={"auto": None, "on": True, "off": False}[args.stream],
        mem_mb=args.mem_mb)
    if args.refine:
        result["refine"] = sweep.refine_grid(
            nx=8 if args.quick else 16, ny=6 if args.quick else 12,
            factor=2 if args.quick else 3,
            target_cs=args.target_cs or (40 if args.quick else 150),
            backend=args.backend, seed=args.seed,
            shard=False if args.no_shard else None, mem_mb=args.mem_mb)

    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

"""CI smoke for the streaming sweep engine: bounded-memory proof.

Runs a 20k-config discipline sweep through
:func:`repro.core.stream.sweep_stream` under a deliberately SMALL memory
budget (default 16 MiB, forcing many chunks) and asserts, in order:

* the chunk plan respects the budget — ``chunk_size x bytes_per_config``
  fits the resolved budget (or the plan bottomed out at one group);
* the run actually streamed (``n_chunks > 1`` at this scale);
* peak-RSS growth over the run (``resource.getrusage`` high-water mark,
  snapshotted after a small warmup that loads jax and compiles the
  kernels) stays under ``--rss-ceiling-mb`` — the observable guarantee
  that a 20k sweep never materializes its full ``(C, T)`` state on host.

Exit status is the contract: 0 = streamed within budget, 1 = any assert
failed.  CI runs this next to the tier-1 tests; scale or budget can be
overridden for local experiments:

    PYTHONPATH=src python -m benchmarks.stream_smoke \\
        [--configs 20000] [--mem-mb 16] [--rss-ceiling-mb 512]
"""

from __future__ import annotations

import argparse
import resource
import time


def _maxrss_mb() -> float:
    # ru_maxrss is KiB on Linux (bytes on macOS; this smoke runs on CI's
    # Linux runners where the tier-1 suite runs).
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", type=int, default=20_000)
    ap.add_argument("--target-cs", type=int, default=20)
    ap.add_argument("--mem-mb", type=float, default=16.0,
                    help="streaming budget — small on purpose, so the "
                         "20k sweep MUST chunk")
    ap.add_argument("--rss-ceiling-mb", type=float, default=512.0,
                    help="max allowed peak-RSS growth over the streamed "
                         "run (measured from the post-warmup high-water "
                         "mark)")
    args = ap.parse_args(argv)

    from repro.configs.catalog import (lock_discipline_columns,
                                       lock_discipline_variants)
    from repro.core import stream as xstream

    V = len(lock_discipline_variants())
    n_scenarios = max(1, args.configs // V)
    C = n_scenarios * V

    # Warmup: touch the whole path at toy scale so jax import, kernel
    # compiles, and allocator pools land in the RSS baseline, not the
    # measured growth.
    xstream.sweep_stream(lock_discipline_columns(n_scenarios=8),
                         target_cs=5, backend="ref", bucket_steps=True,
                         mem_mb=args.mem_mb)
    rss0 = _maxrss_mb()

    cols = lock_discipline_columns(n_scenarios=n_scenarios)
    t0 = time.perf_counter()
    res = xstream.sweep_stream(cols, target_cs=args.target_cs,
                               backend="ref", bucket_steps=True,
                               mem_mb=args.mem_mb)
    wall = time.perf_counter() - t0
    rss1 = _maxrss_mb()
    grown = rss1 - rss0

    budget_bytes = res.budget_mb * (1 << 20)
    chunk_bytes = res.chunk_size * res.bytes_per_config
    print(f"stream smoke: {C} configs in {res.n_chunks} chunk(s) of "
          f"<= {res.chunk_size} ({wall:.1f}s, {C / wall:.0f} cfg/s); "
          f"chunk footprint {chunk_bytes / 2**20:.1f} MB of "
          f"{res.budget_mb:.0f} MB budget; peak RSS {rss1:.0f} MB "
          f"(+{grown:.0f} MB over warmup baseline, ceiling "
          f"{args.rss_ceiling_mb:.0f} MB)")

    failures = []
    # a plan may exceed a too-small budget only when floored at one
    # group (chunk_size == V on a single device)
    if chunk_bytes > budget_bytes and res.chunk_size > V:
        failures.append(f"chunk plan over budget: {chunk_bytes} B > "
                        f"{budget_bytes:.0f} B")
    if res.n_chunks <= 1:
        failures.append(f"did not stream: {res.n_chunks} chunk at "
                        f"C={C}, budget {args.mem_mb} MB")
    if grown > args.rss_ceiling_mb:
        failures.append(f"peak RSS grew {grown:.0f} MB > ceiling "
                        f"{args.rss_ceiling_mb:.0f} MB")
    if failures:
        for line in failures:
            print(f"FAIL: {line}")
        raise SystemExit(1)
    print("stream smoke: OK")
    return {"n_configs": C, "n_chunks": res.n_chunks,
            "chunk_size": res.chunk_size, "wall_s": wall,
            "rss_grown_mb": grown}


if __name__ == "__main__":
    main()

"""Workload x discipline x oracle diagram — which lock wins under which
workload.

Every workload row (``repro.core.policy.WORKLOAD_ROWS``: the paper's
constant uniform draws, bursty ON/OFF duty cycles, heterogeneous
per-thread CS/NCS scales, Poisson-like jittered arrivals) crossed with
every discipline-diagram variant (``DISCIPLINE_ROWS`` x ``ORACLE_ROWS``,
windowed-row pruning), on every random scenario of the adaptive-spin
design space — simulated by a SINGLE jit-compiled
:func:`repro.core.xdes.simulate_batch` program, sharded over all visible
devices (``shard_map`` over the config axis; the scenario count
auto-sizes to the device count).

This is the experiment behind the paper's robustness pitch: the winner
flips with workload shape, and the mutable lock's value is exactly that
it does not need to know the shape in advance (docs/workloads.md walks
through how to read the artifact).

Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/workload_diagram.json`` — full per-(workload, variant) stats
* ``reports/workload_phase_diagram.csv`` — which (discipline, oracle)
  wins per (workload x CS length x subscription) bucket
* ``reports/workload_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.workload_diagram [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep
from benchmarks.discipline_diagram import auto_scenarios


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "workload_phase_diagram"
                        ) -> tuple[str, str]:
    """Render the workload grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    meta = result["meta"]
    variant_names = meta["variant_names"]

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("workload,cs,subscription,n,winner,win_share,"
                + ",".join(f"wins_{n}" for n in variant_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['workload']},{cell['cs']},{cell['sub']},"
                    f"{cell['n']},{cell['winner']},{cell['win_share']},"
                    + ",".join(str(cell["wins_by_variant"].get(n, 0))
                               for n in variant_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    with open(md_path, "w") as f:
        f.write("# Workload phase diagram — which lock wins under which "
                "workload\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_workloads']} workload rows x "
                f"{meta['n_variants']} (discipline, oracle) variants = "
                f"{meta['n_configs']} configurations, one "
                f"{'sharded ' if meta['sharded'] else ''}batched xdes call "
                f"({meta['backend']} backend, {meta['n_devices']} "
                f"device(s), {meta['n_steps']} steps, {meta['wall_s']}s "
                f"wall).\n\nWorkload rows and how to read this page: "
                "docs/workloads.md; discipline rows: docs/disciplines.md; "
                "oracle families: docs/oracles.md.\n\n")
        f.write("## Discipline wins per workload (best variant per "
                "scenario)\n\n")
        disc_names = list(next(iter(result["workloads"].values())))
        f.write("| workload | " + " | ".join(disc_names)
                + " | top discipline |\n")
        f.write("|---" * (len(disc_names) + 2) + "|\n")
        for w, rows in result["workloads"].items():
            top = max(rows, key=lambda d: rows[d]["wins"])
            f.write(f"| {w} | "
                    + " | ".join(str(rows[d]["wins"]) for d in disc_names)
                    + f" | {top} |\n")
        f.write("\n## Phase diagram\n\nBuckets: workload row x CS length "
                "(short ≤ 10 µs < mid ≤ 100 µs < long) x subscription "
                "(threads vs cores).  The per-scenario best is taken "
                "within the workload, so winners are judged against the "
                "other locks under the same hold-time model.\n\n")
        f.write("| workload | CS | subscription | n | winning variant "
                "| win share |\n|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['workload']} | {cell['cs']} | {cell['sub']} "
                    f"| {cell['n']} | {cell['winner']} "
                    f"| {cell['win_share']:.2f} |\n")
        f.write("\n## Variant detail (per workload)\n\n| workload "
                "| variant | wins | mean ratio | p10 ratio "
                "| spin CPU/CS (µs) |\n|---|---|---|---|---|---|\n")
        for v in sorted(result["variants"],
                        key=lambda v: (v["workload"],
                                       -v["mean_ratio_to_best"])):
            f.write(f"| {v['workload']} | {v['name']} | {v['wins']} "
                    f"| {v['mean_ratio_to_best']:.3f} "
                    f"| {v['p10_ratio_to_best']:.3f} "
                    f"| {v['mean_sync_cpu_per_cs_us']:.2f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<60 s on CPU)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: auto-sized to the device count "
                         "(100/device full, 12/device with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-shard", action="store_true",
                    help="disable the shard_map path even on multi-device "
                         "hosts")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/workload_diagram.json")
    args = ap.parse_args(argv)

    from repro.configs.catalog import (LOCK_WORKLOADS,
                                       lock_workload_variants)

    n_variants = len(lock_workload_variants())
    base = 12 if args.quick else 100
    n_scenarios = args.scenarios or auto_scenarios(base, n_variants)
    result = sweep.workload_grid(
        n_scenarios=n_scenarios,
        target_cs=args.target_cs or (40 if args.quick else 150),
        backend=args.backend, seed=args.seed,
        workloads=LOCK_WORKLOADS,
        shard=False if args.no_shard else None,
        stream={"auto": None, "on": True, "off": False}[args.stream],
        mem_mb=args.mem_mb)

    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

"""Park-cost x discipline diagram — when is parking worth it.

The M:N lightweight-thread environment axis (``SimConfig.park_cost``
scaling the park/unpark round trip across three orders of magnitude:
user-level M:N schedulers where a park is a userspace context switch,
the OS-futex baseline, and oversubscribed/VM-mediated kernels) crossed
with every (discipline, oracle) variant of the discipline diagram, on
every random scenario of the adaptive-spin design space — simulated by
a SINGLE jit-compiled :func:`repro.core.xdes.simulate_batch` program,
sharded over all visible devices (``shard_map`` over the config axis).

This is the environment companion to the discipline diagram: the
``park_cost=1`` slice reproduces the benign "which lock wins where" map
on the same scenarios, and the other slices show how the ranking moves
as parking gets cheaper (sleep-leaning rows and Hapax gain) or more
expensive (spin rows and the fissile spin-for-a-round-trip budget
gain).  Row encodings and the axis semantics: docs/disciplines.md.

Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/park_diagram.json`` — full per-(park_cost, variant) stats
* ``reports/park_phase_diagram.csv`` — which (discipline, oracle) wins
  per (park_cost x CS length x subscription) bucket
* ``reports/park_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.park_diagram [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep
from benchmarks.discipline_diagram import auto_scenarios


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "park_phase_diagram"
                        ) -> tuple[str, str]:
    """Render the park grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    variant_names = result["meta"]["variant_names"]
    park_costs = result["meta"]["park_costs"]

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("park_cost,cs,subscription,n,winner,win_share,"
                + ",".join(f"wins_{n}" for n in variant_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['park_cost']},{cell['cs']},{cell['sub']},"
                    f"{cell['n']},{cell['winner']},{cell['win_share']},"
                    + ",".join(str(cell["wins_by_variant"].get(n, 0))
                               for n in variant_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    meta = result["meta"]
    with open(md_path, "w") as f:
        f.write("# Park-cost phase diagram — when is parking worth "
                "it\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_park_costs']} park costs x "
                f"{meta['n_variants']} (discipline, oracle) variants = "
                f"{meta['n_configs']} configurations, one "
                f"{'sharded ' if meta['sharded'] else ''}batched xdes call "
                f"({meta['backend']} backend, {meta['n_devices']} "
                f"device(s), {meta['n_steps']} steps, {meta['wall_s']}s "
                f"wall).\n\nThe park_cost axis and the discipline rows: "
                "docs/disciplines.md.\n\n")
        f.write("## Park-cost summary (wins and throughput retained vs "
                "park_cost=1)\n\n")
        f.write("| park_cost | " + " | ".join(
            f"{d} wins / retained"
            for d in next(iter(result["park_costs"].values()))) + " |\n")
        f.write("|---|" + "---|" * len(
            next(iter(result["park_costs"].values()))) + "\n")
        for p in park_costs:
            rows = result["park_costs"][str(p)]
            cells = []
            for d, r in rows.items():
                ret = ("—" if r["mean_retained_vs_unit"] is None
                       else f"{r['mean_retained_vs_unit']:.2f}")
                cells.append(f"{r['wins']} / {ret}")
            f.write(f"| {p} | " + " | ".join(cells) + " |\n")
        f.write("\n## Phase diagram\n\nBuckets: park_cost x CS length "
                "(short ≤ 10 µs < mid ≤ 100 µs < long) x subscription "
                "(threads vs cores).  The `park_cost=1` rows reproduce "
                "the benign discipline diagram on the same scenarios.\n\n")
        f.write("| park_cost | CS | subscription | n | winning variant "
                "| win share |\n|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['park_cost']} | {cell['cs']} "
                    f"| {cell['sub']} | {cell['n']} | {cell['winner']} "
                    f"| {cell['win_share']:.2f} |\n")
        f.write("\n## Variant detail\n\n| park_cost | variant | wins "
                "| mean ratio | p10 ratio | retained vs unit "
                "| spin CPU/CS (µs) |\n|---|---|---|---|---|---|---|\n")
        for v in result["variants"]:
            ret = ("—" if v["mean_retained_vs_unit"] is None
                   else f"{v['mean_retained_vs_unit']:.3f}")
            f.write(f"| {v['park_cost']} | {v['name']} | {v['wins']} "
                    f"| {v['mean_ratio_to_best']:.3f} "
                    f"| {v['p10_ratio_to_best']:.3f} | {ret} "
                    f"| {v['mean_sync_cpu_per_cs_us']:.2f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<60 s on CPU)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: auto-sized to the device count "
                         "(50/device full, 8/device with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-shard", action="store_true",
                    help="disable the shard_map path even on multi-device "
                         "hosts")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/park_diagram.json")
    args = ap.parse_args(argv)

    from repro.configs.catalog import (LOCK_PARK_COSTS,
                                       lock_discipline_variants)

    n_cells = len(LOCK_PARK_COSTS) * len(lock_discipline_variants())
    base = 8 if args.quick else 50
    n_scenarios = args.scenarios or auto_scenarios(base, n_cells)
    result = sweep.park_grid(
        n_scenarios=n_scenarios,
        target_cs=args.target_cs or (40 if args.quick else 150),
        backend=args.backend, seed=args.seed,
        shard=False if args.no_shard else None,
        stream={"auto": None, "on": True, "off": False}[args.stream],
        mem_mb=args.mem_mb)

    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

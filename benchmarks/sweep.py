"""Batched lock-simulation sweeps on the xdes engine (one device program).

Two artifacts:

* ``fig3`` — the paper's Fig. 3 grid (4 regimes x 5 locks x 8 thread
  counts x seeds) as ONE ``jax.jit``-compiled call, summarized exactly like
  ``benchmarks.lockbench.fig3`` (avg throughput, ratio-to-optimum, PT-EXP)
  and checked against the paper's qualitative claims C2-C4.
* ``scenario`` — a beyond-paper sweep (default 200 scenarios x 5 locks =
  1000 configurations, one call per step-count bucket — see
  ``repro.core.xdes.plan_buckets``): random machines/workloads sampling
  the adaptive-spin design space, answering "which discipline wins where"
  and "how far from the per-scenario optimum is a blind static choice vs
  the mutable lock" — the experiment the sequential DES made impractical.
* ``oracle_grid`` — the SWS-oracle ablation (4 families x K x sws_max x
  scenarios, one call), consumed by ``benchmarks/oracle_ablation.py``
  which renders it into the phase-diagram report (see docs/oracles.md).
* ``discipline_grid`` — the full discipline x oracle diagram (every
  DISCIPLINE_ROW x every ORACLE_ROW x scenarios, one call), consumed by
  ``benchmarks/discipline_diagram.py`` (see docs/disciplines.md).
* ``workload_grid`` — the workload x discipline x oracle diagram (every
  WORKLOAD_ROW x every discipline variant x scenarios, one call),
  consumed by ``benchmarks/workload_diagram.py`` (see docs/workloads.md).

Every batched call auto-shards its config axis over all visible devices
(``repro.core.xdes.simulate_batch(shard=...)``, ``shard_map`` through the
version-robust shim in ``repro/sharding/compat.py``) — on a multi-device
host the same entry points sweep 10-100k configurations.

    PYTHONPATH=src python -m benchmarks.sweep [--quick] [--backend pallas]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs.catalog import (LOCK_DISCIPLINE_SET, LOCK_DISCIPLINES,
                                   LOCK_ORACLE_KS, LOCK_ORACLE_SWS_MAX,
                                   LOCK_ORACLES, LOCK_REGIMES, LOCK_THREADS,
                                   LOCK_WORKLOADS, lock_discipline_sweep,
                                   lock_discipline_variants, lock_fig3_grid,
                                   lock_oracle_sweep, lock_oracle_variants,
                                   lock_scenario_sweep, lock_workload_sweep)
from repro.core import xdes


# --------------------------------------------------------------------------
# Fig. 3 grid, batched
# --------------------------------------------------------------------------
def fig3_batched(target_cs: int = 250, seeds=(0, 1), backend: str = "ref",
                 verbose: bool = True) -> dict:
    configs = lock_fig3_grid(seeds=seeds)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs, backend=backend)
    wall = time.time() - t0

    thr = res.throughput.reshape(len(LOCK_REGIMES), len(LOCK_DISCIPLINES),
                                 len(LOCK_THREADS), len(seeds)).mean(-1)
    cpu = res.sync_cpu_per_cs.reshape(thr.shape[0], thr.shape[1],
                                      thr.shape[2], len(seeds)).mean(-1)

    out: dict = {"meta": {"backend": backend, "n_configs": len(configs),
                          "n_steps": res.n_steps, "wall_s": round(wall, 2)}}
    for ri, regime in enumerate(LOCK_REGIMES):
        rows = {
            lock: [{"threads": int(tc), "throughput": float(thr[ri, li, ti]),
                    "sync_cpu_per_cs": float(cpu[ri, li, ti])}
                   for ti, tc in enumerate(LOCK_THREADS)]
            for li, lock in enumerate(LOCK_DISCIPLINES)
        }
        opt = thr[ri].max(axis=0)                  # optimum per thread count
        avg_opt = float(opt.mean())
        summary = {}
        for li, lock in enumerate(LOCK_DISCIPLINES):
            avg = float(thr[ri, li].mean())
            summary[lock] = {"avg_throughput": avg,
                             "ratio_to_opt": avg / avg_opt}
        pt_exp = 0.5 * (summary["ttas"]["avg_throughput"]
                        + summary["sleep"]["avg_throughput"])
        summary["pt-exp"] = {"avg_throughput": pt_exp,
                             "ratio_to_opt": pt_exp / avg_opt}
        out[regime] = {"rows": rows, "summary": summary}
        if verbose:
            print(f"\n=== {regime} (xdes, {backend}) ===")
            print(f"{'lock':>10} {'avg thr (cs/s)':>16} {'ratio':>7}")
            for lock in list(LOCK_DISCIPLINES) + ["pt-exp"]:
                s = summary[lock]
                print(f"{lock:>10} {s['avg_throughput']:16.0f} "
                      f"{s['ratio_to_opt']:7.3f}")

    out["claims"] = _check_claims(out)
    if verbose:
        print(f"\nfig3 batched: {len(configs)} configs x {res.n_steps} "
              f"steps in {wall:.1f}s -> claims {out['claims']}")
    return out


def _check_claims(f3: dict) -> dict:
    """The paper's qualitative orderings (C2-C4) on the batched results."""
    ss = f3["cs_short_ncs_short"]["summary"]
    ls = f3["cs_long_ncs_short"]["summary"]
    lo = f3["cs_short_ncs_long"]["summary"]
    # C2: short CS — mutable within ~12% of optimum and above PT-EXP.
    c2 = (ss["mutable"]["ratio_to_opt"] > ss["pt-exp"]["ratio_to_opt"]
          and ss["mutable"]["ratio_to_opt"] > 0.85)
    # C3: long CS — mutable within ~15% of optimum while spin CPU is cut
    # by >= 5x vs TTAS at 20 threads (checked on per-thread rows).
    rows = f3["cs_long_ncs_short"]["rows"]
    i20 = list(LOCK_THREADS).index(20)
    ttas_cpu = rows["ttas"][i20]["sync_cpu_per_cs"]
    mut_cpu = max(rows["mutable"][i20]["sync_cpu_per_cs"], 1e-12)
    c3 = (ls["mutable"]["ratio_to_opt"] > 0.8 and ttas_cpu / mut_cpu >= 5.0)
    # C4: low contention — every lock within ~12% of every other.
    ratios = [lo[l]["ratio_to_opt"] for l in LOCK_DISCIPLINES]
    c4 = min(ratios) > 0.85
    return {"C2": bool(c2), "C3": bool(c3), "C4": bool(c4),
            "ttas_over_mutable_cpu_at_20t": round(ttas_cpu / mut_cpu, 1)}


# --------------------------------------------------------------------------
# Beyond-paper scenario sweep
# --------------------------------------------------------------------------
def scenario(n_scenarios: int = 200, target_cs: int = 150,
             backend: str = "ref", seed: int = 0, bucket: bool = True,
             verbose: bool = True) -> dict:
    """``bucket=True`` groups the heterogeneous scenarios into power-of-two
    step-count buckets (:func:`repro.core.xdes.plan_buckets`) — one
    batched call per bucket instead of pinning every cell to the slowest
    scenario's scan length.  All five locks of a scenario share its
    planned step count, so per-scenario comparisons stay consistent."""
    locks = list(LOCK_DISCIPLINES)
    configs = lock_scenario_sweep(n_scenarios=n_scenarios, seed=seed,
                                  locks=locks)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs, backend=backend,
                              bucket_steps=bucket)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, len(locks))
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, len(locks))
    best = thr.max(axis=1)
    win = thr.argmax(axis=1)
    ratio = thr / np.maximum(best[:, None], 1e-30)

    out = {
        "meta": {"backend": backend, "n_configs": len(configs),
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "configs_per_s": round(len(configs) / max(wall, 1e-9), 1)},
        "wins": {lock: int((win == i).sum())
                 for i, lock in enumerate(locks)},
        "mean_ratio_to_best": {lock: float(ratio[:, i].mean())
                               for i, lock in enumerate(locks)},
        "p10_ratio_to_best": {lock: float(np.percentile(ratio[:, i], 10))
                              for i, lock in enumerate(locks)},
        "mean_sync_cpu_per_cs_us": {lock: float(cpu[:, i].mean() * 1e6)
                                    for i, lock in enumerate(locks)},
    }
    if verbose:
        print(f"\nscenario sweep: {len(configs)} configs x {res.n_steps} "
              f"steps in {wall:.1f}s "
              f"({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'lock':>10} {'wins':>6} {'mean ratio':>11} "
              f"{'p10 ratio':>10} {'cpu/cs (µs)':>12}")
        for i, lock in enumerate(locks):
            print(f"{lock:>10} {out['wins'][lock]:6d} "
                  f"{out['mean_ratio_to_best'][lock]:11.3f} "
                  f"{out['p10_ratio_to_best'][lock]:10.3f} "
                  f"{out['mean_sync_cpu_per_cs_us'][lock]:12.2f}")
    return out


# --------------------------------------------------------------------------
# Oracle-family ablation grid
# --------------------------------------------------------------------------
def _bucket_scenarios(configs, n_variants: int) -> list[dict]:
    """Coarse workload features per scenario (row 0 of each variant block):
    the phase-diagram axes of the oracle report."""
    feats = []
    for s in range(len(configs) // n_variants):
        c = configs[s * n_variants]
        feats.append({
            "cs": ("short" if c.cs[1] <= 1e-5
                   else "mid" if c.cs[1] <= 1e-4 else "long"),
            "sub": "under" if c.threads <= c.cores else "over",
            "wake": "fast" if c.wake_latency <= 1e-5 else "slow",
        })
    return feats


def oracle_grid(n_scenarios: int = 200, target_cs: int = 150,
                backend: str = "ref", seed: int = 0,
                oracles=LOCK_ORACLES, ks=LOCK_ORACLE_KS,
                sws_maxes=LOCK_ORACLE_SWS_MAX, verbose: bool = True) -> dict:
    """The full ``(oracle, K, sws_max) x scenario`` product as ONE
    jit-compiled :func:`repro.core.xdes.simulate_batch` call (no per-cell
    Python loop), summarized three ways:

    * per variant — wins, mean/p10 throughput ratio to the per-scenario
      best variant, spin CPU per CS;
    * per family — wins of its best-tuned variant and the ratio a
      per-scenario best tuning of that family achieves;
    * phase diagram — which family wins in each (CS-length x
      subscription x wake-latency) workload bucket, the "which oracle
      wins where" artifact rendered by ``benchmarks/oracle_ablation.py``.
    """
    variants = lock_oracle_variants(oracles, ks, sws_maxes)
    configs = lock_oracle_sweep(n_scenarios=n_scenarios, seed=seed,
                                oracles=oracles, ks=ks, sws_maxes=sws_maxes)
    V = len(variants)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs, backend=backend)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, V)
    sws = res.final_sws.reshape(n_scenarios, V)
    best = np.maximum(thr.max(axis=1), 1e-30)
    ratio = thr / best[:, None]
    win = thr.argmax(axis=1)

    def vname(v):
        m = "cores" if v["sws_max"] is None else v["sws_max"]
        return f"{v['oracle']}-k{v['k']}-m{m}"

    out_variants = [{
        "name": vname(v), "oracle": v["oracle"], "k": v["k"],
        "sws_max": v["sws_max"], "wins": int((win == i).sum()),
        "mean_ratio_to_best": float(ratio[:, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, i].mean() * 1e6),
        "mean_final_sws": float(sws[:, i].mean()),
    } for i, v in enumerate(variants)]

    fam_names = list(dict.fromkeys(v["oracle"] for v in variants))
    fam_cols = {f: [i for i, v in enumerate(variants) if v["oracle"] == f]
                for f in fam_names}
    win_fam = np.asarray([variants[i]["oracle"] for i in win])
    families = {f: {
        "wins": int((win_fam == f).sum()),
        # ratio achieved by the best tuning of this family per scenario
        "best_tuned_mean_ratio": float(ratio[:, cols].max(axis=1).mean()),
        "mean_sync_cpu_per_cs_us": float(cpu[:, cols].mean() * 1e6),
    } for f, cols in fam_cols.items()}

    feats = _bucket_scenarios(configs, V)
    cells: dict[tuple, dict] = {}
    for s, ft in enumerate(feats):
        key = (ft["cs"], ft["sub"], ft["wake"])
        cell = cells.setdefault(key, {f: 0 for f in fam_names})
        cell[win_fam[s]] += 1
    phase = []
    for (cs_b, sub_b, wake_b), counts in sorted(cells.items()):
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"cs": cs_b, "sub": sub_b, "wake": wake_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_family": counts})

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_variants": V, "n_configs": len(configs),
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "configs_per_s": round(len(configs) / max(wall, 1e-9), 1)},
        "variants": out_variants,
        "families": families,
        "phase": phase,
    }
    if verbose:
        print(f"\noracle grid: {len(configs)} configs ({n_scenarios} "
              f"scenarios x {V} variants) x {res.n_steps} steps "
              f"in {wall:.1f}s ({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'family':>9} {'wins':>5} {'best-tuned ratio':>17} "
              f"{'cpu/cs (µs)':>12}")
        for f, row in families.items():
            print(f"{f:>9} {row['wins']:5d} "
                  f"{row['best_tuned_mean_ratio']:17.3f} "
                  f"{row['mean_sync_cpu_per_cs_us']:12.2f}")
    return out


# --------------------------------------------------------------------------
# Discipline x oracle diagram grid
# --------------------------------------------------------------------------
def discipline_grid(n_scenarios: int = 200, target_cs: int = 150,
                    backend: str = "ref", seed: int = 0,
                    disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                    shard: bool | None = None, verbose: bool = True) -> dict:
    """The full ``(discipline, oracle) x scenario`` product — every row of
    ``DISCIPLINE_ROWS`` crossed with every ``ORACLE_ROWS`` family — as ONE
    (sharded) jit-compiled :func:`repro.core.xdes.simulate_batch` call,
    summarized three ways:

    * per variant — wins, mean/p10 throughput ratio to the per-scenario
      best variant, spin CPU per CS, fairness spread;
    * per discipline — wins of its best variant and the ratio its
      best-oracle tuning achieves per scenario;
    * phase diagram — which (discipline, oracle) wins in each (CS-length
      x subscription x wake-latency) workload bucket: the "which lock
      wins where" artifact rendered by ``benchmarks/discipline_diagram.py``.
    """
    variants = lock_discipline_variants(disciplines, oracles)
    configs = lock_discipline_sweep(n_scenarios=n_scenarios, seed=seed,
                                    disciplines=disciplines, oracles=oracles)
    V = len(variants)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs, backend=backend,
                              shard=shard)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, V)
    best = np.maximum(thr.max(axis=1), 1e-30)
    ratio = thr / best[:, None]
    win = thr.argmax(axis=1)

    def vname(v):
        return (f"{v['lock']}/{v['oracle']}"
                if v["lock"] == "mutable" else v["lock"])

    out_variants = [{
        "name": vname(v), "lock": v["lock"], "oracle": v["oracle"],
        "wins": int((win == i).sum()),
        "mean_ratio_to_best": float(ratio[:, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, i].mean() * 1e6),
    } for i, v in enumerate(variants)]

    disc_names = list(dict.fromkeys(v["lock"] for v in variants))
    disc_cols = {d: [i for i, v in enumerate(variants) if v["lock"] == d]
                 for d in disc_names}
    win_disc = np.asarray([variants[i]["lock"] for i in win])
    by_discipline = {d: {
        "wins": int((win_disc == d).sum()),
        "best_variant_mean_ratio": float(ratio[:, cols].max(axis=1).mean()),
        "mean_sync_cpu_per_cs_us": float(cpu[:, cols].mean() * 1e6),
    } for d, cols in disc_cols.items()}

    feats = _bucket_scenarios(configs, V)
    win_name = np.asarray([out_variants[i]["name"] for i in win])
    cells: dict[tuple, dict] = {}
    for s, ft in enumerate(feats):
        key = (ft["cs"], ft["sub"], ft["wake"])
        cell = cells.setdefault(key, {})
        cell[win_name[s]] = cell.get(win_name[s], 0) + 1
    phase = []
    for (cs_b, sub_b, wake_b), counts in sorted(cells.items()):
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"cs": cs_b, "sub": sub_b, "wake": wake_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_variants": V, "n_configs": len(configs),
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "configs_per_s": round(len(configs) / max(wall, 1e-9), 1)},
        "variants": out_variants,
        "disciplines": by_discipline,
        "phase": phase,
    }
    if verbose:
        print(f"\ndiscipline grid: {len(configs)} configs ({n_scenarios} "
              f"scenarios x {V} variants) x {res.n_steps} steps in "
              f"{wall:.1f}s on {out['meta']['n_devices']} device(s) "
              f"({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'discipline':>10} {'wins':>5} {'best-variant ratio':>19} "
              f"{'cpu/cs (µs)':>12}")
        for d, row in by_discipline.items():
            print(f"{d:>10} {row['wins']:5d} "
                  f"{row['best_variant_mean_ratio']:19.3f} "
                  f"{row['mean_sync_cpu_per_cs_us']:12.2f}")
    return out


# --------------------------------------------------------------------------
# Workload x discipline x oracle diagram grid
# --------------------------------------------------------------------------
def workload_grid(n_scenarios: int = 100, target_cs: int = 150,
                  backend: str = "ref", seed: int = 0,
                  workloads=LOCK_WORKLOADS,
                  disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                  shard: bool | None = None, verbose: bool = True) -> dict:
    """The full ``workload x (discipline, oracle) x scenario`` product —
    every row of ``WORKLOAD_ROWS`` crossed with every discipline-diagram
    variant — as ONE (sharded) jit-compiled
    :func:`repro.core.xdes.simulate_batch` call, summarized three ways:

    * per (workload, variant) — wins, mean/p10 throughput ratio to the
      per-(scenario, workload) best variant, spin CPU per CS;
    * per workload — which discipline wins how often under that hold-time
      model, and each discipline's best-variant mean ratio;
    * phase diagram — which (discipline, oracle) wins in each
      (workload x CS-length x subscription) bucket: the "which lock wins
      under which workload" artifact rendered by
      ``benchmarks/workload_diagram.py``.

    The per-scenario best is taken *within* a workload, so a variant is
    judged against the other locks under the same workload — never
    against an easier workload's throughput.
    """
    disc_variants = lock_discipline_variants(disciplines, oracles)
    configs = lock_workload_sweep(n_scenarios=n_scenarios, seed=seed,
                                  workloads=workloads,
                                  disciplines=disciplines, oracles=oracles)
    W, V = len(workloads), len(disc_variants)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs, backend=backend,
                              shard=shard)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, W, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, W, V)
    best = np.maximum(thr.max(axis=2), 1e-30)          # (S, W)
    ratio = thr / best[..., None]
    win = thr.argmax(axis=2)                           # (S, W)

    def vname(v):
        return (f"{v['lock']}/{v['oracle']}"
                if v["lock"] == "mutable" else v["lock"])

    variant_names = [vname(v) for v in disc_variants]
    out_variants = [{
        "workload": w, "name": variant_names[i],
        "lock": disc_variants[i]["lock"],
        "oracle": disc_variants[i]["oracle"],
        "wins": int((win[:, wi] == i).sum()),
        "mean_ratio_to_best": float(ratio[:, wi, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, wi, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, wi, i].mean() * 1e6),
    } for wi, w in enumerate(workloads) for i in range(V)]

    disc_names = list(dict.fromkeys(v["lock"] for v in disc_variants))
    disc_cols = {d: [i for i, v in enumerate(disc_variants)
                     if v["lock"] == d] for d in disc_names}
    by_workload = {}
    for wi, w in enumerate(workloads):
        win_disc = np.asarray([disc_variants[i]["lock"]
                               for i in win[:, wi]])
        by_workload[w] = {d: {
            "wins": int((win_disc == d).sum()),
            "best_variant_mean_ratio":
                float(ratio[:, wi, cols].max(axis=1).mean()),
            "mean_sync_cpu_per_cs_us":
                float(cpu[:, wi, cols].mean() * 1e6),
        } for d, cols in disc_cols.items()}

    feats = _bucket_scenarios(configs, W * V)
    cells: dict[tuple, dict] = {}
    for s, ft in enumerate(feats):
        for wi, w in enumerate(workloads):
            key = (w, ft["cs"], ft["sub"])
            cell = cells.setdefault(key, {})
            name = variant_names[win[s, wi]]
            cell[name] = cell.get(name, 0) + 1
    phase = []
    for (w, cs_b, sub_b), counts in sorted(
            cells.items(), key=lambda kv: (list(workloads).index(kv[0][0]),
                                           kv[0][1:])):
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"workload": w, "cs": cs_b, "sub": sub_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_workloads": W, "n_variants": V,
                 "n_configs": len(configs), "n_steps": res.n_steps,
                 "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "configs_per_s": round(len(configs) / max(wall, 1e-9), 1),
                 "workloads": list(workloads),
                 "variant_names": variant_names},
        "variants": out_variants,
        "workloads": by_workload,
        "phase": phase,
    }
    if verbose:
        print(f"\nworkload grid: {len(configs)} configs ({n_scenarios} "
              f"scenarios x {W} workloads x {V} variants) x {res.n_steps} "
              f"steps in {wall:.1f}s on {out['meta']['n_devices']} "
              f"device(s) ({out['meta']['configs_per_s']} cfg/s)")
        for w in workloads:
            rows = by_workload[w]
            top = max(rows, key=lambda d: rows[d]["wins"])
            print(f"{w:>9}: top discipline {top} "
                  f"({rows[top]['wins']}/{n_scenarios} wins); "
                  + " ".join(f"{d}:{r['wins']}" for d, r in rows.items()))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale batches (<60 s total)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--scenarios", type=int, default=200)
    ap.add_argument("--target-cs", type=int, default=250)
    ap.add_argument("--no-bucket", action="store_true",
                    help="run the scenario sweep as one global-horizon "
                         "batch instead of per-step-count buckets")
    ap.add_argument("--out", default="reports/sweep.json")
    args = ap.parse_args(argv)

    if args.quick:
        f3 = fig3_batched(target_cs=60, seeds=(0,), backend=args.backend)
        sc = scenario(n_scenarios=40, target_cs=50, backend=args.backend,
                      bucket=not args.no_bucket)
    else:
        f3 = fig3_batched(target_cs=args.target_cs, backend=args.backend)
        sc = scenario(n_scenarios=args.scenarios,
                      target_cs=args.target_cs, backend=args.backend,
                      bucket=not args.no_bucket)

    results = {"fig3": f3, "scenario": sc}
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}")
    return results


if __name__ == "__main__":
    main()
